"""Unit tests for the mesh/PartitionSpec plumbing (sharding/partition.py,
launch/mesh.py): spec construction from the name-based rule tables, the
divisibility-fitting fallback, the paged-pool TP specs, and the TP mesh
constructor.  All of it runs on a single device (specs are pure data; the
1-device mesh degenerately satisfies every divisibility check); the fake
mesh stands in where a >1 axis size is needed so the fitting logic is
tested without device simulation.
"""
import types

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as M
from repro.sharding import partition as Pt


def _fake_mesh(**axes):
    """Shape-only stand-in for _fit_spec (which reads mesh.shape[axis])."""
    return types.SimpleNamespace(shape=dict(axes),
                                 axis_names=tuple(axes))


# --- _fit_spec ------------------------------------------------------------


def test_fit_spec_keeps_divisible_axes():
    mesh = _fake_mesh(data=2, model=4)
    sp = Pt._fit_spec(P(None, "model", None), (3, 8, 5), mesh)
    assert sp == P(None, "model", None)


def test_fit_spec_drops_indivisible_axis():
    mesh = _fake_mesh(data=2, model=4)
    # 6 % 4 != 0 -> the model axis is dropped, the rest survives
    sp = Pt._fit_spec(P("data", "model"), (4, 6), mesh)
    assert sp == P("data", None)


def test_fit_spec_trims_to_rank():
    mesh = _fake_mesh(model=2)
    sp = Pt._fit_spec(P(None, "model", None), (4, 4), mesh)
    assert len(sp) == 2


# --- rule tables ----------------------------------------------------------


def _dev_mesh():
    return M.make_tp_mesh(1)  # 1-device ('model',) mesh, always available


def test_serve_rules_spec_lookup():
    rules = Pt._serve_rules("data")
    assert Pt._spec_for("blocks/slot0/wq/w", rules, 3) == \
        P(None, None, "model")
    assert Pt._spec_for("blocks/slot0/wo/w", rules, 3) == \
        P(None, "model", None)
    assert Pt._spec_for("lm_head/w", rules, 2) == P(None, "model")
    # unmatched paths replicate
    assert Pt._spec_for("blocks/slot0/attn_q/M_idx", rules, 0) == P()


def test_make_param_shardings_on_struct_tree():
    mesh = _dev_mesh()
    tree = {"lm_head": {"w": jax.ShapeDtypeStruct((8, 16), np.int8)},
            "blocks": {"slot0": {"wq": {
                "w": jax.ShapeDtypeStruct((2, 4, 8), np.int8)}}}}
    sh = Pt.make_param_shardings(mesh, tree, mode="serve")
    assert sh["lm_head"]["w"].spec == P(None, "model")
    assert sh["blocks"]["slot0"]["wq"]["w"].spec == P(None, None, "model")


# --- paged-pool TP specs --------------------------------------------------


def test_kv_pool_pspec_shards_only_heads():
    sp = Pt.kv_pool_pspec()
    # (n_reps, n_pages, P, Hkv, hd): pages MUST stay unsharded — global
    # page ids are what keep the host allocator a single authority
    assert sp == P(None, None, None, "model", None)
    assert sp[1] is None and sp[3] == "model"


def test_paged_pool_shardings_tree():
    mesh = _dev_mesh()
    pool = {"slot0": {"k": jax.ShapeDtypeStruct((2, 9, 4, 4, 32), np.int8),
                      "v": jax.ShapeDtypeStruct((2, 9, 4, 4, 32), np.int8)}}
    sh = Pt.paged_pool_shardings(mesh, pool)
    for leaf in (sh["slot0"]["k"], sh["slot0"]["v"]):
        assert leaf.spec == P(None, None, None, "model", None)
        assert leaf.mesh.shape["model"] == 1


def test_paged_pool_shardings_drops_indivisible_heads():
    # Hkv=3 on a 4-way model axis cannot shard: _fit_spec falls back to
    # replicated rather than erroring (the engine asserts divisibility
    # before ever building such a pool)
    mesh = _fake_mesh(model=4)
    sp = Pt._fit_spec(Pt.kv_pool_pspec(), (2, 9, 4, 3, 32), mesh)
    assert sp == P(None, None, None, None, None)


# --- meshes ---------------------------------------------------------------


def test_make_tp_mesh_shape_and_axis():
    mesh = M.make_tp_mesh(1)
    assert mesh.axis_names == ("model",)
    assert mesh.shape["model"] == 1


def test_make_tp_mesh_rejects_oversubscription():
    with pytest.raises(AssertionError, match="devices"):
        M.make_tp_mesh(len(jax.devices()) + 1)


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 devices")
def test_make_tp_mesh_multi_device():
    mesh = M.make_tp_mesh(4)
    assert mesh.shape["model"] == 4
    assert len(set(mesh.devices.flat)) == 4


def test_shard_map_compat_runs_degenerate():
    """The compat wrapper must produce a working shard_map on whatever jax
    version is installed (the CI matrix pins the floor and latest)."""
    mesh = M.make_tp_mesh(1)
    f = Pt.shard_map_compat(lambda x: x * 2, mesh, in_specs=(P(),),
                            out_specs=P())
    y = jax.jit(f)(np.arange(4, dtype=np.int32))
    assert np.array_equal(np.asarray(y), np.arange(4) * 2)
