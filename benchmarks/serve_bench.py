"""Serving throughput AND latency: cache layouts (paged vs contiguous),
engines (continuous vs lockstep), and prefill scheduling (chunked vs
one-shot) over the same folded integer model.

Workloads (``--workload``):

  * ``poisson`` — N requests from a Poisson arrival process, prompt lengths
    mixed over a palette (16-256 tokens by default), per-request decode
    budgets.
  * ``prefix`` — the millions-of-users shape: every request shares one long
    system prompt (``--prefix-len``) followed by a short unique suffix drawn
    from the length palette.  The paged engine's block-table allocator maps
    the shared prefix pages copy-on-write, so repeated prompts skip both the
    prefill compute and the pages.
  * ``longprompt`` — the tail-latency shape: a few very long prompts
    (``--n-long`` x ``--long-len``) dropped into steady short-request
    traffic.  Runs the paged engine twice — one-shot admission prefill vs
    the chunked token-budget loop (``--max-batched-tokens`` /
    ``--max-prefill-chunk``) — and reports per-class TTFT: chunking bounds
    the short requests' TTFT because a long prompt no longer monopolizes
    the step loop for its whole prefill.
  * ``overload`` — decode-heavy traffic against a page pool deliberately
    too small for the concurrent decode budgets (``--pool-pages``, auto =
    one worst-case request plus one page of headroom).  A/Bs
    ``reserve_policy="full"`` (admission waits until a request's whole
    budget fits — nothing is ever spilled) against ``"ondemand"`` (admit
    on prompt pages, grow decode pages at boundary crossings, preempt a
    victim when the pool runs dry), with an unlimited-pool run supplying
    the truth tokens.  Reports preemption / recomputed-token /
    pool-wait counters per run; exits non-zero if the preempted run's
    greedy outputs diverge from the unlimited pool's, or if the sized
    pool failed to force at least one spill.

``--spec-k K`` runs the speculative-decoding A/B instead: plain greedy
decode vs draft-then-verify (prompt-lookup proposals, up to K per slot per
tick) on the same paged engine over a lookup-friendly cycle-prompt
workload (artifact BENCH_SPEC.json).  Greedy acceptance is exact argmax
matching, so outputs must be bit-identical off-pallas (gated), and the
deterministic decode-forward reduction must reach 1.2x (gated); wall
tok/s and accepted-tokens/forward are reported.

``--affinity`` runs the prefix-affinity + shared-prefix-tier A/B instead:
a 2-replica router with ``affinity=False, shared_tier=False`` vs
``affinity=True, shared_tier=True`` over a multi-conversation chat
workload (artifact BENCH_AFFINITY.json).  Placement must never change
greedy tokens (gated off-pallas), and the on-run's total prefill work —
``prefill_tokens`` summed over replicas, a deterministic scheduling
counter — must be strictly below the off-run's (gated): conversations
stick to the replica holding their prefix chain, and replicas adopt
published chains from the shared host tier instead of re-prefilling.

``--tp N`` (any workload flag ignored; Poisson shape) runs the
tensor-parallel A/B instead: the paged engine unsharded vs sharded over an
N-way model mesh (KV-head-sharded page pool, replicated block tables).
Divergence always exits non-zero — the sharded forward reassembles int8
head contexts, so it is bit-exact on every backend.  CI runs it in the
test-tp lane under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(artifact BENCH_TP.json).

Engines/layouts (``--layout``, poisson/prefix workloads):

  * ``contiguous`` — lockstep baseline vs the continuous engine on the dense
    per-slot cache (the pre-paging A/B).
  * ``paged``      — continuous engine, contiguous vs PAGED cache layout:
    same requests, same greedy tokens, different cache addressing.
  * ``both``       — all three (default).

Every run reports aggregate tokens/s plus per-request TTFT and inter-token
latency p50/p95 (wall clock, measured on the timed pass).  All randomness —
the Poisson arrival trace, prompt sampling, and the shared prefix — derives
from ONE ``--seed`` through independent SeedSequence streams, so A/B runs
replay the identical workload.

Greedy outputs must be identical per request across every engine / layout /
chunking policy off the compiled pallas backend — scheduling changes
throughput and latency, not tokens; the bench exits non-zero on a mismatch.
Prints ``name,value,derived`` CSV; ``--json`` also writes an artifact
(BENCH_PR.json / BENCH_PREFIX.json / BENCH_CHUNKED.json in CI) for the perf
trajectory; the longprompt artifact includes a per-tick Engine.stats()
trace of the chunked run.

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json BENCH_PR.json
    PYTHONPATH=src python benchmarks/serve_bench.py --workload prefix --layout paged
    PYTHONPATH=src python benchmarks/serve_bench.py --workload longprompt \
        --json BENCH_CHUNKED.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def make_workload(rng, n_requests, lengths, rate, max_new_range,
                  prefix_len=0):
    """Poisson arrivals: exponential interarrival gaps (unit = engine
    ticks), uniform prompt-length palette, uniform decode budgets.  With
    ``prefix_len`` the palette lengths become suffixes after one shared
    system prompt."""
    t = 0.0
    work = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        work.append(dict(
            arrival=t,
            prompt_len=prefix_len + int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
            cls="all",
        ))
    return work


def make_longprompt_workload(rng, n_long, long_len, n_short, lengths, rate,
                             max_new_range):
    """A few very long prompts spread over a steady stream of short
    requests — the workload whose TTFT tail one-shot admission prefill
    ruins and chunked prefill bounds.  Each long prompt lands on a short
    request's arrival tick, AHEAD of it in FIFO order — the collision where
    one-shot admission makes the short wait out the entire long prefill
    (in continuous traffic these collisions are the norm; the virtual-time
    clock would otherwise hide them between ticks)."""
    t = 0.0
    shorts = []
    for _ in range(n_short):
        t += rng.exponential(1.0 / rate)
        shorts.append(dict(
            arrival=t,
            prompt_len=int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
            cls="short",
        ))
    longs = [dict(arrival=shorts[(j * n_short) // n_long]["arrival"],
                  prompt_len=long_len,
                  max_new=int(rng.integers(*max_new_range)),
                  cls="long")
             for j in range(max(n_long, 0))] if shorts else []
    # stable sort: a long precedes its equal-arrival short (FIFO collision)
    return sorted(longs + shorts, key=lambda w: w["arrival"])


def make_bursty_workload(rng, n_requests, lengths, rate, max_new_range, *,
                         burst=4, prefix_len=0, prefix_frac=0.5,
                         cancel_frac=0.25):
    """Bursty chat traffic for the serving stack: arrivals land in bursts
    of ``burst`` requests on one tick (exponential gaps between bursts,
    mean ``burst/rate`` so the long-run rate matches the Poisson
    workloads), a ``prefix_frac`` share are prefix-heavy chat turns
    sharing one system prompt, and ``cancel_frac`` of requests carry a
    ``cancel_after`` token count after which the client cancels the
    stream mid-decode."""
    t = 0.0
    work = []
    while len(work) < n_requests:
        t += rng.exponential(burst / rate)
        for _ in range(min(burst, n_requests - len(work))):
            chat = prefix_len > 0 and rng.random() < prefix_frac
            mn = int(rng.integers(*max_new_range))
            cancel_after = (int(rng.integers(1, max(2, mn)))
                            if rng.random() < cancel_frac else None)
            work.append(dict(
                arrival=t,
                prompt_len=(prefix_len if chat else 0)
                + int(rng.choice(lengths)),
                max_new=mn,
                cls="chat" if chat else "plain",
                chat=chat,
                cancel_after=cancel_after,
            ))
    return work


def make_affinity_workload(rng, n_convs, turns, lengths, rate,
                           max_new_range):
    """Multi-conversation chat for the prefix-affinity A/B: ``n_convs``
    conversations, ``turns`` turns each, every turn sharing its
    conversation's system prompt and adding a unique suffix.  Turn order
    is a fresh shuffle per round, so conversations interleave irregularly
    — the shape where affinity-less least-loaded placement scatters one
    conversation's turns across replicas and each replica re-prefills the
    shared prefix the others already paid for."""
    order = []
    for _ in range(turns):
        order.extend(int(c) for c in rng.permutation(n_convs))
    t = 0.0
    work = []
    for c in order:
        t += rng.exponential(1.0 / rate)
        work.append(dict(
            arrival=t, conv=c,
            suffix_len=int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
            cls="chat", cancel_after=None))
    return work


def build_requests(Request, rng, work, vocab, prefix=None):
    reqs = []
    for w in work:
        suffix_len = w["prompt_len"] - (len(prefix) if prefix is not None
                                        else 0)
        suffix = rng.integers(0, vocab, (suffix_len,)).astype(np.int32)
        prompt = suffix if prefix is None else np.concatenate([prefix, suffix])
        reqs.append(Request(prompt=prompt, max_new_tokens=w["max_new"]))
    return reqs


def run_lockstep(eng, requests):
    """Static batching: same-length groups (correct per-request outputs),
    each group decoded to its longest budget.  The engine is reset between
    groups — recurrent-state archs (mamba/xLSTM) would otherwise leak the
    previous group's SSM state into the next prefill (attention rows are
    position-masked; SSM state is not)."""
    by_len = {}
    for r in requests:
        by_len.setdefault(len(r.prompt), []).append(r)
    for group in by_len.values():
        for i in range(0, len(group), eng.batch):
            eng.reset()
            eng.generate(group[i:i + eng.batch])
    return requests


def run_continuous(eng, requests, work, lat=None, trace=None):
    """Requests arrive over virtual time (1 tick = one engine step)
    following the workload's arrival process and are submitted when due;
    the clock fast-forwards over idle gaps so lulls cost no wall time.
    ``lat`` (dict) collects per-request submit/token timestamps; ``trace``
    (list) collects Engine.stats() gauges per tick."""
    rid2idx = {}
    i = 0
    n = len(requests)

    def submit(idx, tick):
        rid2idx[eng.submit(requests[idx])] = idx
        if lat is not None:
            lat[idx] = dict(submit_tick=tick,
                            submit_wall=time.perf_counter(), tokens=[])

    while i < n or eng.sched.has_work:
        t = eng.counters["ticks"]
        while i < n and work[i]["arrival"] <= t:
            submit(i, t)
            i += 1
        if not eng.sched.has_work and i < n:
            # idle: jump the clock to the next arrival — and submit EVERY
            # request due at that instant, so same-arrival collisions (the
            # longprompt workload's point) survive the fast-forward
            t_next = work[i]["arrival"]
            while i < n and work[i]["arrival"] <= t_next:
                submit(i, t_next)
                i += 1
        emitted = eng.step()
        now = time.perf_counter()
        tick = eng.counters["ticks"]
        if lat is not None:
            for rid, _tok in emitted:
                lat[rid2idx[rid]]["tokens"].append((tick, now))
        if trace is not None:
            if len(trace) < 5000:
                g = eng.stats()
                g.pop("counters")
                g["tick"] = tick
                trace.append(g)
            elif trace[-1] != "TRUNCATED":
                trace.append("TRUNCATED")   # explicit, not a silent cutoff
    return requests


def latency_summary(work, lat):
    """Per-request TTFT (submit -> first token) p50/p95 per request class,
    and inter-token latency p50/p95 pooled over all gaps.  Milliseconds."""
    def pct(xs, q):
        return round(float(np.percentile(xs, q)) * 1e3, 3) if xs else 0.0

    ttft_by_cls = {}
    itl = []
    for i, w in enumerate(work):
        rec = lat.get(i)
        if not rec or not rec["tokens"]:
            continue
        ttft_by_cls.setdefault(w["cls"], []).append(
            rec["tokens"][0][1] - rec["submit_wall"])
        walls = [wall for _, wall in rec["tokens"]]
        itl.extend(float(d) for d in np.diff(walls))
    out = dict(itl_p50_ms=pct(itl, 50), itl_p95_ms=pct(itl, 95))
    for cls, tt in sorted(ttft_by_cls.items()):
        out[f"ttft_{cls}_p50_ms"] = pct(tt, 50)
        out[f"ttft_{cls}_p95_ms"] = pct(tt, 95)
    return out


def _timed(runner, eng, fresh, *extra, **kw):
    """Warmup pass (compilation) then a timed pass on fresh state."""
    runner(eng, fresh(), *extra)
    eng.reset()
    t0 = time.perf_counter()
    out = runner(eng, fresh(), *extra, **kw)
    return out, time.perf_counter() - t0


def _rng_streams(seed):
    """Independent deterministic streams off ONE seed: arrival process,
    prompt tokens, shared prefix tokens.  A/B runs (and the warmup vs
    timed pass) therefore replay byte-identical workloads."""
    ss = np.random.SeedSequence(seed)
    return [np.random.default_rng(c) for c in ss.spawn(3)]


def bench_chunked(args, cfg, folded, Request):
    """longprompt workload: paged one-shot admission vs the chunked
    token-budget loop, same requests, same tokens — different TTFT tail."""
    from repro.serve.engine import Engine, EngineConfig

    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_longprompt_workload(
        r_arrival, args.n_long, args.long_len, args.requests, lengths,
        args.rate, (args.max_new_lo, args.max_new_hi))
    max_len = max(args.long_len, max(lengths)) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size)

    n_tok = sum(w["max_new"] for w in work)
    rows, outs, summaries = [], {}, {}
    artifact = dict(
        bench="serve_chunked", workload="longprompt", arch=cfg.name,
        slots=args.slots, n_long=args.n_long, long_len=args.long_len,
        n_short=args.requests, lengths=lengths, page_size=args.page_size,
        max_batched_tokens=args.max_batched_tokens,
        max_prefill_chunk=args.max_prefill_chunk, seed=args.seed)

    trace = []
    for name, kw, tr in [
        ("oneshot", {}, None),
        ("chunked", dict(max_batched_tokens=args.max_batched_tokens,
                         max_prefill_chunk=args.max_prefill_chunk), trace),
    ]:
        eng = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size, **kw))
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work,
                           lat=lat, trace=tr)
        outs[name] = [r.out.tolist() for r in out]
        summaries[name] = latency_summary(work, lat)
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_ttft_short_p95_ms",
                     summaries[name].get("ttft_short_p95_ms", 0.0),
                     f"p50={summaries[name].get('ttft_short_p50_ms', 0.0)}"))
        rows.append((f"serve/{name}_itl_p95_ms",
                     summaries[name]["itl_p95_ms"], ""))
        artifact[name] = dict(tok_per_s=round(tps, 2), **summaries[name],
                              engine_counters=eng.counters)

    os_p95 = summaries["oneshot"].get("ttft_short_p95_ms", 0.0)
    ch_p95 = summaries["chunked"].get("ttft_short_p95_ms", 0.0)
    if ch_p95 > 0:
        rows.append(("serve/chunked_ttft_short_p95_speedup",
                     os_p95 / ch_p95, "oneshot_p95/chunked_p95"))
        artifact["ttft_short_p95_speedup"] = round(os_p95 / ch_p95, 3)
    match = outs["chunked"] == outs["oneshot"]
    rows.append(("serve/outputs_match", float(match), "chunked+oneshot"))
    artifact.update(outputs_match=bool(match), stats_trace=trace)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        print("ERROR: greedy outputs diverged between chunked and one-shot "
              "prefill", file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    return 0


def bench_tp(args, cfg, folded, Request):
    """--tp N: sharded-vs-unsharded A/B on the paged engine — same Poisson
    workload, the pool sharded over KV heads on an N-way model mesh (on
    CPU: XLA_FLAGS=--xla_force_host_platform_device_count=N).  Sharding
    must change memory layout only, never greedy tokens; exits non-zero on
    divergence on any backend (the sharded forward all-gathers int8 head
    contexts, which is bit-exact even where prefill kernels are not)."""
    from repro.serve.engine import Engine, EngineConfig

    if len(jax.devices()) < args.tp:
        print(f"ERROR: --tp {args.tp} needs {args.tp} devices, found "
              f"{len(jax.devices())}; on CPU set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.tp}",
              file=sys.stderr)
        return 1
    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi))
    max_len = max(lengths) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size)

    n_tok = sum(w["max_new"] for w in work)
    rows, outs = [], {}
    artifact = dict(
        bench="serve_tp", workload="poisson", arch=cfg.name, tp=args.tp,
        slots=args.slots, requests=args.requests, lengths=lengths,
        page_size=args.page_size, seed=args.seed)

    for name, kw in [("unsharded", {}), (f"tp{args.tp}", dict(tp=args.tp))]:
        eng = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size, **kw))
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work, lat=lat)
        outs[name] = [r.out.tolist() for r in out]
        summ = latency_summary(work, lat)
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_ttft_p95_ms",
                     summ.get("ttft_all_p95_ms", 0.0),
                     f"itl_p95={summ['itl_p95_ms']}"))
        artifact[name] = dict(tok_per_s=round(tps, 2), **summ,
                              engine_counters=eng.counters)

    un, sh = outs["unsharded"], outs[f"tp{args.tp}"]
    match = un == sh
    rows.append(("serve/outputs_match", float(match),
                 f"unsharded+tp{args.tp}"))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")
    if not match:
        print(f"ERROR: greedy outputs diverged between the unsharded and "
              f"TP={args.tp} engines", file=sys.stderr)
        return 1
    return 0


def bench_overload(args, cfg, folded, Request):
    """overload workload: on-demand growth + preemption vs full
    reservation on the same starved pool, plus an unlimited-pool truth
    run.  Preemption must change memory, latency, and throughput — never
    greedy tokens."""
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.scheduler import pages_needed

    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi))
    max_len = max(lengths) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size)

    worst = max(pages_needed(w["prompt_len"] + w["max_new"] - 1,
                             args.page_size) for w in work)
    # auto pool: one worst-case request + one page of headroom.  Full
    # reservation can seat roughly one request at a time; on-demand seats
    # every slot on prompt pages and preempts its way through the decode.
    pool = args.pool_pages or (worst + 1)
    if pool < worst:
        # fail BEFORE the engines run: Engine.submit would otherwise raise
        # mid-bench after the unlimited pass already burned its wall time
        print(f"ERROR: --pool-pages {pool} cannot hold the workload's "
              f"largest request ({worst} pages); every request must fit "
              "individually for preemption to make progress",
              file=sys.stderr)
        return 1
    n_tok = sum(w["max_new"] for w in work)
    rows, outs, summaries, counters = [], {}, {}, {}
    artifact = dict(
        bench="serve_preempt", workload="overload", arch=cfg.name,
        slots=args.slots, requests=args.requests, lengths=lengths,
        page_size=args.page_size, pool_pages=pool,
        worst_case_pages=worst, seed=args.seed)

    for name, kw in [
        ("unlimited", {}),                       # ample default pool
        ("full", dict(n_pages=pool + 1, reserve_policy="full")),
        ("ondemand", dict(n_pages=pool + 1, reserve_policy="ondemand")),
    ]:
        eng = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size, **kw))
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work, lat=lat)
        outs[name] = [r.out.tolist() for r in out]
        summaries[name] = latency_summary(work, lat)
        c = dict(eng.counters)
        counters[name] = c
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_preemptions", c["preemptions"],
                     f"recomputed_tokens={c['recomputed_tokens']}"))
        rows.append((f"serve/{name}_pool_wait_ticks", c["pool_wait_ticks"],
                     f"peak_pages={c['cache_pages_peak']}"))
        rows.append((f"serve/{name}_ttft_p95_ms",
                     summaries[name].get("ttft_all_p95_ms", 0.0),
                     f"p50={summaries[name].get('ttft_all_p50_ms', 0.0)}"))
        artifact[name] = dict(tok_per_s=round(tps, 2), **summaries[name],
                              engine_counters=c)

    od = counters["ondemand"]
    od_tps = artifact["ondemand"]["tok_per_s"]
    fl_tps = artifact["full"]["tok_per_s"]
    rows.append(("serve/ondemand_vs_full_tok_per_s_speedup",
                 od_tps / fl_tps, "same starved pool"))
    artifact["ondemand_vs_full_speedup"] = round(od_tps / fl_tps, 3)
    match = outs["ondemand"] == outs["unlimited"] \
        and outs["full"] == outs["unlimited"]
    rows.append(("serve/outputs_match", float(match),
                 "unlimited+full+ondemand"))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        print("ERROR: greedy outputs diverged under preemption / full "
              "reservation", file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    if counters["unlimited"]["preemptions"]:
        print("ERROR: the unlimited-pool reference run preempted — its "
              "outputs are not a clean truth baseline", file=sys.stderr)
        return 1
    if od["preemptions"] < 1:
        print(f"ERROR: pool_pages={pool} failed to force a single "
              "preemption — the overload A/B measured nothing; shrink "
              "--pool-pages or raise --requests/--max-new-hi",
              file=sys.stderr)
        return 1
    return 0


def _first_divergence(a, b):
    """Index of the first differing token between two per-request output
    lists, or -1 if identical (length difference counts at the shorter
    length)."""
    for i, (x, y) in enumerate(zip(a, b, strict=False)):
        if x != y:
            return i
    return -1 if len(a) == len(b) else min(len(a), len(b))


def bench_kv4(args, cfg, folded, Request):
    """--kv-bits 4: int8 vs int4-packed paged KV pool A/B on the plain
    Poisson AND shared-prefix workloads.  The kv4 engine gets the SAME
    POOL BYTE BUDGET as the int8 engine — which buys it ~2x the pages
    (nibble-packed payloads + two fp32 per-page scales).

    kv4 is a QUALITY contract, not an identity contract: greedy outputs
    may diverge from int8 once a page's shared scale clips a code, so the
    first-divergence token index per request is REPORTED (never gated).
    What is gated: the packed pool must fit >= 1.5x more pages in the
    int8 byte budget, and tok/s must hold against the committed baseline
    (check_regression.py)."""
    from repro.serve.engine import Engine, EngineConfig

    lengths = [int(x) for x in args.lengths.split(",")]
    rows = []
    artifact = dict(
        bench="serve_kv4", arch=cfg.name, slots=args.slots,
        requests=args.requests, lengths=lengths,
        prefix_len=args.prefix_len, page_size=args.page_size,
        seed=args.seed)
    worst_headroom = float("inf")

    for wl in ("plain", "prefix"):
        prefix_len = args.prefix_len if wl == "prefix" else 0
        max_len = prefix_len + max(lengths) + args.max_new_hi + 1
        r_arrival, _, r_prefix = _rng_streams(args.seed)
        work = make_workload(r_arrival, args.requests, lengths, args.rate,
                             (args.max_new_lo, args.max_new_hi),
                             prefix_len=prefix_len)
        prefix = (r_prefix.integers(0, cfg.vocab_size, (prefix_len,))
                  .astype(np.int32) if prefix_len else None)

        def fresh():
            _, r_prompt, _ = _rng_streams(args.seed)
            return build_requests(Request, r_prompt, work, cfg.vocab_size,
                                  prefix=prefix)

        n_tok = sum(w["max_new"] for w in work)
        # int8 reference: ample auto pool.  Its byte budget defines the
        # kv4 pool: same bytes, more (packed) pages.
        eng8 = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size))
        budget = eng8.alloc.pool_bytes
        probe = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size, kv_bits=4, n_pages=2))
        bpp4 = probe.alloc.bytes_per_page
        eng4 = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size, kv_bits=4,
            n_pages=budget // bpp4 + 1))

        outs, wrec = {}, dict(
            bytes_per_page_kv8=eng8.alloc.bytes_per_page,
            bytes_per_page_kv4=bpp4,
            pool_bytes_budget=budget,
            pool_capacity_kv8=eng8.alloc.capacity,
            pool_capacity_kv4=eng4.alloc.capacity)
        for name, eng in (("kv8", eng8), ("kv4", eng4)):
            lat = {}
            out, secs = _timed(run_continuous, eng, fresh, work, lat=lat)
            outs[name] = [r.out.tolist() for r in out]
            tps = n_tok / secs
            rows.append((f"serve/{wl}_{name}_tok_per_s", tps,
                         f"wall={secs:.2f}s"))
            wrec[name] = dict(tok_per_s=round(tps, 2),
                              peak_pages=eng.counters["cache_pages_peak"],
                              **latency_summary(work, lat),
                              engine_counters=dict(eng.counters))

        headroom = eng4.alloc.capacity / eng8.alloc.capacity
        worst_headroom = min(worst_headroom, headroom)
        div = [_first_divergence(a, b)
               for a, b in zip(outs["kv4"], outs["kv8"], strict=True)]
        diverged = [d for d in div if d >= 0]
        wrec.update(
            pages_headroom=round(headroom, 3),
            kv4_matches_int8=not diverged,
            first_divergence_token=div,
            min_first_divergence=min(diverged) if diverged else -1,
            diverged_requests=len(diverged))
        rows.append((f"serve/{wl}_kv4_pages_headroom", headroom,
                     f"capacity {eng4.alloc.capacity} vs "
                     f"{eng8.alloc.capacity} in {budget} bytes"))
        rows.append((f"serve/{wl}_kv4_diverged_requests", len(diverged),
                     f"of {len(div)}; first_token="
                     f"{min(diverged) if diverged else -1}"))
        artifact[wl] = wrec

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    if worst_headroom < 1.5:
        print(f"ERROR: kv4 page headroom {worst_headroom:.2f}x < 1.5x — "
              "the packed pool is not paying for itself", file=sys.stderr)
        return 1
    return 0


def build_cycle_requests(Request, rng, work, vocab, period=3):
    """Prompt-lookup-friendly variant of ``build_requests``: each prompt is
    a short random token cycle tiled to the workload's prompt length, so
    the draft source's suffix n-gram always reoccurs earlier in the
    context (the repetitive-text regime prompt-lookup decoding exists
    for — code, copied spans, templated output)."""
    reqs = []
    for w in work:
        pat = rng.integers(0, vocab, (period,)).astype(np.int32)
        prompt = np.tile(pat, w["prompt_len"] // period + 1)[:w["prompt_len"]]
        reqs.append(Request(prompt=prompt, max_new_tokens=w["max_new"]))
    return reqs


def bench_spec(args, cfg, folded, Request):
    """--spec-k K: speculative decoding A/B — plain greedy decode vs
    draft-then-verify with the prompt-lookup draft source, same paged
    engine, same Poisson workload over cycle prompts.

    Two gates, one report:

    * IDENTITY (hard, off-pallas): greedy spec outputs must be
      bit-identical to plain decode — acceptance is exact argmax matching,
      so any divergence is an engine bug, never noise.  Exits non-zero.
    * DECODE-FORWARD REDUCTION (hard, deterministic): plain decode
      forwards / spec forwards must be >= 1.2x.  Every forward streams the
      same weights + KV once regardless of how many verify rows ride it
      (decode is memory-bound — the roofline the repo's cost model
      prices), so forwards saved IS the decode speed ratio on serving
      hardware; gating the deterministic counter instead of wall clock
      keeps the CI lane meaningful on shared CPU runners where the
      interpret backend's per-row cost is nothing like an accelerator's.

    Wall tok/s for both runs is reported and regression-gated against the
    committed baseline, not asserted inline."""
    from repro.serve.engine import Engine, EngineConfig

    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi))
    max_len = max(lengths) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_cycle_requests(Request, r_prompt, work, cfg.vocab_size)

    n_tok = sum(w["max_new"] for w in work)
    rows, outs, steps = [], {}, {}
    artifact = dict(
        bench="serve_spec", workload="poisson-cycle", arch=cfg.name,
        spec_k=args.spec_k, slots=args.slots, requests=args.requests,
        lengths=lengths, page_size=args.page_size, seed=args.seed)

    for name, kw in [("plain", {}), ("spec", dict(spec_k=args.spec_k))]:
        eng = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size, **kw))
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work, lat=lat)
        outs[name] = [r.out.tolist() for r in out]
        c = dict(eng.counters)
        steps[name] = c["decode_steps"]
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_decode_steps", c["decode_steps"],
                     f"decode_tokens={c['decode_tokens']}"))
        artifact[name] = dict(tok_per_s=round(tps, 2),
                              **latency_summary(work, lat),
                              engine_counters=c)

    sc = artifact["spec"]["engine_counters"]
    fwd_ratio = steps["plain"] / steps["spec"]
    acc_rate = sc["accepted"] / max(sc["drafted"], 1)
    acc_per_fwd = sc["accepted"] / max(steps["spec"], 1)
    match = outs["spec"] == outs["plain"]
    div = [_first_divergence(a, b)
           for a, b in zip(outs["spec"], outs["plain"], strict=True)]
    rows.append(("serve/spec_decode_fwd_reduction", fwd_ratio,
                 f"{steps['plain']} -> {steps['spec']} forwards"))
    rows.append(("serve/spec_accept_rate", acc_rate,
                 f"drafted={sc['drafted']}_accepted={sc['accepted']}"))
    rows.append(("serve/spec_accepted_per_forward", acc_per_fwd,
                 f"hist={sc['accept_len_hist']}"))
    rows.append(("serve/outputs_match", float(match), "plain+spec"))
    artifact.update(outputs_match=bool(match),
                    first_divergence_token=div,
                    decode_fwd_reduction=round(fwd_ratio, 3),
                    accept_rate=round(acc_rate, 3),
                    accepted_per_forward=round(acc_per_fwd, 3))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        bad = [i for i, d in enumerate(div) if d >= 0]
        print(f"ERROR: speculative greedy outputs diverged from plain "
              f"decode (requests {bad}, first token "
              f"{min(d for d in div if d >= 0)}) — greedy acceptance must "
              "be bit-identical", file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    if sc["drafted"] < 1:
        print("ERROR: the draft source never proposed — the workload is "
              "not exercising speculative decoding", file=sys.stderr)
        return 1
    if fwd_ratio < 1.2:
        print(f"ERROR: speculative decoding cut decode forwards only "
              f"{fwd_ratio:.2f}x (< 1.2x) on the lookup-friendly "
              "workload", file=sys.stderr)
        return 1
    return 0


def run_serve(router, requests, work, info=None):
    """Virtual-time driver for the ReplicaRouter (same event-driven core
    the asyncio server polls): submit each request at its arrival tick,
    client-cancel a stream after its workload item's ``cancel_after``-th
    token, and treat a RouterBusy rejection as final (the shed, not
    retried — the overload behavior the SLO phase measures).  ``info``
    (list of dicts, one per request) collects submit/first-token ticks,
    token counts, and terminal status."""
    from repro.serve.router import RouterBusy

    n = len(requests)
    if info is None:
        info = [dict() for _ in range(n)]
    for rec in info:
        rec.update(status=None, submit_tick=None, first_tick=None, tokens=0)
    grid2idx = {}
    i = 0
    while i < n or router.has_work:
        t = router.counters["ticks"]
        while i < n and work[i]["arrival"] <= t:
            try:
                grid2idx[router.submit(requests[i])] = i
                info[i]["submit_tick"] = t
            except RouterBusy:
                info[i]["status"] = "rejected"
            i += 1
        for e in router.poll():
            idx = grid2idx.get(e.rid)
            if idx is None:
                continue
            rec = info[idx]
            tick = router.counters["ticks"]
            if e.token is not None:
                if rec["first_tick"] is None:
                    rec["first_tick"] = tick
                rec["tokens"] += 1
                ca = work[idx]["cancel_after"]
                if ca is not None and rec["tokens"] >= ca and not e.final:
                    router.cancel(e.rid)
            if e.final:
                rec["status"] = e.finish_reason or "unknown"
    return info


def bench_serve(args, cfg, folded, Request):
    """--serve: asyncio server + SLO-aware replica router over the bursty
    chat workload, gated on token identity and on overload behavior.

    Three phases over ONE seeded trace:

      1. ``truth``     — a single Engine, ``generate()``: per-request full
         greedy outputs (the identity reference).
      2. ``unbounded`` — ReplicaRouter over ``--replicas`` engines with an
         effectively unbounded queue and no deadlines; client
         cancellations active.  Completed requests must be bit-identical
         to truth, cancelled ones truth-prefixes.  The same trace then
         replays through the asyncio AsyncServer (cancellations off) and
         must ALSO match truth — the server and this synchronous driver
         poll the identical event-driven core, so they cannot diverge.
      3. ``slo``       — same trace against a small ``--max-queue`` and a
         per-request ``deadline_tick`` (arrival + ``--slo-ticks``).  The
         gate (``slo_ok``) asserts overload surfaced as shed/rejected
         requests, survivors stayed token-identical, and the survivors'
         TTFT p95 in TICKS (deterministic, no wall-clock noise) is no
         worse than the unbounded run's — the router sheds the tail
         instead of growing it.
    """
    import asyncio

    from repro.serve import stats as stats_schema
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.router import ReplicaRouter, RouterConfig
    from repro.serve.server import AsyncServer

    r_arrival, _, r_prefix = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_bursty_workload(
        r_arrival, args.requests, lengths, args.rate,
        (args.max_new_lo, args.max_new_hi), burst=args.burst,
        prefix_len=args.prefix_len, cancel_frac=args.cancel_frac)
    prefix = r_prefix.integers(0, cfg.vocab_size,
                               (args.prefix_len,)).astype(np.int32)
    max_len = args.prefix_len + max(lengths) + args.max_new_hi + 1

    def fresh(deadline_ticks=None):
        _, r_prompt, _ = _rng_streams(args.seed)
        reqs = []
        for w in work:
            sfx = w["prompt_len"] - (args.prefix_len if w["chat"] else 0)
            suffix = r_prompt.integers(0, cfg.vocab_size,
                                       (sfx,)).astype(np.int32)
            reqs.append(Request(
                prompt=np.concatenate([prefix, suffix]) if w["chat"]
                else suffix,
                max_new_tokens=w["max_new"],
                deadline_tick=None if deadline_ticks is None
                else int(w["arrival"]) + deadline_ticks))
        return reqs

    ecfg = EngineConfig(batch_slots=args.slots, max_len=max_len,
                        cache_layout="paged", page_size=args.page_size)

    truth_eng = Engine(cfg, folded, ecfg)
    truth = [r.out.tolist() for r in truth_eng.generate(fresh())]

    replicas = [Engine(cfg, folded, ecfg) for _ in range(args.replicas)]

    def serve_run(*, deadline_ticks=None, max_queue=None):
        for e in replicas:
            e.reset(ecfg.seed)
        router = ReplicaRouter(replicas, RouterConfig(
            max_queue=max_queue or len(work) + 1))
        reqs = fresh(deadline_ticks)
        t0 = time.perf_counter()
        info = run_serve(router, reqs, work)
        secs = time.perf_counter() - t0
        stats_schema.validate_router_stats(router.stats())
        return router, reqs, info, secs

    def identity(reqs, info):
        for i, (r, rec) in enumerate(zip(reqs, info, strict=True)):
            if rec["status"] == "rejected":
                continue
            out = [] if r.out is None else r.out.tolist()
            full = rec["status"] in ("length", "eos")
            if out != (truth[i] if full else truth[i][:len(out)]):
                return False
        return True

    def ttft_p95(info):
        tt = [rec["first_tick"] - rec["submit_tick"] for rec in info
              if rec["first_tick"] is not None]
        return float(np.percentile(tt, 95)) if tt else 0.0

    def phase_summary(router, info, secs):
        by_status = {}
        for rec in info:
            by_status[rec["status"]] = by_status.get(rec["status"], 0) + 1
        return dict(
            tok_per_s=round(sum(r["tokens"] for r in info) / secs, 2),
            ttft_p95_ticks=round(ttft_p95(info), 2),
            statuses=by_status,
            router_counters=dict(router.counters),
            replicas=[dict(engine_counters=dict(e.counters))
                      for e in replicas])

    serve_run()                                        # warmup (compile)
    rt_u, reqs_u, info_u, secs_u = serve_run()         # timed, unbounded
    match_u = identity(reqs_u, info_u)
    cancelled_u = sum(1 for r in info_u if r["status"] == "cancelled")

    # asyncio replay: all submissions through the AsyncServer frontend
    async def async_replay():
        for e in replicas:
            e.reset(ecfg.seed)
        router = ReplicaRouter(replicas, RouterConfig(
            max_queue=len(work) + 1))
        srv = AsyncServer(router, max_inflight=len(work) + 1)
        task = asyncio.create_task(srv.serve_forever())
        handles = [await srv.submit(r) for r in fresh()]
        outs = [await h.tokens() for h in handles]
        srv.stop()
        await asyncio.sleep(0)
        task.cancel()
        return outs

    match_async = asyncio.run(async_replay()) == truth

    rt_s, reqs_s, info_s, _ = serve_run(deadline_ticks=args.slo_ticks,
                                        max_queue=args.max_queue)
    match_s = identity(reqs_s, info_s)
    shed = rt_s.counters["shed_deadline"] \
        + sum(r.counters["shed_deadline"] for r in replicas)
    rejected = rt_s.counters["rejected"]
    p95_u, p95_s = ttft_p95(info_u), ttft_p95(info_s)
    slo_ok = bool(shed + rejected >= 1 and match_s and p95_s <= p95_u)

    match = bool(match_u and match_async)
    n_tok = sum(r["tokens"] for r in info_u)
    rows = [
        ("serve/unbounded_tok_per_s", n_tok / secs_u,
         f"wall={secs_u:.2f}s_replicas={args.replicas}"),
        ("serve/unbounded_ttft_p95_ticks", p95_u,
         f"cancelled={cancelled_u}"),
        ("serve/slo_ttft_p95_ticks", p95_s,
         f"shed={shed}_rejected={rejected}"),
        ("serve/slo_shed_plus_rejected", shed + rejected,
         f"of {len(work)} requests"),
        ("serve/outputs_match", float(match), "truth+router+async"),
        ("serve/slo_ok", float(slo_ok),
         "shed>=1 & identity & p95_slo<=p95_unbounded"),
    ]
    artifact = dict(
        bench="serve_async", workload="bursty", arch=cfg.name,
        replicas=args.replicas, slots=args.slots, requests=args.requests,
        lengths=lengths, prefix_len=args.prefix_len, burst=args.burst,
        cancel_frac=args.cancel_frac, slo_ticks=args.slo_ticks,
        max_queue=args.max_queue, page_size=args.page_size, seed=args.seed,
        stats_schema_version=stats_schema.STATS_SCHEMA_VERSION,
        outputs_match=match, slo_ok=slo_ok,
        unbounded=phase_summary(rt_u, info_u, secs_u),
        slo=phase_summary(rt_s, info_s, 1.0))
    artifact["slo"].pop("tok_per_s")    # shed runs don't measure throughput

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        print("ERROR: serve outputs diverged from the single-engine truth "
              "(router or asyncio frontend changed tokens)", file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    if not slo_ok:
        print(f"ERROR: SLO phase failed its contract: shed+rejected="
              f"{shed + rejected} (need >=1), survivor identity={match_s}, "
              f"ttft_p95 slo={p95_s} vs unbounded={p95_u} (need <=)",
              file=sys.stderr)
        return 1
    return 0


def bench_affinity(args, cfg, folded, Request):
    """--affinity: prefix-affinity routing + shared-prefix-tier A/B over
    the multi-conversation chat workload, 2+ replicas, one seeded trace.

    Three phases:

      1. ``truth`` — single Engine ``generate()``: the identity reference.
      2. ``off``   — ReplicaRouter with ``affinity=False, shared_tier=
         False``: pure least-loaded placement scatters conversations, so
         replicas re-prefill prefixes their peers already hold.
      3. ``on``    — ``affinity=True, shared_tier=True``: turns stick to
         the replica holding their conversation's chain, and replicas
         adopt published chains instead of re-prefilling them.

    Two gates (both deterministic scheduling counters — wall-clock tok/s
    is deliberately absent from this artifact, so the CI gate cannot flake
    on runner noise):

      * IDENTITY (``outputs_match``): both routed runs must be
        bit-identical to truth off-pallas — affinity and adoption change
        placement and work, never tokens.
      * WORK (``affinity_ok``): the on-run's total prefill work
        (``prefill_tokens`` summed over replicas) must be STRICTLY below
        the off-run's, and at least one chain must flow through the tier
        (``published_pages`` > 0) — otherwise the A/B measured nothing.

    Per-replica ``suffix_prefills`` / ``shared_rows`` / ``prefix_hits``
    land in the artifact for the trajectory."""
    from repro.serve import stats as stats_schema
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.router import ReplicaRouter, RouterConfig

    r_arrival, _, r_prefix = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_affinity_workload(
        r_arrival, args.convs, args.turns, lengths, args.rate,
        (args.max_new_lo, args.max_new_hi))
    prefixes = [r_prefix.integers(0, cfg.vocab_size,
                                  (args.prefix_len,)).astype(np.int32)
                for _ in range(args.convs)]
    max_len = args.prefix_len + max(lengths) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return [Request(
            prompt=np.concatenate([
                prefixes[w["conv"]],
                r_prompt.integers(0, cfg.vocab_size,
                                  (w["suffix_len"],)).astype(np.int32)]),
            max_new_tokens=w["max_new"]) for w in work]

    ecfg = EngineConfig(batch_slots=args.slots, max_len=max_len,
                        cache_layout="paged", page_size=args.page_size)
    truth = [r.out.tolist() for r in Engine(cfg, folded, ecfg)
             .generate(fresh())]

    WORK_KEYS = ("prefill_tokens", "prefill_chunks", "suffix_prefills",
                 "prefix_hits", "shared_rows", "published_pages",
                 "adopted_pages")

    def phase(affinity, shared_tier):
        replicas = [Engine(cfg, folded, ecfg)
                    for _ in range(args.replicas)]
        router = ReplicaRouter(replicas, RouterConfig(
            max_queue=len(work) + 1, affinity=affinity,
            shared_tier=shared_tier))
        reqs = fresh()
        run_serve(router, reqs, work)
        s = stats_schema.validate_router_stats(router.stats())
        match = [r.out.tolist() for r in reqs] == truth
        totals = {k: sum(rep.counters[k] for rep in replicas)
                  for k in WORK_KEYS}
        return dict(
            outputs_match=bool(match),
            totals=totals,
            shared_tier_pages=s["shared_tier_pages"],
            router_counters=dict(router.counters),
            replicas=[dict(engine_counters=dict(rep.counters))
                      for rep in replicas])

    off = phase(affinity=False, shared_tier=False)
    on = phase(affinity=True, shared_tier=True)

    p_off = off["totals"]["prefill_tokens"]
    p_on = on["totals"]["prefill_tokens"]
    saved = 1.0 - p_on / max(p_off, 1)
    match = bool(off["outputs_match"] and on["outputs_match"])
    affinity_ok = bool(p_on < p_off
                       and on["totals"]["published_pages"] > 0)
    rows = [
        ("serve/affinity_off_prefill_tokens", p_off,
         f"suffix_prefills={off['totals']['suffix_prefills']}"),
        ("serve/affinity_on_prefill_tokens", p_on,
         f"suffix_prefills={on['totals']['suffix_prefills']}"),
        ("serve/affinity_prefill_saved_frac", saved,
         f"{p_off} -> {p_on} prompt rows"),
        ("serve/affinity_on_published_pages",
         on["totals"]["published_pages"],
         f"tier_pages={on['shared_tier_pages']}"),
        ("serve/affinity_on_adopted_pages", on["totals"]["adopted_pages"],
         f"prefix_hits={on['totals']['prefix_hits']}"),
        ("serve/affinity_hits", on["router_counters"]["affinity_hits"],
         f"misses={on['router_counters']['affinity_misses']}"),
        ("serve/outputs_match", float(match), "truth+off+on"),
        ("serve/affinity_ok", float(affinity_ok),
         "on_prefill<off_prefill & published>0"),
    ]
    artifact = dict(
        bench="serve_affinity", workload="multi-conv-chat", arch=cfg.name,
        replicas=args.replicas, slots=args.slots, convs=args.convs,
        turns=args.turns, lengths=lengths, prefix_len=args.prefix_len,
        page_size=args.page_size, seed=args.seed,
        stats_schema_version=stats_schema.STATS_SCHEMA_VERSION,
        outputs_match=match, affinity_ok=affinity_ok,
        prefill_tokens_off=p_off, prefill_tokens_on=p_on,
        prefill_saved_frac=round(saved, 3), off=off, on=on)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        print("ERROR: routed outputs diverged from the single-engine "
              "truth — affinity/tier placement changed tokens",
              file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    if not affinity_ok:
        print(f"ERROR: affinity A/B failed its contract: prefill_tokens "
              f"on={p_on} vs off={p_off} (need strictly lower), "
              f"published_pages={on['totals']['published_pages']} "
              f"(need > 0)", file=sys.stderr)
        return 1
    return 0


def bench(args):
    from repro.configs import smoke_config
    from repro.launch.serve import calibrated_folded
    from repro.serve.engine import (Engine, EngineConfig, LockstepEngine,
                                    Request)

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    folded = calibrated_folded(cfg, key, calib)

    if args.tp:
        return bench_tp(args, cfg, folded, Request)
    if args.kv_bits == 4:
        return bench_kv4(args, cfg, folded, Request)
    if args.spec_k:
        return bench_spec(args, cfg, folded, Request)
    if args.affinity:
        return bench_affinity(args, cfg, folded, Request)
    if args.serve or args.workload == "bursty":
        return bench_serve(args, cfg, folded, Request)
    if args.workload == "longprompt":
        return bench_chunked(args, cfg, folded, Request)
    if args.workload == "overload":
        return bench_overload(args, cfg, folded, Request)

    lengths = [int(x) for x in args.lengths.split(",")]
    prefix_len = args.prefix_len if args.workload == "prefix" else 0
    max_len = prefix_len + max(lengths) + args.max_new_hi + 1
    r_arrival, _, r_prefix = _rng_streams(args.seed)
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi),
                         prefix_len=prefix_len)
    prefix = (r_prefix.integers(0, cfg.vocab_size, (prefix_len,))
              .astype(np.int32) if prefix_len else None)

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size,
                              prefix=prefix)

    run_lock = args.layout in ("contiguous", "both")
    run_paged = args.layout in ("paged", "both")

    rows, artifact = [], dict(
        bench="serve_layouts", workload=args.workload, arch=cfg.name,
        slots=args.slots, requests=args.requests, lengths=lengths,
        prefix_len=prefix_len, page_size=args.page_size, seed=args.seed)
    n_tok = n_prompt = None
    outs = {}

    cont = Engine(cfg, folded, EngineConfig(
        batch_slots=args.slots, max_len=max_len, cache_layout="contiguous"))
    cont_lat = {}
    cont_out, cont_s = _timed(run_continuous, cont, fresh, work, lat=cont_lat)
    n_tok = sum(len(r.out) for r in cont_out)
    n_prompt = sum(len(r.prompt) for r in cont_out)
    cont_tps = n_tok / cont_s
    outs["contiguous"] = [r.out.tolist() for r in cont_out]
    # the dense layout reserves its whole footprint up front: page-equivalent
    # is slots x blocks-per-stripe, the number the paged pool competes with
    cont_pages = args.slots * -(-cont.smax // args.page_size)
    cont_sum = latency_summary(work, cont_lat)
    rows.append(("serve/continuous_tok_per_s", cont_tps,
                 f"wall={cont_s:.2f}s_gen={n_tok}_prompt={n_prompt}"))
    rows.append(("serve/continuous_ttft_p95_ms",
                 cont_sum.get("ttft_all_p95_ms", 0.0),
                 f"itl_p95={cont_sum['itl_p95_ms']}"))
    artifact.update(generated_tokens=n_tok, prompt_tokens=n_prompt,
                    continuous_tok_per_s=round(cont_tps, 2),
                    continuous_latency=cont_sum,
                    contiguous_page_equiv=cont_pages,
                    engine_counters=cont.counters)

    if run_lock:
        lock = LockstepEngine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len))
        lock_out, lock_s = _timed(run_lockstep, lock, fresh)
        lock_tps = n_tok / lock_s
        outs["lockstep"] = [r.out.tolist() for r in lock_out]
        rows.insert(0, ("serve/lockstep_tok_per_s", lock_tps,
                        f"wall={lock_s:.2f}s"))
        rows.append(("serve/continuous_speedup", cont_tps / lock_tps, ""))
        artifact.update(lockstep_tok_per_s=round(lock_tps, 2),
                        speedup=round(cont_tps / lock_tps, 3))

    if run_paged:
        paged = Engine(cfg, folded, EngineConfig(
            batch_slots=args.slots, max_len=max_len, cache_layout="paged",
            page_size=args.page_size))
        paged_lat = {}
        paged_out, paged_s = _timed(run_continuous, paged, fresh, work,
                                    lat=paged_lat)
        paged_tps = n_tok / paged_s
        outs["paged"] = [r.out.tolist() for r in paged_out]
        peak = paged.counters["cache_pages_peak"]
        paged_sum = latency_summary(work, paged_lat)
        rows.append(("serve/paged_tok_per_s", paged_tps,
                     f"wall={paged_s:.2f}s_prefix_hits="
                     f"{paged.counters['prefix_hits']}"))
        rows.append(("serve/paged_vs_contiguous_speedup",
                     paged_tps / cont_tps, ""))
        rows.append(("serve/paged_peak_pages", peak,
                     f"contiguous_equiv={cont_pages}"))
        rows.append(("serve/paged_ttft_p95_ms",
                     paged_sum.get("ttft_all_p95_ms", 0.0),
                     f"itl_p95={paged_sum['itl_p95_ms']}"))
        artifact.update(paged_tok_per_s=round(paged_tps, 2),
                        paged_vs_contiguous_speedup=round(paged_tps / cont_tps,
                                                          3),
                        paged_peak_pages=peak,
                        paged_latency=paged_sum,
                        paged_engine_counters=paged.counters)

    from repro.kernels import ops
    ref_outputs = outs["contiguous"]
    match = all(o == ref_outputs for o in outs.values())
    # bit-identity between engines/layouts is only guaranteed off the
    # compiled pallas backend (engine.py docstring): there prefill (q7
    # flash) and decode kernels may differ in the last LSB, flipping rare
    # argmax ties
    match_enforced = ops.backend() != "pallas"
    rows.append(("serve/outputs_match", float(match),
                 "+".join(sorted(outs))))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")

    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")
    if not match and match_enforced:
        print("ERROR: greedy outputs diverged between engines/layouts",
              file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(engines are not bit-identical there)", file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16,
                    help="request count (longprompt: SHORT request count)")
    ap.add_argument("--lengths", default="16,32,64,128,256",
                    help="comma-separated prompt (or suffix) length palette")
    ap.add_argument("--layout", default="both",
                    choices=["contiguous", "paged", "both"],
                    help="contiguous: lockstep-vs-continuous baseline; "
                         "paged: contiguous-vs-paged cache A/B; both: all")
    ap.add_argument("--workload", default="poisson",
                    choices=["poisson", "prefix", "longprompt", "overload",
                             "bursty"])
    ap.add_argument("--serve", action="store_true",
                    help="serving-stack bench: asyncio server + replica "
                         "router over the bursty workload (implied by "
                         "--workload bursty)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="engine replicas behind the router (--serve)")
    ap.add_argument("--burst", type=int, default=4,
                    help="requests arriving per burst (bursty workload)")
    ap.add_argument("--cancel-frac", type=float, default=0.25,
                    help="fraction of requests client-cancelled mid-stream "
                         "(bursty workload)")
    ap.add_argument("--affinity", action="store_true",
                    help="prefix-affinity + shared-tier A/B: router with "
                         "affinity/tier off vs on over the multi-"
                         "conversation chat workload (identity + strict "
                         "prefill-work reduction gated)")
    ap.add_argument("--convs", type=int, default=3,
                    help="conversations in the affinity workload")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per conversation (affinity workload)")
    ap.add_argument("--slo-ticks", type=int, default=24,
                    help="deadline_tick window after arrival for the SLO "
                         "phase (--serve)")
    ap.add_argument("--max-queue", type=int, default=4,
                    help="router queue bound for the SLO phase (--serve)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="starved-pool capacity for the overload workload "
                         "(0 = auto: one worst-case request + 1 page)")
    ap.add_argument("--prefix-len", type=int, default=96,
                    help="shared system-prompt length (prefix workload)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-bits", type=int, default=8, choices=[8, 4],
                    dest="kv_bits",
                    help="4: int8-vs-int4-packed KV pool A/B at the same "
                         "pool byte budget (plain + prefix workloads; "
                         "quality divergence reported, page headroom "
                         "gated at 1.5x)")
    ap.add_argument("--spec-k", type=int, default=0, dest="spec_k",
                    help="run the speculative-decoding A/B: plain greedy "
                         "vs draft-then-verify with up to K prompt-lookup "
                         "proposals per slot per tick (cycle-prompt "
                         "workload; identity + >=1.2x decode-forward "
                         "reduction gated)")
    ap.add_argument("--rate", type=float, default=0.25,
                    help="Poisson arrival rate (requests per engine tick)")
    ap.add_argument("--max-new-lo", type=int, default=8)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--n-long", type=int, default=2,
                    help="long prompts in the longprompt workload")
    ap.add_argument("--long-len", type=int, default=384,
                    help="long-prompt length (longprompt workload)")
    ap.add_argument("--max-batched-tokens", type=int, default=64,
                    help="per-tick token budget of the chunked run")
    ap.add_argument("--max-prefill-chunk", type=int, default=32,
                    help="per-slot prefill chunk cap of the chunked run")
    ap.add_argument("--tp", type=int, default=0,
                    help="run the sharded-vs-unsharded TP A/B at this "
                         "model-parallel degree (needs that many devices; "
                         "CPU: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--seed", type=int, default=0,
                    help="single seed for arrivals, prompts, and prefix")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_*.json artifact here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (fast on 2 CPU cores)")
    args = ap.parse_args()
    if args.smoke:
        # 5 requests (was 6): the overload lane rides in the same CI wall
        # budget, paid for by trimming every workload's request count
        args.requests = min(args.requests, 5)
        args.lengths = "8,16" if args.workload != "prefix" else "4,8"
        args.prefix_len = min(args.prefix_len, 48)
        args.max_new_lo, args.max_new_hi = 4, 8
        args.n_long = min(args.n_long, 2)
        args.long_len = min(args.long_len, 192)
        args.page_size = min(args.page_size, 8)
        # budget fits the largest short prompt + decode slots + the
        # head-of-line page reservation in one tick
        args.max_batched_tokens = min(args.max_batched_tokens, 32)
        args.max_prefill_chunk = min(args.max_prefill_chunk, 16)
        if args.workload == "overload":
            # burst arrivals + decode-heavy budgets: the starved pool must
            # see real concurrency or nothing gets preempted
            args.rate = max(args.rate, 1.0)
            args.max_new_lo, args.max_new_hi = 8, 16
        if args.spec_k:
            # decode-heavy budgets: prompt-lookup needs enough decode
            # ticks for the greedy cycles it feeds on to establish
            args.rate = max(args.rate, 1.0)
            args.max_new_lo, args.max_new_hi = 12, 20
        if args.serve or args.workload == "bursty":
            # the SLO phase must actually overload the router: more
            # requests than the trimmed default, tight slots, fast bursts
            args.requests = max(args.requests, 8)
            args.slots = min(args.slots, 2)
            args.rate = max(args.rate, 1.0)
            args.prefix_len = min(args.prefix_len, 16)
        if args.affinity:
            # prefixes must dominate the prompt (that's the work the A/B
            # measures) and bursts must interleave conversations
            args.slots = min(args.slots, 2)
            args.rate = max(args.rate, 1.0)
            args.convs = min(args.convs, 3)
            args.turns = min(args.turns, 3)
    raise SystemExit(bench(args))


if __name__ == "__main__":
    main()
