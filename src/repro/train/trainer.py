"""Training loop: data -> jitted step -> metrics -> periodic checkpoints,
with crash-resume (exactly-once data) and elastic-mesh restore.

Straggler mitigation at scale (documented design + hooks): the loop is
synchronous-SPMD inside a pod; across pods the grad-accumulation schedule
lets the DCN all-reduce of microbatch k overlap microbatch k+1's compute.
Node failure handling is restart-from-checkpoint (checkpoint.py is atomic
and resharding-tolerant); the ``watchdog_s`` knob aborts a hung step so the
job supervisor can reschedule — the standard large-fleet pattern.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import make_source
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train import steps as St
from repro.sharding import partition as Pt


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    accum_steps: int = 1
    seed: int = 0
    watchdog_s: float = 0.0     # 0 = off; else abort a step that exceeds this
    keep_ckpts: int = 3


def train(cfg: ModelConfig, shape: ShapeConfig, mesh, opt_cfg: AdamWConfig,
          tcfg: TrainerConfig, *, fsdp: bool = True,
          log_fn: Callable[[int, Dict], None] | None = None):
    source = make_source(cfg, shape, seed=tcfg.seed)
    batch0 = source.batch_at(0)
    batch_spec = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch0)
    Pt.set_mesh_ctx(mesh)
    try:
        jitted, state_shard, batch_shard = St.jit_train_step(
            cfg, mesh, opt_cfg, batch_spec, fsdp=fsdp,
            accum_steps=tcfg.accum_steps)

        start_step = 0
        state = None
        if tcfg.ckpt_dir:
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is not None:
                shape_tree = jax.eval_shape(
                    lambda k: St.init_train_state(cfg, k, opt_cfg),
                    jax.random.PRNGKey(tcfg.seed))
                state, meta = ckpt.restore(tcfg.ckpt_dir, last, shape_tree,
                                           state_shard)
                start_step = int(meta.get("data_step", last))
        if state is None:
            init = jax.jit(
                lambda k: St.init_train_state(cfg, k, opt_cfg),
                out_shardings=state_shard)
            state = init(jax.random.PRNGKey(tcfg.seed))

        history = []
        for step in range(start_step, tcfg.steps):
            batch = jax.tree.map(
                lambda a: jax.device_put(a),
                source.batch_at(step))
            t0 = time.time()
            state, metrics = jitted(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            if tcfg.watchdog_s and dt > tcfg.watchdog_s:
                raise TimeoutError(
                    f"step {step} took {dt:.1f}s > watchdog {tcfg.watchdog_s}s")
            metrics["step_s"] = dt
            history.append(metrics)
            if log_fn and (step % tcfg.log_every == 0 or step == tcfg.steps - 1):
                log_fn(step, metrics)
            if tcfg.ckpt_dir and ((step + 1) % tcfg.ckpt_every == 0
                                  or step == tcfg.steps - 1):
                ckpt.save(tcfg.ckpt_dir, step + 1, state,
                          meta={"data_step": step + 1, "arch": cfg.name})
                ckpt.gc_old(tcfg.ckpt_dir, tcfg.keep_ckpts)
        return state, history
    finally:
        Pt.set_mesh_ctx(None)
