"""Sharded, atomic, resharding-tolerant checkpoints.

Layout (one directory per step):

    <dir>/step_000120.tmp-<nonce>/   <- written first
        manifest.json                 (pytree structure, shapes, dtypes, meta)
        shard_00000.npz ...           (one npz per host, leaf-chunked)
    <dir>/step_000120/               <- atomic rename AFTER fsync

Fault-tolerance contract:
  * a crash mid-write leaves only .tmp dirs -> ``latest_step`` ignores them;
    restart resumes from the last complete checkpoint (exactly-once via the
    data-offset stored in meta).
  * ``restore`` takes target ShapeDtypeStructs + shardings and re-shards on
    load, so a job may resume on a DIFFERENT mesh (elastic resize) or a
    different host count.
  * integrity: per-leaf crc32 recorded in the manifest and verified on load.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import uuid
import zlib
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

_LEAF_KEY = "leaf_{:05d}"


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, v in flat:
        parts = []
        for k in kp:
            parts.append(str(getattr(k, "key", getattr(k, "idx",
                                                       getattr(k, "name", "")))))
        out.append(("/".join(parts), v))
    return out


def save(ckpt_dir: str | Path, step: int, tree, meta: Optional[Dict] = None):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:06d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir()
    leaves = _paths(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": []}
    arrays = {}
    for i, (path, v) in enumerate(leaves):
        arr = np.asarray(jax.device_get(v))
        key = _LEAF_KEY.format(i)
        arrays[key] = arr
        manifest["leaves"].append({
            "path": path, "key": key, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "crc32": zlib.crc32(arr.tobytes()),
        })
    np.savez(tmp / "shard_00000.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # fsync the directory contents before the atomic publish
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    final = ckpt_dir / f"step_{step:06d}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, target_tree,
            shardings=None, *, strict_crc: bool = True):
    """Load into the structure of ``target_tree`` (ShapeDtypeStructs ok),
    placing leaves with ``shardings`` (same pytree shape) when given —
    this is what makes elastic-mesh resume work."""
    d = Path(ckpt_dir) / f"step_{step:06d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "shard_00000.npz")
    by_path = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if strict_crc and zlib.crc32(arr.tobytes()) != leaf["crc32"]:
            raise IOError(f"checkpoint corruption at {leaf['path']}")
        by_path[leaf["path"]] = arr

    tgt = _paths(target_tree)
    shd = _paths(shardings)[:] if shardings is not None else None
    out = []
    for i, (path, v) in enumerate(tgt):
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = by_path[path].astype(v.dtype) if hasattr(v, "dtype") else by_path[path]
        if shd is not None:
            out.append(jax.device_put(arr, shd[i][1]))
        else:
            out.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target_tree)
    return treedef.unflatten(out), manifest["meta"]


def gc_old(ckpt_dir: str | Path, keep: int = 3):
    """Delete all but the newest ``keep`` complete checkpoints + tmp litter."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    done = sorted([p for p in ckpt_dir.iterdir()
                   if re.fullmatch(r"step_\d+", p.name)])
    for p in done[:-keep] if keep else done:
        shutil.rmtree(p)
    for p in ckpt_dir.iterdir():
        if ".tmp-" in p.name:
            shutil.rmtree(p)
