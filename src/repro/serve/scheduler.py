"""Slot-table scheduler + paged KV-cache block allocator.

The decode graph is compiled once for a fixed number of slots; this module
owns the bookkeeping that lets requests stream through that fixed shape:
a FIFO waiting queue, a slot table, admission of waiting requests into free
slots, and eviction on completion.  It is deliberately model-agnostic — the
engine owns prefill/decode; the scheduler only decides *who sits where* and,
since the token-budget step loop, *how much prefill runs per tick*: a seated
request no longer prefills whole at admission but carries a ``prefill_pos``
cursor that ``next_chunk`` advances in page-aligned chunks, co-scheduled with
the tick's decoding slots under ``max_batched_tokens``.

``BlockAllocator`` extends "where" from slots to cache memory: instead of an
exclusive ``Smax`` stripe per slot, the paged engine draws fixed-size KV
pages from one global pool.  The allocator keeps a free list, per-page
refcounts, and a prefix registry keyed by the page's *cumulative* token
prefix (K/V rows depend on every earlier token, so content identity is the
whole prefix, not the page's own tokens).  Pages whose refcount drops to
zero but that are still registered stay cached (their pool content is
intact) on an LRU list and are reclaimed only under allocation pressure —
so a repeated system prompt keeps hitting even after its first request
finished.  Shared pages are mapped copy-on-write: sharers only ever read
them; a writer must own the page exclusively (``ensure_exclusive``), which
the engine guarantees structurally by sharing only whole pages strictly
before the first position it will write.

With ``reserve="ondemand"`` the scheduler stops reserving a request's full
decode budget at admission: only the prompt's pages are taken up front and
decode slots request their next page when the write cursor crosses a page
boundary (``grow``).  The pool can therefore run dry mid-request; the
engine resolves that by preempting a victim (``pick_victim`` +
``preempt``) instead of stalling.  Spill registers the victim's fully
written pages in the prefix registry before dropping its references, so a
restore that re-admits before allocation pressure reclaims them turns the
lost work back into a prefix-cache hit and replays only the tail.

Tensor parallelism does not appear in this module by design: the engine
shards the pool over KV heads, never over pages, so a page id names the
same logical page on every rank and ONE allocator/scheduler instance on
the host is the single authority for all of them.  Every decision here —
admission reservations, ``grow`` grants, victim choice, spill
registration, LRU reclaim — is a pure function of tokens, page ids, and
refcounts (all rank-agnostic), which is the invariant that makes a
sharded engine's scheduling trace, counters, and greedy tokens
bit-identical to the unsharded engine's.  Spill/restore consequently
never moves cache data across ranks: registration records page ids +
tokens, and replay recomputes each rank's own head slice locally.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, List, Optional, Sequence, Tuple

from repro.serve.prefix import RegistryPrefixStore

TRASH_PAGE = 0   # inactive slots' block tables point here; never allocated


def pages_needed(rows: int, page_size: int) -> int:
    return -(-rows // page_size)


class BlockAllocator:
    """Fixed-size KV page pool: free list, refcounts, prefix reuse.

    Page 0 is reserved as the trash page — zeroed block-table entries of
    inactive slots alias it, so a full-table decode step can harmlessly
    scatter its garbage rows somewhere that no live request reads.
    """

    def __init__(self, n_pages: int, page_size: int,
                 bytes_per_page: Optional[int] = None):
        assert n_pages >= 2 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        # HBM bytes one pool page occupies across every rep/slot leaf —
        # payload AND per-page scales for the packed (kv_bits=4) layout,
        # where a page holds ~half the bytes of the int8 layout.  Purely
        # observational (pool sizing / benchmarks); allocation stays
        # page-granular, so refcounts, CoW, and spill/restore move packed
        # payloads and their scales together by construction (a page id
        # names both).
        self.bytes_per_page = bytes_per_page
        self.free: Deque[int] = collections.deque(range(1, n_pages))
        self.ref: List[int] = [0] * n_pages
        # the chained-prefix registry, as a first-class PrefixStore (see
        # repro.serve.prefix): the allocator owns refcounts and reclaim
        # POLICY; the store owns key->page bindings and the LRU of
        # refcount-0 registered pages.  Everything outside the allocator
        # (scheduler refresh, router affinity probes, the shared tier's
        # adoption path) programs against ``self.prefix``; the ref-taking
        # wrappers below are the only way references move.
        self.prefix = RegistryPrefixStore(page_size)
        self.live = 0                         # pages with refcount > 0
        self.peak_live = 0

    # --- capacity -------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the trash page)."""
        return self.n_pages - 1

    @property
    def pool_bytes(self) -> Optional[int]:
        """Total allocatable-pool HBM bytes (None when the engine never
        told the allocator its page byte size)."""
        if self.bytes_per_page is None:
            return None
        return self.capacity * self.bytes_per_page

    def available(self) -> int:
        return len(self.free) + self.prefix.lru_count

    def can_alloc(self, n: int) -> bool:
        return n <= self.available()

    # --- allocation -----------------------------------------------------

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` exclusive pages (refcount 1), reclaiming LRU cached
        pages if the free list runs short.  None if the pool can't cover
        the request — the caller waits, it never partially allocates."""
        if not self.can_alloc(n):
            return None
        pages = []
        for _ in range(n):
            if self.free:
                p = self.free.popleft()
            else:
                p = self.prefix.pop_reclaim()  # oldest cached page
                assert p is not None, "can_alloc said yes but pool is dry"
            self.ref[p] = 1
            pages.append(p)
        self._bump_live(n)
        return pages

    def free_pages(self, pages: Sequence[int]):
        """Drop one reference per page; refcount-0 pages return to the free
        list, unless registered — those stay cached for prefix reuse."""
        for p in pages:
            assert self.ref[p] > 0, f"double free of page {p}"
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self.live -= 1
                if self.prefix.is_registered(p):
                    self.prefix.park(p)
                else:
                    self.free.append(p)

    def _bump_live(self, n: int):
        self.live += n
        self.peak_live = max(self.peak_live, self.live)

    # --- prefix sharing -------------------------------------------------
    # The chained-key content addressing and the registry itself live in
    # ``self.prefix`` (repro.serve.prefix.RegistryPrefixStore).  The two
    # wrappers below are the ref-counting boundary: ``match_prefix`` takes
    # a reference per matched page, ``register_prefix`` applies the
    # strictly-before-last-token trim.  Read-only probes (router affinity,
    # the engine's adoption path) call ``self.prefix.match`` directly.

    @property
    def registry_version(self) -> int:
        """Bumped on every registration (the refresh_prefix memo key)."""
        return self.prefix.version

    def match_prefix(self, tokens: Sequence[int], max_pages: int) -> List[int]:
        """Longest chain of registered pages covering full-page prefixes of
        ``tokens`` (at most ``max_pages``).  Matched pages get a reference;
        release with ``free_pages`` if the reservation is abandoned."""
        pages = list(self.prefix.match(tokens, max_pages).pages)
        for p in pages:
            if self.ref[p] == 0:           # revive a cached (LRU) page
                self.prefix.revive(p)
                self._bump_live(1)
            self.ref[p] += 1
        return pages

    def register_prefix(self, tokens: Sequence[int], pages: Sequence[int]):
        """Publish a prompt's full pages for reuse.  Only pages strictly
        before the last prompt token are registered — at least one token
        must run through the model so admission has next-token logits, and
        the page the first write lands in must stay exclusive (COW
        discipline without ever copying)."""
        n = min((len(tokens) - 1) // self.page_size, len(pages))
        self.prefix.register(tokens[:n * self.page_size], pages[:n])

    def ensure_exclusive(self, pages: List[int], idx: int
                         ) -> Tuple[int, Optional[int]]:
        """Copy-on-write: make ``pages[idx]`` safe to overwrite.  Returns
        ``(page, copy_src)`` — ``copy_src`` is the old page whose rows must
        be copied into the fresh page when the original was shared (or
        registered, i.e. passively shareable), else None.

        The caller KEEPS its reference on ``copy_src`` until the row copy
        is done and must then drop it with ``free_pages([copy_src])``.
        Releasing it here instead (as this method once did) is a
        use-after-free: a refcount-1 registered source parks on the LRU the
        moment it is freed, and any allocation before the copy — including
        the very ``alloc`` that serves a concurrent slot — may reclaim and
        overwrite it.  The paged engine only ever writes pages it allocated
        exclusively, so today this is a no-op assert; the hook carries the
        semantics preemption/swap code inherits."""
        p = pages[idx]
        if self.ref[p] == 1 and not self.prefix.is_registered(p):
            return p, None
        fresh = self.alloc(1)
        if fresh is None:
            raise RuntimeError("pool exhausted during copy-on-write")
        pages[idx] = fresh[0]
        return fresh[0], p

    @property
    def cached_pages(self) -> int:
        return self.prefix.cached_count

    @property
    def free_list_pages(self) -> int:
        """Pages on the free list proper (excludes LRU-cached pages)."""
        return len(self.free)

    @property
    def lru_pages(self) -> int:
        """Refcount-0 registered pages parked on the LRU (reclaimable)."""
        return self.prefix.lru_count

    # --- debug ----------------------------------------------------------

    def check_invariants(self):
        """Assert the pool's structural invariants (O(n_pages); called from
        ``Engine.stats()`` so every per-tick stats assertion sweeps the
        allocator too, and hammered by the property tests):

        * the trash page is never referenced, freed, cached, or registered,
        * no page sits on the free list and the LRU at once,
        * refcounts are nonnegative and ``live`` counts exactly the pages
          with refcount > 0,
        * live + LRU + free partitions the allocatable pool,
        * the PrefixStore boundary holds: the registry and its page->key
          inverse agree (the store's own sweep), every registered page is
          a valid pool page, every LRU page is a refcount-0 registered
          page, and no free-list page is registered.
        """
        self.prefix.check_invariants()     # registry-internal bijection
        free = set(self.free)
        lru = set(self.prefix.lru_pages)
        assert len(free) == len(self.free), "free list holds duplicates"
        assert TRASH_PAGE not in free and TRASH_PAGE not in lru and \
            not self.prefix.is_registered(TRASH_PAGE) and \
            self.ref[TRASH_PAGE] == 0, "trash page leaked into the pool"
        assert not free & lru, f"pages on free AND lru: {free & lru}"
        assert all(r >= 0 for r in self.ref), f"negative refcount: {self.ref}"
        held = {p for p in range(self.n_pages) if self.ref[p] > 0}
        assert self.live == len(held), (self.live, held)
        assert not held & free and not held & lru, \
            "referenced page on free list or LRU"
        assert self.live + len(lru) + len(free) == self.n_pages - 1, \
            (self.live, len(lru), len(free), self.n_pages)
        registered = {p for p in range(self.n_pages)
                      if self.prefix.is_registered(p)}
        assert all(0 < p < self.n_pages for p in registered), \
            f"registered page outside the pool: {registered}"
        for p in lru:
            assert self.ref[p] == 0, \
                f"LRU page {p} not a refcount-0 registered page"
        assert not free & registered, \
            f"registered page on free list: {free & registered}"


@dataclasses.dataclass
class SlotState:
    """One occupied slot of the decode batch."""
    rid: int
    request: object                 # the engine's Request
    pos: int = 0                    # next cache write position for this slot
    last_token: int = 0             # token to feed at the next decode step
    emitted: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    shared_rows: int = 0            # prompt rows mapped from cached pages
    prefill_pos: int = 0            # prompt rows already in the cache
    chunks_done: int = 0            # prefill chunk forwards run so far
    refresh_seen: int = -1          # registry version last re-matched against
    starved_ticks: int = 0          # consecutive ticks prefilling w/o a chunk
    tokens: Optional[List[int]] = None   # replay sequence after a decode
    #                                      preemption (prompt + emitted);
    #                                      set by preempt, cleared at the
    #                                      replay's handoff — only valid
    #                                      while the slot prefills
    spilled_rows: int = 0           # cache rows held when last preempted
    hwm_rows: int = 0               # furthest row ever computed (across
    #                                 spills): replay below it = recompute
    preemptions: int = 0            # times this request was spilled

    def prompt_tokens(self):
        """The token sequence prefill must cover.  Normally the request
        prompt; after a DECODE preemption it is prompt + every token
        emitted so far — the spilled KV rows are regenerated by replaying
        them (greedy decode is deterministic, so the replay is
        bit-identical and the final chunk's logits emit the next new
        token, never a repeat)."""
        return self.request.prompt if self.tokens is None else self.tokens

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens())

    @property
    def prefilling(self) -> bool:
        """True until the whole prompt is in the cache; the slot joins the
        decode batch only after its final prefill chunk hands off."""
        return self.prefill_pos < self.prompt_len


class Scheduler:
    def __init__(self, n_slots: int,
                 allocator: Optional[BlockAllocator] = None,
                 rows_fn: Optional[Callable[[object, int], int]] = None,
                 max_batched_tokens: Optional[int] = None,
                 max_prefill_chunk: Optional[int] = None,
                 reserve: str = "full"):
        assert n_slots >= 1
        assert reserve in ("full", "ondemand"), reserve
        assert reserve == "full" or allocator is not None, \
            "on-demand page growth needs the paged allocator"
        self.n_slots = n_slots
        self.allocator = allocator
        # "full": admission reserves prompt + decode budget, decode can
        # never OOM.  "ondemand": admission reserves only the prompt's
        # pages; decode pages are granted by ``grow`` at page-boundary
        # crossings and exhaustion is resolved by preemption, not refusal.
        self.reserve = reserve
        # rows_fn(request, shared_rows) -> cache rows to reserve (the engine
        # knows about prefill bucketing; the scheduler stays model-agnostic)
        self.rows_fn = rows_fn or (
            lambda req, shared: len(req.prompt) + req.max_new_tokens - 1)
        # per-tick budget policy: max_batched_tokens caps prefill-chunk
        # tokens + decode tokens per tick; max_prefill_chunk caps one slot's
        # chunk.  Both None -> a seated request prefills whole in one chunk
        # (the pre-chunking one-shot behavior through the unified loop).
        ps = allocator.page_size if allocator is not None else 1
        if max_prefill_chunk is not None:
            assert allocator is not None, \
                "chunked prefill needs the paged allocator (page-aligned " \
                "chunks); the contiguous layout prefills in one chunk"
            assert max_prefill_chunk >= ps and max_prefill_chunk % ps == 0, \
                (max_prefill_chunk, ps)
        if max_batched_tokens is not None:
            assert max_batched_tokens >= 1
        self.max_batched_tokens = max_batched_tokens
        self.max_prefill_chunk = max_prefill_chunk
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self.waiting: Deque[Tuple[int, object]] = collections.deque()
        self._next_rid = 0

    # --- queue side -----------------------------------------------------

    def submit(self, request) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append((rid, request))
        return rid

    def remove_waiting(self, rid: int):
        """Drop ``rid`` from the waiting queue (cancellation / deadline
        shed).  Returns the queued item — the plain request, or the
        preempted SlotState if it was requeued by ``preempt`` (its pages
        were already freed at spill time) — or None if not queued."""
        for i, (r, item) in enumerate(self.waiting):
            if r == rid:
                del self.waiting[i]
                return item
        return None

    # --- slot side ------------------------------------------------------

    def _reserve(self, st: SlotState) -> bool:
        """Map shared prefix pages and allocate the exclusive tail.  False
        when the pool can't cover the request — admission stalls (FIFO is
        preserved: later, smaller requests do NOT jump the queue).  Under
        ``reserve="full"`` the tail covers the whole decode budget
        (``rows_fn``); under ``"ondemand"`` only the prompt rows — the
        prefill scatter writes whole pages, so ``pages_needed(len)`` is
        exactly what the chunk forwards touch."""
        al = self.allocator
        ps = al.page_size
        prompt = [int(t) for t in st.prompt_tokens()]
        shared = al.match_prefix(prompt, (len(prompt) - 1) // ps)
        shared_rows = len(shared) * ps
        rows = (len(prompt) if self.reserve == "ondemand" else
                self.rows_fn(st.request, shared_rows))
        need = max(0, pages_needed(rows, ps) - len(shared))
        excl = al.alloc(need)
        if excl is None:
            al.free_pages(shared)          # abandon the speculative mapping
            return False
        st.pages = shared + excl
        st.shared_rows = shared_rows
        st.prefill_pos = shared_rows       # the cursor skips mapped rows
        return True

    def admit(self, limit: Optional[int] = None
              ) -> List[Tuple[int, SlotState]]:
        """Seat waiting requests in free slots (FIFO).  Returns the new
        (slot index, state) pairs; the engine prefills them and fills in
        ``pos`` / ``last_token``.  With a BlockAllocator, admission also
        reserves the request's KV pages (shared prefix + an exclusive tail
        covering the whole decode budget under ``reserve="full"``, or just
        the prompt under ``"ondemand"``) — a head-of-line request that
        doesn't fit stalls the queue.  A preempted SlotState requeued by
        ``preempt`` sits at the queue front and is re-seated as-is: its
        replay sequence re-matches the prefix registry, so spilled pages
        that survived on the LRU come back as cache hits."""
        placed = []
        for b in range(self.n_slots):
            if limit is not None and len(placed) >= limit:
                break
            if self.slots[b] is not None or not self.waiting:
                continue
            rid, item = self.waiting[0]
            st = item if isinstance(item, SlotState) else \
                SlotState(rid=rid, request=item)
            if self.allocator is not None and not self._reserve(st):
                break                       # out of pages: wait, keep FIFO
            self.waiting.popleft()
            self.slots[b] = st
            placed.append((b, st))
        return placed

    def evict(self, b: int) -> SlotState:
        st = self.slots[b]
        assert st is not None, f"evicting empty slot {b}"
        self.slots[b] = None
        if self.allocator is not None and st.pages:
            # tail first: registered refcount-0 pages enter the LRU in free
            # order and reclaim pops oldest, so freeing the chain HEAD
            # first would make the next allocation break the registry chain
            # at page 0 and strand the rest unmatchable — reversed, reclaim
            # consumes the tail and a usable prefix survives longest
            self.allocator.free_pages(st.pages[::-1])
        return st

    # --- on-demand growth + preemption ----------------------------------

    def grow(self, st: SlotState, rows: int) -> Optional[int]:
        """Extend ``st``'s page chain to cover ``rows`` cache rows (the
        on-demand decode path calls this just before the write cursor
        enters a page it doesn't own; the speculative verify path calls
        it with a multi-row budget — cursor + 1 + k proposals — so one
        tick's accepted tokens all land in owned pages).  Returns the
        number of pages newly allocated (0 when the chain already covers
        ``rows``), or None when the pool came up empty — the engine then
        preempts a victim and retries; the chain is never partially
        grown.  A speculative over-reservation (proposals rejected) is
        harmless: the extra pages sit past the cursor inside the slot's
        max_len ceiling and the next real token reuses them."""
        al = self.allocator
        need = pages_needed(rows, al.page_size) - len(st.pages)
        if need <= 0:
            return 0
        got = al.alloc(need)
        if got is None:
            return None
        st.pages.extend(got)
        return need

    def pick_victim(self, exclude: frozenset = frozenset()
                    ) -> Optional[int]:
        """The slot to spill under pool pressure, or None if no candidate.

        Policy: the LAST-admitted prefilling slot first (least sunk cost —
        its unfinished pages are pure loss anyway and its restore is the
        cheap chunk-replay path), then the decoding slot with the most
        decode budget remaining (it would hold pages hostage longest;
        ties break youngest).  When more than one candidate exists the
        oldest (lowest-rid) seated request is never chosen — combined with
        requeue-at-front restores this keeps the head of line progressing,
        so every request eventually finishes under sustained overload."""
        cands = [(b, st) for b, st in enumerate(self.slots)
                 if st is not None and b not in exclude]
        if not cands:
            return None
        if len(cands) > 1:
            head = min(st.rid for _, st in cands)
            cands = [(b, st) for b, st in cands if st.rid != head]
        pre = [(st.rid, b) for b, st in cands if st.prefilling]
        if pre:
            return max(pre)[1]
        dec = [(st.request.max_new_tokens - len(st.emitted), st.rid, b)
               for b, st in cands]
        return max(dec)[2]

    def preempt(self, b: int) -> SlotState:
        """Spill slot ``b``'s pages and requeue it at the FRONT of the
        waiting queue (it outranks everything submitted after it).

        The victim's fully written pages — up to the last page boundary
        under its write cursor — are registered in the prefix registry
        BEFORE its references drop, so they park on the LRU instead of the
        free list; if allocation pressure hasn't reclaimed them by
        re-admission, ``_reserve``/``refresh_prefix`` revive them as a
        prefix hit and the replay prefills only the lost tail.  A decoding
        victim folds its emitted tokens into the replay sequence
        (``SlotState.prompt_tokens``): greedy replay regenerates the
        identical KV rows and the handoff logits continue exactly where
        the victim stopped.  The partial page past the boundary is
        unregistered and returns to the free list — those rows are the
        recompute cost the engine accounts."""
        st = self.slots[b]
        assert st is not None, f"preempting empty slot {b}"
        al = self.allocator
        ps = al.page_size
        if st.prefilling:
            cached = st.prefill_pos        # page-aligned mid-prefill
        else:
            cached = st.pos                # decode wrote rows [0, pos)
            st.tokens = [int(t) for t in st.request.prompt] + \
                [int(t) for t in st.emitted]
        boundary = (cached // ps) * ps
        if boundary:
            al.register_prefix([int(t) for t in st.prompt_tokens()],
                               st.pages[:boundary // ps])
        al.free_pages(st.pages[::-1])  # tail first — see evict()
        self.slots[b] = None
        st.pages = []
        st.shared_rows = 0
        st.prefill_pos = 0
        st.chunks_done = 0
        st.refresh_seen = -1
        st.starved_ticks = 0
        st.pos = 0
        st.spilled_rows = cached
        st.hwm_rows = max(st.hwm_rows, cached)
        st.preemptions += 1
        self.waiting.appendleft((st.rid, st))
        return st

    # --- chunked prefill planning ---------------------------------------

    def refresh_prefix(self, st: SlotState) -> int:
        """Re-match ``st``'s prompt against the prefix registry just before
        its FIRST chunk runs.  Registration happens at prefill completion,
        so a request admitted in the same tick as (or mid-prefill of) an
        identical prompt misses at admission but hits here — the hit can
        land mid-chunk, skipping rows the chunk grid would otherwise cover.
        Adopted pages replace the exclusive pages reserved for the same
        rows (those go back to the pool); returns rows newly shared.
        Memoized on the registry version: a budget-starved slot polled
        every chunk of every tick only re-hashes its prompt after a
        registration actually changed what it could match."""
        al = self.allocator
        if al is None or st.chunks_done or not st.prefilling:
            return 0
        if st.refresh_seen == al.registry_version:
            return 0
        st.refresh_seen = al.registry_version
        ps = al.page_size
        prompt = [int(t) for t in st.prompt_tokens()]
        matched = al.match_prefix(prompt, (len(prompt) - 1) // ps)
        new_rows = len(matched) * ps
        if new_rows <= st.shared_rows:
            al.free_pages(matched)         # nothing longer than we hold
            return 0
        # the registry chain is stable while we hold refs, so matched[:k]
        # are the pages already mapped at admission: dropping one ref per
        # replaced entry nets out for those and frees the exclusives
        replaced = st.pages[:len(matched)]
        st.pages = matched + st.pages[len(matched):]
        al.free_pages(replaced)
        gained = new_rows - st.shared_rows
        st.shared_rows = new_rows
        st.prefill_pos = new_rows
        return gained

    def next_chunk(self, n_decode_active: int, used_tokens: int,
                   exclude: frozenset = frozenset()
                   ) -> Optional[Tuple[int, SlotState, int, int]]:
        """The next prefill chunk to run this tick: ``(slot, state, pos0,
        n_tokens)`` — or None when the budget is spent or nothing prefills.

        Policy: with a chunk policy active (either knob set), prefilling
        slots are served shortest-remaining-first (rid breaks ties), so a
        short prompt arriving while a long one is mid-prefill reaches its
        first token after ONE chunk instead of queueing behind the whole
        long prefill — the TTFT tail chunking exists to bound.  Two
        anti-starvation guards protect the head-of-line (lowest-rid)
        prefilling slot from a steady stream of short arrivals: while the
        tick's starting budget covers at least two pages, every other slot
        leaves one page of the REMAINING budget for the unserved head (so
        later short picks cannot eat the reserved page); and a head that
        got no chunk for two consecutive ticks preempts the SJF order
        outright — under any budget the head advances at least one page
        every third tick.  With no policy (one-shot mode) slots prefill
        whole in FIFO order — the pre-chunking admission behavior,
        preserved as the A/B baseline.  A chunk is ``min(remaining,
        max_prefill_chunk, budget left)`` rounded DOWN to whole pages
        unless it finishes the prompt (the ragged last chunk).
        ``max_batched_tokens`` is shared with the tick's decode tokens
        (``n_decode_active`` + chunk tokens already ``used_tokens`` this
        tick).  When the budget leaves no whole page but nothing else runs
        this tick, one page is forced so prefill always makes progress."""
        pre = [(b, st) for b, st in enumerate(self.slots)
               if st is not None and st.prefilling and b not in exclude]
        if not pre:
            return None
        ps = self.allocator.page_size if self.allocator is not None else 1
        budget = (None if self.max_batched_tokens is None else
                  self.max_batched_tokens - n_decode_active - used_tokens)
        # refresh before ordering: an adopted prefix shrinks `remaining`
        for _, st in pre:
            if st.chunks_done == 0:
                self.refresh_prefix(st)
        chunked_mode = self.max_batched_tokens is not None or \
            self.max_prefill_chunk is not None
        if chunked_mode:
            pre.sort(key=lambda e: (e[1].prompt_len - e[1].prefill_pos,
                                    e[1].rid))
        else:
            pre.sort(key=lambda e: e[1].rid)
        # the head-of-line slot is the oldest PREFILLING slot, whether or
        # not it already chunked this tick (exclude) — its reservation only
        # lifts once it has actually been served
        all_pre = [st for st in self.slots
                   if st is not None and st.prefilling]
        head_rid = min(st.rid for st in all_pre)
        head_waiting = any(st.rid == head_rid for _, st in pre)
        tick_budget = (None if self.max_batched_tokens is None else
                       self.max_batched_tokens - n_decode_active)
        if chunked_mode and head_waiting:
            head = next(st for _, st in pre if st.rid == head_rid)
            if head.starved_ticks >= 2:
                # bounded starvation: a head that got nothing for two ticks
                # (the tight-budget regime where the reservation is off)
                # preempts the SJF order for this pick
                pre.sort(key=lambda e: e[1].rid != head_rid)
        for b, st in pre:
            remaining = st.prompt_len - st.prefill_pos
            take = remaining
            if self.max_prefill_chunk is not None:
                take = min(take, self.max_prefill_chunk)
            if budget is not None:
                # reserve one page of the REMAINING budget for the unserved
                # head — gated on the tick-START budget covering head +
                # someone else, so a one-page budget doesn't invert into
                # the head starving every shorter prompt instead
                reserve = ps if (st.rid != head_rid and head_waiting
                                 and tick_budget >= 2 * ps) else 0
                take = min(take, max(budget - reserve, 0))
            if take < remaining:
                take = (take // ps) * ps   # mid-prompt chunks: whole pages
            if take <= 0 and st.rid == head_rid and st.starved_ticks >= 2:
                # the override must FORCE a chunk, not just reorder: when
                # the budget net of decode stays under a page for many
                # ticks (slots decoding long budgets), reordering alone
                # would stall the head for the decode's whole lifetime.
                # Overshoots the budget by at most one page, like the
                # final-chunk handoff token.
                take = min(remaining, ps)
            if take > 0:
                return b, st, st.prefill_pos, take
        if used_tokens or n_decode_active:
            return None                    # budget went to real work
        b, st = pre[0]                     # forced progress: empty tick
        return b, st, st.prefill_pos, min(st.prompt_len - st.prefill_pos, ps)

    # --- queries --------------------------------------------------------

    @property
    def active(self) -> List[int]:
        return [b for b, st in enumerate(self.slots) if st is not None]

    @property
    def decoding(self) -> List[int]:
        """Slots whose whole prompt is cached — the tick's decode batch."""
        return [b for b, st in enumerate(self.slots)
                if st is not None and not st.prefilling]

    @property
    def prefilling(self) -> List[int]:
        return [b for b, st in enumerate(self.slots)
                if st is not None and st.prefilling]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(st is not None for st in self.slots)

    @property
    def n_free(self) -> int:
        return sum(st is None for st in self.slots)
