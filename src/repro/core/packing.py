"""int4 nibble packing — the storage format the accelerator streams from HBM.

The paper's 7.94x compression comes from 4-bit weights in off-chip memory.
On TPU the analogous win is HBM bytes: we pack two signed 4-bit codes per
int8 lane.  Two layouts are provided:

* ``pack_int4`` — adjacent-pair layout: codes (..., 2i) and (..., 2i+1) share a
  byte.  Natural for storage; unpack interleaves.
* ``pack_int4_planar`` — nibble-planar layout: the LOW nibbles of the first
  half of the axis and HIGH nibbles of the second half.  This is the Type-A
  BIM trick from the paper (Fig. 4): "using shift logic at adder tree's output
  can save more resources, though this need to rearrange the input data".
  On TPU the rearrangement means unpacking produces two CONTIGUOUS int8 tiles
  (no interleave shuffle), which lowers to cheap vector ops in Pallas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.boundary import kernel_boundary


def pack_int4(codes: jax.Array, axis: int = -1) -> jax.Array:
    """Pack signed int4 codes (stored in int8, range [-8,7]) two per uint8.

    codes.shape[axis] must be even; the packed axis has half the length.
    Byte layout: low nibble = even index, high nibble = odd index.
    """
    axis = axis % codes.ndim
    assert codes.shape[axis] % 2 == 0, "pack axis must be even-sized"
    lo = jnp.take(codes, jnp.arange(0, codes.shape[axis], 2), axis=axis)
    hi = jnp.take(codes, jnp.arange(1, codes.shape[axis], 2), axis=axis)
    lo_u = lo.astype(jnp.uint8) & 0xF
    hi_u = (hi.astype(jnp.uint8) & 0xF) << 4
    return lo_u | hi_u


def unpack_int4(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of pack_int4: uint8 -> sign-extended int8 codes, axis doubled."""
    axis = axis % packed.ndim
    lo = _sign_extend_nibble(packed & 0xF)
    hi = _sign_extend_nibble((packed >> 4) & 0xF)
    stacked = jnp.stack([lo, hi], axis=axis + 1)  # (..., n, 2, ...)
    new_shape = list(packed.shape)
    new_shape[axis] *= 2
    return stacked.reshape(new_shape)


def pack_int4_planar(codes: jax.Array, axis: int = 0) -> jax.Array:
    """Nibble-planar pack: first half of ``axis`` -> low nibbles, second half
    -> high nibbles (Type-A BIM data rearrangement)."""
    axis = axis % codes.ndim
    n = codes.shape[axis]
    assert n % 2 == 0
    first, second = jnp.split(codes, 2, axis=axis)
    lo_u = first.astype(jnp.uint8) & 0xF
    hi_u = (second.astype(jnp.uint8) & 0xF) << 4
    return lo_u | hi_u


def unpack_int4_planar(packed: jax.Array, axis: int = 0) -> jax.Array:
    """Inverse of pack_int4_planar: concatenates the two nibble planes."""
    lo = _sign_extend_nibble(packed & 0xF)
    hi = _sign_extend_nibble((packed >> 4) & 0xF)
    return jnp.concatenate([lo, hi], axis=axis % packed.ndim)


def _sign_extend_nibble(u4: jax.Array) -> jax.Array:
    """uint8 holding a nibble in [0,15] -> signed int8 in [-8,7].

    Branch-free: (x ^ 8) - 8 maps 0..7 -> 0..7 and 8..15 -> -8..-1.
    """
    x = u4.astype(jnp.int8)
    return (x ^ jnp.int8(8)) - jnp.int8(8)


def packed_nbytes(shape, axis: int = -1) -> int:
    """Bytes of the packed representation of an int4 tensor of ``shape``."""
    n = 1
    for i, d in enumerate(shape):
        n *= d // 2 if i == axis % len(shape) else d
    return n


# --- int4-packed KV pages (shared scale per page) ------------------------------
#
# The serving KV pool stores int8 codes; at ``kv_bits=4`` each page is
# re-quantized to signed 4-bit codes under ONE shared fp32 scale per page
# and nibble-packed planar along the head dim (pages hold half the bytes).
# The helpers below define the quantize/dequantize contract that the write
# path (models/serve_int.py), the fused-dequant attention kernels, and the
# bit-exact oracles (kernels/ref.py) all share: any drift between them
# breaks the kernel-vs-oracle equality tests.

KV4_QMAX = 7  # symmetric int4 target range: codes in [-7, 7] (+-8 unused
              # by the scale so dequant round-trips the extremes exactly)


def kv_page_scale(codes_i8: jax.Array) -> jax.Array:
    """Shared dequant scale for one page of int8 KV codes.

    ``max(amax(|codes|), 1) / 7`` — the ``max(.., 1)`` keeps an all-zero
    page (trash page, never-written tail rows) at a well-defined scale
    instead of dividing by zero.  fp32 scalar.
    """
    amax = jnp.max(jnp.abs(codes_i8.astype(jnp.int32)))
    return jnp.maximum(amax, 1).astype(jnp.float32) / KV4_QMAX


def quantize_kv_page(codes_i8: jax.Array, scale: jax.Array,
                     axis: int = -1) -> jax.Array:
    """int8 KV codes -> planar nibble-packed uint8 under a shared scale.

    ``c4 = clip(round(c8 / scale), -8, 7)``, then ``pack_int4_planar`` along
    ``axis`` (the head dim for the KV pool) — the packed axis halves.
    """
    c4 = jnp.clip(jnp.round(codes_i8.astype(jnp.float32) / scale), -8, 7)
    return pack_int4_planar(c4.astype(jnp.int8), axis=axis)


def dequant_int4_codes(c4_i8: jax.Array, scale: jax.Array) -> jax.Array:
    """int4 codes (in int8 storage) -> int8 codes: clip(round(c4*scale)).

    THE dequant formula: the Pallas kernels fuse exactly this (sign-extend,
    fp32 multiply by the page scale, round, clip) into their inner loop.
    """
    y = jnp.round(c4_i8.astype(jnp.float32) * scale)
    return jnp.clip(y, -127, 127).astype(jnp.int8)


def dequantize_kv_page(packed_u8: jax.Array, scale: jax.Array,
                       axis: int = -1) -> jax.Array:
    """Inverse of ``quantize_kv_page`` (lossy at 4 bits): unpack + dequant."""
    return dequant_int4_codes(unpack_int4_planar(packed_u8, axis=axis), scale)


@kernel_boundary(why="whole-pool int4 dequant for the bit-exact jnp "
                     "oracles; the Pallas kernels do this per tile in VMEM")
def dequantize_kv_pool(packed_pool_u8: jax.Array,
                       page_scales: jax.Array) -> jax.Array:
    """Whole-pool dequant: (n_pages, P, Hkv, hd//2) uint8 + (n_pages,) fp32
    -> (n_pages, P, Hkv, hd) int8.  Used by the jnp fallback paths and the
    kernel oracles — NOT by the Pallas kernels, which dequantize per tile
    in VMEM and never materialize this view.  Registered as a kernel
    boundary: the pool-scale float cast inside is the audited exemption."""
    c4 = unpack_int4_planar(packed_pool_u8, axis=-1)
    return dequant_int4_codes(c4, page_scales[:, None, None, None])
