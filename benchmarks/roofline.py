"""Roofline analysis from the dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), TPU v5e constants:

    compute    = dot_FLOPs        / (chips * 197e12 FLOP/s)
    memory     = hbm_bytes        / (chips * 819e9  B/s)
    collective = collective_bytes / (chips * 4 links * 50e9 B/s)

Inputs are the **loop-aware** costs stored by the dry-run
(``benchmarks/hlo_cost.py``: every while-body's costs scaled by its
``known_trip_count``), so scan-over-layers and grad-accumulation are fully
counted — unlike raw ``cost_analysis()``, which counts loop bodies once
(measured discrepancy ~100x on 32-layer models; see EXPERIMENTS.md §Roofline
notes).  dot_flops/collective bytes are exact per the partitioned HLO; the
HBM term uses CPU-backend fusion granularity and over-estimates TPU traffic
(fusion on TPU merges more elementwise chains) — treat it as an upper bound.

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

# per-chip ceilings — single source of truth, drift-tested
from repro.kernels.hw_constants import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS  # noqa: E402
RESULTS = ROOT / "results" / "dryrun"
OUT = ROOT / "results" / "roofline"


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_params_estimate()
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * n_active * toks
    return 2.0 * n_active * toks


def analyze_record(rec: dict, cfg, shape):
    chips = rec["chips"]
    hc = rec.get("hlo_cost")
    if not hc:
        return None
    flops_dev = hc["dot_flops"]
    bytes_dev = hc["hbm_bytes"]
    coll_dev = hc["collective_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / (ICI_LINKS * ICI_BW)
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * chips
    bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_bound_s": bound,
        "compute_fraction": t_compute / max(bound, 1e-30),
        "mfu_bound": mf / (chips * PEAK_FLOPS) / max(bound, 1e-30),
        "hbm_gb_per_dev": (rec["memory"]["temp_bytes"]
                           + rec["memory"]["argument_bytes"]) / 2**30,
        "collective_breakdown": {k: v["bytes"] for k, v in
                                 hc["collectives"].items()},
        "tag": rec.get("tag", ""),
    }


def load_all(pattern="*.json"):
    from repro.configs.base import SHAPES, get_config

    rows = []
    for f in sorted(RESULTS.glob(pattern)):
        try:
            rec = json.loads(f.read_text())
        except json.JSONDecodeError:
            continue
        if not rec.get("ok"):
            continue
        cfg = get_config(rec["arch"])
        r = analyze_record(rec, cfg, SHAPES[rec["shape"]])
        if r:
            rows.append(r)
    return rows


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    rows = [r for r in load_all() if not r["tag"]]
    (OUT / "roofline.json").write_text(json.dumps(rows, indent=1))
    lines = ["| arch | shape | t_comp | t_mem* | t_coll | bound | MFU-bound |"
             " MODEL/HLO | HBM GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant']} | {r['mfu_bound']:.3f} | "
            f"{r['useful_ratio']:.2f} | {r['hbm_gb_per_dev']:.1f} |")
    (OUT / "roofline.md").write_text("\n".join(lines) + "\n")
    print("\n".join(lines))


if __name__ == "__main__":
    main()
