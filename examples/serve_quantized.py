"""Batched serving example: calibrate, fold to integers, generate with the
engine (quantized KV cache, greedy + temperature sampling).

    PYTHONPATH=src python examples/serve_quantized.py --arch mixtral-8x22b
"""
import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import calibrated_folded
from repro.serve.engine import Engine, Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="yi-6b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = smoke_config(args.arch)
key = jax.random.PRNGKey(0)
calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
folded = calibrated_folded(cfg, key, calib)
eng = Engine(cfg, folded, batch_slots=args.batch, max_len=128)
rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
                max_new_tokens=args.max_new) for _ in range(args.batch)]
for i, r in enumerate(eng.generate(reqs)):
    print(f"req{i}: prompt={r.prompt[:6].tolist()}.. -> {r.out.tolist()}")
