"""First-class prefix-registry subsystem: the ``PrefixStore`` protocol.

Until PR 10 the chained-prefix registry was private ``BlockAllocator``
state: three dicts (``_cached`` / ``_key_of`` / ``_lru``) nobody outside
the allocator could program against, which made cross-replica sharing
impossible — the router could not ask "who already holds this prompt's
pages?" and a replica had no way to export a chain it had paid to
prefill.  This module promotes the registry to an API:

* :func:`chain_keys` — the content-addressed chained hash walk, the ONE
  definition shared by the in-allocator registry, the shared tier, and
  the router's affinity probe (``key_i = hash((key_{i-1}, page_i
  tokens))``; a page's identity is its *cumulative* prefix because K/V
  rows depend on every earlier token).
* :class:`PrefixChain` / :class:`SealedChain` — frozen value types: a
  chain of per-page keys + token segments, either bound to local pool
  page ids (``PrefixChain``) or carrying host-memory page payloads
  (``SealedChain`` — the publishable form).
* :class:`PrefixStore` — the typed protocol (``match / register / seal /
  publish / adopt``) both implementations speak.
* :class:`RegistryPrefixStore` — the default implementation: the
  allocator-owned registry, extracted.  ``BlockAllocator`` keeps the
  refcount/free-list machinery and composes one of these; the scheduler's
  ``refresh_prefix`` and spill-time registration reach the registry only
  through the allocator's thin ref-counting wrappers over this store.
* :class:`SharedPrefixTier` — a host-memory, read-only-to-consumers tier
  replicas publish sealed chains into and adopt pages from.  Adoption
  installs byte-identical page payloads into the adopter's pool and
  registers the chain locally, so downstream it is an ordinary prefix
  hit — greedy outputs stay bit-identical to a cold-registry replica
  because the adopted int8/int4 rows (and per-page scales) are exact
  copies of what the adopter would have computed itself.

Store ``match`` is READ-ONLY in both implementations: no references are
taken and no LRU state moves.  Reference counting stays where refcounts
live — ``BlockAllocator.match_prefix`` wraps ``RegistryPrefixStore
.match`` and takes the refs.  That split is what lets the router probe
every replica's registry for affinity without perturbing pool state.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterator, Mapping, Optional, Protocol, Sequence, \
    Tuple

import numpy as np


def chain_keys(tokens: Sequence[int], page_size: int, n_pages: int
               ) -> Iterator[Tuple[int, Tuple[int, ...]]]:
    """Yield ``(key, segment)`` for the first ``n_pages`` full pages of
    ``tokens``: ``key_i = hash((key_{i-1}, page_i tokens))``.  The chained
    hash gives cumulative-prefix identity in O(page_size) per page instead
    of re-hashing the whole prefix (O(L^2) over a prompt).  Lookups verify
    the page's own segment against the stored one, and the parent key is
    verified inductively by the walk, so a false hit needs a 64-bit hash
    collision AND an identical current segment."""
    key = 0
    for i in range(n_pages):
        seg = tuple(tokens[i * page_size:(i + 1) * page_size])
        key = hash((key, seg))
        yield key, seg


@dataclasses.dataclass(frozen=True)
class PrefixChain:
    """A matched/registered run of prefix pages: per-page chained keys,
    per-page token segments, and (when bound to a pool) the local page
    ids.  Frozen — stores hand these out as values, never as views into
    their internal state."""
    page_size: int
    keys: Tuple[int, ...]
    segs: Tuple[Tuple[int, ...], ...]
    pages: Tuple[int, ...] = ()           # local pool page ids; () if unbound

    @property
    def n_pages(self) -> int:
        return len(self.keys)

    @property
    def rows(self) -> int:
        return self.n_pages * self.page_size

    def tokens(self) -> list:
        """The chain's full token prefix (concatenated segments)."""
        return [t for seg in self.segs for t in seg]


@dataclasses.dataclass(frozen=True)
class SealedChain:
    """A publishable chain: keys + segments + one host array per cache
    leaf holding the chain's page payloads stacked along the pool's page
    axis (axis 1 — every paged-pool leaf, int8/int4 payload and per-page
    scale alike, is ``(n_reps, n_pages, ...)``).  ``payload[leaf][:, j]``
    is page ``j``'s slice; a page id names payload AND scales together,
    so kv4 scales travel with their pages by construction."""
    page_size: int
    keys: Tuple[int, ...]
    segs: Tuple[Tuple[int, ...], ...]
    payload: Mapping[str, np.ndarray]

    @property
    def n_pages(self) -> int:
        return len(self.keys)

    def slice(self, lo: int, hi: int) -> "SealedChain":
        """Pages ``[lo, hi)`` as a new SealedChain (payloads sliced along
        the page axis).  Used by adopters that already hold a head of the
        chain locally and only install the tail."""
        return SealedChain(
            page_size=self.page_size, keys=self.keys[lo:hi],
            segs=self.segs[lo:hi],
            payload={k: v[:, lo:hi] for k, v in self.payload.items()})


class PrefixStore(Protocol):
    """What every prefix store speaks.  ``match``/``seal`` are read-only;
    ``register`` binds a key chain to local pool pages; ``publish`` /
    ``adopt`` move payload-backed (sealed) chains.  An implementation
    without one capability returns the lawful empty result (0 pages
    stored / None) rather than raising — callers probe capabilities by
    outcome, not by type."""

    page_size: int
    version: int        # bumped on every successful register/publish

    def match(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> PrefixChain:
        """Longest held chain covering full-page prefixes of ``tokens``
        (at most ``max_pages``).  Read-only: takes no references, moves
        no LRU state."""
        ...

    def register(self, tokens: Sequence[int],
                 pages: Sequence[int]) -> int:
        """Bind the key chain of ``tokens`` to local pool ``pages``;
        returns the number of pages newly recorded (already-known keys
        and already-bound pages are skipped)."""
        ...

    def seal(self, tokens: Sequence[int],
             max_pages: Optional[int] = None) -> PrefixChain:
        """Snapshot the longest held chain for publication (same shape as
        ``match``; named separately because sealing is the publish-side
        contract: the returned chain's pages must stay byte-stable until
        the caller has extracted their payloads)."""
        ...

    def publish(self, sealed: SealedChain) -> int:
        """Store a payload-backed chain; returns pages newly stored."""
        ...

    def adopt(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> Optional[SealedChain]:
        """Longest payload-backed chain covering ``tokens``, ready to
        install into a pool — or None when nothing (or no payloads) are
        held."""
        ...


class RegistryPrefixStore:
    """The default ``PrefixStore``: the in-allocator chained-prefix
    registry, extracted.  Holds key->(page, segment), its page->key
    inverse, and the LRU of refcount-0 registered pages.  Reference
    counting and reclaim POLICY stay in ``BlockAllocator`` — the
    allocator drives this store through the narrow park/revive/reclaim
    surface below, and the invariant sweep runs on both sides of that
    boundary."""

    def __init__(self, page_size: int):
        assert page_size >= 1
        self.page_size = page_size
        self.version = 0
        self._cached: Dict[int, Tuple[int, tuple]] = {}
        self._key_of: Dict[int, int] = {}
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()

    # --- PrefixStore protocol -------------------------------------------

    def match(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> PrefixChain:
        n = len(tokens) // self.page_size
        if max_pages is not None:
            n = min(n, max_pages)
        keys, segs, pages = [], [], []
        for key, seg in chain_keys(tokens, self.page_size, n):
            hit = self._cached.get(key)
            if hit is None or hit[1] != seg:
                break
            keys.append(key)
            segs.append(seg)
            pages.append(hit[0])
        return PrefixChain(self.page_size, tuple(keys), tuple(segs),
                           tuple(pages))

    def register(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        n = min(len(tokens) // self.page_size, len(pages))
        new = 0
        for (key, seg), p in zip(chain_keys(tokens, self.page_size, n),
                                 pages, strict=False):
            if key in self._cached or p in self._key_of:
                continue       # identical content already published
            self._cached[key] = (p, seg)
            self._key_of[p] = key
            self.version += 1
            new += 1
        return new

    def seal(self, tokens: Sequence[int],
             max_pages: Optional[int] = None) -> PrefixChain:
        return self.match(tokens, max_pages)

    def publish(self, sealed: SealedChain) -> int:  # noqa: ARG002 - protocol law
        return 0    # local pool pages ARE this store's storage

    def adopt(self, tokens: Sequence[int],  # noqa: ARG002 - protocol law
              max_pages: Optional[int] = None) -> Optional[SealedChain]:
        return None  # no host payloads behind a pool-bound registry

    # --- allocator-side surface (refcount integration) ------------------

    def is_registered(self, page: int) -> bool:
        return page in self._key_of

    def park(self, page: int):
        """A registered page's refcount hit 0: park it on the LRU (its
        pool content stays intact and matchable until reclaimed)."""
        self._lru[page] = None

    def revive(self, page: int):
        """A parked page was matched again: lift it off the LRU."""
        self._lru.pop(page, None)

    def pop_reclaim(self) -> Optional[int]:
        """Reclaim the oldest parked page for reuse: forget its registry
        entry and return the page id (None when nothing is parked)."""
        if not self._lru:
            return None
        p, _ = self._lru.popitem(last=False)
        del self._cached[self._key_of.pop(p)]
        return p

    @property
    def cached_count(self) -> int:
        return len(self._cached)

    @property
    def lru_count(self) -> int:
        return len(self._lru)

    @property
    def lru_pages(self) -> frozenset:
        return frozenset(self._lru)

    def check_invariants(self):
        """Registry-internal invariants (the allocator's sweep extends
        these across the refcount boundary): the key map and its
        page->key inverse are a bijection, and every LRU page is
        registered."""
        assert len(self._cached) == len(self._key_of)
        for key, (p, _seg) in self._cached.items():
            assert self._key_of.get(p) == key, \
                f"registry desync on page {p}"
        for p in self._lru:
            assert p in self._key_of, f"LRU page {p} not registered"


class SharedPrefixTier:
    """Cross-replica host-memory prefix tier (a ``PrefixStore`` whose
    pages are numpy payloads instead of pool page ids).

    Replicas publish sealed chains after a prefill completes; any replica
    can then ``adopt`` the longest matching chain and install the payload
    bytes into its own pool.  The tier is read-only to consumers — pages
    are immutable once published (a chain key names immutable content, so
    there is nothing to update) — and single-writer-at-a-time by the
    engines' synchronous tick discipline.

    Capacity is bounded: at most ``max_pages`` page payloads, evicted in
    LRU order (publish and adopt both refresh recency of the keys they
    touch).  Evicting a chain's head key strands its tail entries until
    they age out themselves — bounded waste, never a correctness issue,
    because adoption walks from key 0 and stops at the first miss."""

    def __init__(self, page_size: int, max_pages: int = 256):
        assert page_size >= 1 and max_pages >= 1
        self.page_size = page_size
        self.max_pages = max_pages
        self.version = 0
        # key -> (segment, {leaf: (n_reps, 1, ...) payload slice})
        self._entries: "collections.OrderedDict[int, Tuple[tuple, dict]]" \
            = collections.OrderedDict()

    # --- PrefixStore protocol -------------------------------------------

    def match(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> PrefixChain:
        n = len(tokens) // self.page_size
        if max_pages is not None:
            n = min(n, max_pages)
        keys, segs = [], []
        for key, seg in chain_keys(tokens, self.page_size, n):
            hit = self._entries.get(key)
            if hit is None or hit[0] != seg:
                break
            keys.append(key)
            segs.append(seg)
        return PrefixChain(self.page_size, tuple(keys), tuple(segs))

    def register(self, tokens: Sequence[int],  # noqa: ARG002 - protocol law
                 pages: Sequence[int]) -> int:
        return 0    # no pool behind the tier; chains arrive via publish

    def seal(self, tokens: Sequence[int],
             max_pages: Optional[int] = None) -> PrefixChain:
        return self.match(tokens, max_pages)

    def publish(self, sealed: SealedChain) -> int:
        """Insert the sealed chain's pages (skipping keys already held),
        newest-recency, evicting LRU pages past ``max_pages``."""
        if sealed.page_size != self.page_size:
            raise ValueError(
                f"sealed chain page_size={sealed.page_size} does not match "
                f"tier page_size={self.page_size}")
        new = 0
        for j, (key, seg) in enumerate(zip(sealed.keys, sealed.segs,
                                           strict=True)):
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            page_payload = {leaf: np.ascontiguousarray(arr[:, j:j + 1])
                            for leaf, arr in sealed.payload.items()}
            self._entries[key] = (seg, page_payload)
            self.version += 1
            new += 1
        while len(self._entries) > self.max_pages:
            self._entries.popitem(last=False)
        return new

    def adopt(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> Optional[SealedChain]:
        n = len(tokens) // self.page_size
        if max_pages is not None:
            n = min(n, max_pages)
        keys, segs, pages = [], [], []
        for key, seg in chain_keys(tokens, self.page_size, n):
            hit = self._entries.get(key)
            if hit is None or hit[0] != seg:
                break
            keys.append(key)
            segs.append(seg)
            pages.append(hit[1])
        if not keys:
            return None
        for key in keys:
            self._entries.move_to_end(key)     # adopt refreshes recency
        payload = {leaf: np.concatenate([pp[leaf] for pp in pages], axis=1)
                   for leaf in pages[0]}
        return SealedChain(self.page_size, tuple(keys), tuple(segs),
                           payload)

    # --- observability ---------------------------------------------------

    @property
    def n_pages(self) -> int:
        """Page payloads currently held (the ``shared_tier_pages`` router
        gauge)."""
        return len(self._entries)

    def check_invariants(self):
        assert len(self._entries) <= self.max_pages
        leaf_sets = {frozenset(pp) for _seg, pp in self._entries.values()}
        assert len(leaf_sets) <= 1, \
            "tier entries disagree on cache leaf structure"
        for _seg, pp in self._entries.values():
            for leaf, arr in pp.items():
                assert arr.ndim >= 2 and arr.shape[1] == 1, \
                    f"tier payload leaf {leaf} not a single page slice"
