"""Serving throughput: cache layouts (paged vs contiguous) and engines
(continuous vs lockstep) over the same folded integer model.

Workloads (``--workload``):

  * ``poisson`` — N requests from a Poisson arrival process, prompt lengths
    mixed over a palette (16-256 tokens by default), per-request decode
    budgets.
  * ``prefix`` — the millions-of-users shape: every request shares one long
    system prompt (``--prefix-len``) followed by a short unique suffix drawn
    from the length palette.  The paged engine's block-table allocator maps
    the shared prefix pages copy-on-write, so repeated prompts skip both the
    prefill compute and the pages.

Engines/layouts (``--layout``):

  * ``contiguous`` — lockstep baseline vs the continuous engine on the dense
    per-slot cache (the pre-paging A/B).
  * ``paged``      — continuous engine, contiguous vs PAGED cache layout:
    same requests, same greedy tokens, different cache addressing.
  * ``both``       — all three (default).

Greedy outputs must be identical per request across every engine/layout off
the compiled pallas backend — layouts change throughput and memory, not
tokens; the bench exits non-zero on a mismatch.  Prints ``name,value,
derived`` CSV; ``--json`` also writes a BENCH_PR.json artifact (tokens/s per
engine, peak cache pages, prefix-reuse stats) for the CI perf trajectory.

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json BENCH_PR.json
    PYTHONPATH=src python benchmarks/serve_bench.py --workload prefix --layout paged
    PYTHONPATH=src python benchmarks/serve_bench.py --arch yi-6b --requests 24
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def make_workload(rng, n_requests, lengths, rate, max_new_range,
                  prefix_len=0):
    """Poisson arrivals: exponential interarrival gaps (unit = decode steps),
    uniform prompt-length palette, uniform decode budgets.  With
    ``prefix_len`` the palette lengths become suffixes after one shared
    system prompt."""
    t = 0.0
    work = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        work.append(dict(
            arrival=t,
            prompt_len=prefix_len + int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
        ))
    return work


def build_requests(Request, rng, work, vocab, prefix=None):
    reqs = []
    for w in work:
        suffix_len = w["prompt_len"] - (len(prefix) if prefix is not None
                                        else 0)
        suffix = rng.integers(0, vocab, (suffix_len,)).astype(np.int32)
        prompt = suffix if prefix is None else np.concatenate([prefix, suffix])
        reqs.append(Request(prompt=prompt, max_new_tokens=w["max_new"]))
    return reqs


def run_lockstep(eng, requests):
    """Static batching: same-length groups (correct per-request outputs),
    each group decoded to its longest budget.  The engine is reset between
    groups — recurrent-state archs (mamba/xLSTM) would otherwise leak the
    previous group's SSM state into the next prefill (attention rows are
    position-masked; SSM state is not)."""
    by_len = {}
    for r in requests:
        by_len.setdefault(len(r.prompt), []).append(r)
    for group in by_len.values():
        for i in range(0, len(group), eng.batch):
            eng.reset()
            eng.generate(group[i:i + eng.batch])
    return requests


def run_continuous(eng, requests, work):
    """Requests arrive over virtual time (1 tick = one decode step of the
    engine) following the workload's Poisson process and are submitted when
    due; the clock fast-forwards over idle gaps so lulls cost no wall time.
    Same completion set as the lockstep baseline, different admission
    dynamics."""
    i = 0
    n = len(requests)
    while i < n or eng.sched.has_work:
        t = eng.stats["decode_steps"]
        while i < n and work[i]["arrival"] <= t:
            eng.submit(requests[i])
            i += 1
        if not eng.sched.has_work and i < n:
            eng.submit(requests[i])     # idle: jump to the next arrival
            i += 1
        eng.step()
    return requests


def _timed(runner, eng, fresh, *extra):
    """Warmup pass (compilation) then a timed pass on fresh state."""
    runner(eng, fresh(), *extra)
    eng.reset()
    t0 = time.perf_counter()
    out = runner(eng, fresh(), *extra)
    return out, time.perf_counter() - t0


def bench(args):
    from repro.configs import smoke_config
    from repro.launch.serve import calibrated_folded
    from repro.serve.engine import Engine, LockstepEngine, Request

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    folded = calibrated_folded(cfg, key, calib)

    lengths = [int(x) for x in args.lengths.split(",")]
    prefix_len = args.prefix_len if args.workload == "prefix" else 0
    max_len = prefix_len + max(lengths) + args.max_new_hi + 1
    rng = np.random.default_rng(args.seed)
    work = make_workload(rng, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi),
                         prefix_len=prefix_len)
    prefix = (np.random.default_rng(args.seed + 7)
              .integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
              if prefix_len else None)

    def fresh():
        r = np.random.default_rng(args.seed + 1)
        return build_requests(Request, r, work, cfg.vocab_size, prefix=prefix)

    run_lock = args.layout in ("contiguous", "both")
    run_paged = args.layout in ("paged", "both")

    rows, artifact = [], dict(
        bench="serve_layouts", workload=args.workload, arch=cfg.name,
        slots=args.slots, requests=args.requests, lengths=lengths,
        prefix_len=prefix_len, page_size=args.page_size)
    n_tok = n_prompt = None
    outs = {}

    cont = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                  cache_layout="contiguous")
    cont_out, cont_s = _timed(run_continuous, cont, fresh, work)
    n_tok = sum(len(r.out) for r in cont_out)
    n_prompt = sum(len(r.prompt) for r in cont_out)
    cont_tps = n_tok / cont_s
    outs["contiguous"] = [r.out.tolist() for r in cont_out]
    # the dense layout reserves its whole footprint up front: page-equivalent
    # is slots x blocks-per-stripe, the number the paged pool competes with
    cont_pages = args.slots * -(-cont.smax // args.page_size)
    rows.append(("serve/continuous_tok_per_s", cont_tps,
                 f"wall={cont_s:.2f}s_gen={n_tok}_prompt={n_prompt}"))
    artifact.update(generated_tokens=n_tok, prompt_tokens=n_prompt,
                    continuous_tok_per_s=round(cont_tps, 2),
                    contiguous_page_equiv=cont_pages,
                    engine_stats=cont.stats)

    if run_lock:
        lock = LockstepEngine(cfg, folded, batch_slots=args.slots,
                              max_len=max_len)
        lock_out, lock_s = _timed(run_lockstep, lock, fresh)
        lock_tps = n_tok / lock_s
        outs["lockstep"] = [r.out.tolist() for r in lock_out]
        rows.insert(0, ("serve/lockstep_tok_per_s", lock_tps,
                        f"wall={lock_s:.2f}s"))
        rows.append(("serve/continuous_speedup", cont_tps / lock_tps, ""))
        artifact.update(lockstep_tok_per_s=round(lock_tps, 2),
                        speedup=round(cont_tps / lock_tps, 3))

    if run_paged:
        paged = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                       cache_layout="paged", page_size=args.page_size)
        paged_out, paged_s = _timed(run_continuous, paged, fresh, work)
        paged_tps = n_tok / paged_s
        outs["paged"] = [r.out.tolist() for r in paged_out]
        peak = paged.stats["cache_pages_peak"]
        rows.append(("serve/paged_tok_per_s", paged_tps,
                     f"wall={paged_s:.2f}s_prefix_hits="
                     f"{paged.stats['prefix_hits']}"))
        rows.append(("serve/paged_vs_contiguous_speedup",
                     paged_tps / cont_tps, ""))
        rows.append(("serve/paged_peak_pages", peak,
                     f"contiguous_equiv={cont_pages}"))
        artifact.update(paged_tok_per_s=round(paged_tps, 2),
                        paged_vs_contiguous_speedup=round(paged_tps / cont_tps,
                                                          3),
                        paged_peak_pages=peak,
                        paged_engine_stats=paged.stats)

    from repro.kernels import ops
    ref_outputs = outs["contiguous"]
    match = all(o == ref_outputs for o in outs.values())
    # bit-identity between engines/layouts is only guaranteed off the
    # compiled pallas backend (engine.py docstring): there prefill (q7
    # flash) and decode kernels may differ in the last LSB, flipping rare
    # argmax ties
    match_enforced = ops.backend() != "pallas"
    rows.append(("serve/outputs_match", float(match),
                 "+".join(sorted(outs))))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")

    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")
    if not match and match_enforced:
        print("ERROR: greedy outputs diverged between engines/layouts",
              file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(engines are not bit-identical there)", file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lengths", default="16,32,64,128,256",
                    help="comma-separated prompt (or suffix) length palette")
    ap.add_argument("--layout", default="both",
                    choices=["contiguous", "paged", "both"],
                    help="contiguous: lockstep-vs-continuous baseline; "
                         "paged: contiguous-vs-paged cache A/B; both: all")
    ap.add_argument("--workload", default="poisson",
                    choices=["poisson", "prefix"])
    ap.add_argument("--prefix-len", type=int, default=96,
                    help="shared system-prompt length (prefix workload)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.25,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--max-new-lo", type=int, default=8)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_PR.json artifact here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (fast on 2 CPU cores)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.lengths = "8,16,32" if args.workload == "poisson" else "4,8"
        args.prefix_len = min(args.prefix_len, 48)
        args.max_new_lo, args.max_new_hi = 4, 8
    raise SystemExit(bench(args))


if __name__ == "__main__":
    main()
