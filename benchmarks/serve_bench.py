"""Serving throughput: continuous batching vs. the lockstep baseline on a
mixed-length Poisson-arrival workload.

A workload of N requests is drawn from a Poisson arrival process with prompt
lengths mixed over a palette (16-256 tokens by default) and per-request decode
budgets.  Both engines process the SAME request set over the same folded
integer model:

  * ``LockstepEngine`` — static batching: requests are grouped by prompt
    length (so left-padding never contaminates positions and its outputs are
    per-request correct), each group decoded in lockstep to the group's
    longest budget.
  * ``Engine`` — continuous batching: requests arrive over virtual time
    (one tick per decode step, idle gaps fast-forwarded) and stream through
    the slot table; admissions prefill in one shot; slots are evicted and
    refilled mid-flight.  The lockstep baseline ignores arrival times
    entirely (sees the whole workload upfront), which favors the baseline.

Greedy outputs must be identical per request — continuous batching changes
throughput, not tokens.  (The throughput win applies to attention archs,
where admission prefills in one shot; SSM/hybrid archs prefill via a
batch-1 recurrence loop and generally still favor the lockstep baseline.)  Prints ``name,value,derived`` CSV; ``--json`` also
writes a BENCH_PR.json artifact for the CI perf trajectory.

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json BENCH_PR.json
    PYTHONPATH=src python benchmarks/serve_bench.py --arch yi-6b --requests 24
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def make_workload(rng, n_requests, lengths, rate, max_new_range):
    """Poisson arrivals: exponential interarrival gaps (unit = decode steps),
    uniform prompt-length palette, uniform decode budgets."""
    t = 0.0
    work = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        work.append(dict(
            arrival=t,
            prompt_len=int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
        ))
    return work


def build_requests(Request, rng, work, vocab):
    return [Request(prompt=rng.integers(0, vocab, (w["prompt_len"],)
                                        ).astype(np.int32),
                    max_new_tokens=w["max_new"])
            for w in work]


def run_lockstep(eng, requests):
    """Static batching: same-length groups (correct per-request outputs),
    each group decoded to its longest budget.  The engine is reset between
    groups — recurrent-state archs (mamba/xLSTM) would otherwise leak the
    previous group's SSM state into the next prefill (attention rows are
    position-masked; SSM state is not)."""
    by_len = {}
    for r in requests:
        by_len.setdefault(len(r.prompt), []).append(r)
    for group in by_len.values():
        for i in range(0, len(group), eng.batch):
            eng.reset()
            eng.generate(group[i:i + eng.batch])
    return requests


def run_continuous(eng, requests, work):
    """Requests arrive over virtual time (1 tick = one decode step of the
    engine) following the workload's Poisson process and are submitted when
    due; the clock fast-forwards over idle gaps so lulls cost no wall time.
    Same completion set as the lockstep baseline, different admission
    dynamics."""
    i = 0
    n = len(requests)
    while i < n or eng.sched.has_work:
        t = eng.stats["decode_steps"]
        while i < n and work[i]["arrival"] <= t:
            eng.submit(requests[i])
            i += 1
        if not eng.sched.has_work and i < n:
            eng.submit(requests[i])     # idle: jump to the next arrival
            i += 1
        eng.step()
    return requests


def bench(args):
    from repro.configs import smoke_config
    from repro.launch.serve import calibrated_folded
    from repro.serve.engine import Engine, LockstepEngine, Request

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    folded = calibrated_folded(cfg, key, calib)

    lengths = [int(x) for x in args.lengths.split(",")]
    max_len = max(lengths) + args.max_new_hi + 1
    rng = np.random.default_rng(args.seed)
    work = make_workload(rng, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi))

    cont = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len)
    lock = LockstepEngine(cfg, folded, batch_slots=args.slots,
                          max_len=max_len)

    def fresh():
        r = np.random.default_rng(args.seed + 1)
        return build_requests(Request, r, work, cfg.vocab_size)

    # warmup pass (compilation), then the timed pass on fresh state
    run_lockstep(lock, fresh())
    lock.reset()
    t0 = time.perf_counter()
    lock_out = run_lockstep(lock, fresh())
    lock_s = time.perf_counter() - t0

    run_continuous(cont, fresh(), work)
    cont.reset()
    t0 = time.perf_counter()
    cont_out = run_continuous(cont, fresh(), work)
    cont_s = time.perf_counter() - t0

    from repro.kernels import ops
    match = all(a.out.tolist() == b.out.tolist()
                for a, b in zip(lock_out, cont_out))
    # bit-identity between the engines is only guaranteed off the compiled
    # pallas backend (engine.py docstring): there prefill (q7 flash) and
    # decode kernel may differ in the last LSB, flipping rare argmax ties
    match_enforced = ops.backend() != "pallas"
    n_tok = sum(len(r.out) for r in cont_out)
    n_prompt = sum(len(r.prompt) for r in cont_out)
    lock_tps = n_tok / lock_s
    cont_tps = n_tok / cont_s

    rows = [
        ("serve/lockstep_tok_per_s", lock_tps,
         f"wall={lock_s:.2f}s_gen={n_tok}_prompt={n_prompt}"),
        ("serve/continuous_tok_per_s", cont_tps,
         f"wall={cont_s:.2f}s_oneshot_prefills="
         f"{cont.stats['oneshot_prefills']}"),
        ("serve/continuous_speedup", cont_tps / lock_tps,
         f"outputs_match={match}"),
    ]
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")

    if args.json:
        Path(args.json).write_text(json.dumps(dict(
            bench="serve_continuous_vs_lockstep",
            arch=cfg.name, slots=args.slots, requests=args.requests,
            lengths=lengths, generated_tokens=n_tok, prompt_tokens=n_prompt,
            lockstep_tok_per_s=round(lock_tps, 2),
            continuous_tok_per_s=round(cont_tps, 2),
            speedup=round(cont_tps / lock_tps, 3),
            outputs_match=bool(match),
            engine_stats=cont.stats,
        ), indent=2) + "\n")
    if not match and match_enforced:
        print("ERROR: greedy outputs diverged between engines",
              file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(engines are not bit-identical there)", file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lengths", default="16,32,64,128,256",
                    help="comma-separated prompt-length palette")
    ap.add_argument("--rate", type=float, default=0.25,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--max-new-lo", type=int, default=8)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_PR.json artifact here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (fast on 2 CPU cores)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.lengths = "8,16,32"
        args.max_new_lo, args.max_new_hi = 4, 8
    raise SystemExit(bench(args))


if __name__ == "__main__":
    main()
