"""Batched single-query decode attention over the int8 KV cache — the
continuous-batching serving kernel.

One grid step = (slot, kv head, KV block).  Every slot in the table decodes
at its own depth: the per-slot ``lengths`` vector rides as a scalar-prefetch
argument, so it is available both to the kernel body (per-slot masking) and
to the BlockSpec index maps, which CLAMP the KV block index to the slot's
last live block — grid steps past a slot's length re-address the block that
is already resident in VMEM, so the pipeliner issues no new DMA and short
slots genuinely pay no HBM traffic for the unused tail of their cache.

Per KV block the datapath is exactly the paper's Softmax Core —

    int8 q @ kᵀ -> int32 scores -> (max - s) -> fixed-point LUT index ->
    Q0.7 exp numerators -> int8 P @ int8 V on the MXU -> int32 partial

— with the same fp32 cross-block carry (running max rescale, denominator,
output accumulator) as ``flash_qattention``.  With a single KV block the
kernel degenerates to the paper's row-wise softmax and is bit-exact vs.
``kernels/ref.py::decode_qattention_ref``.

GQA: q heads arrive pre-grouped per kv head, (B, Hkv, G, D); K/V arrive in
the cache's native (B, Smax, Hkv, D) layout and each live KV block is
streamed from HBM exactly once, shared by the whole group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixedpoint as fxp
from repro.core.qsoftmax import LUT_SIZE, MASK_OFFSET
from repro.kernels.pallas_compat import CompilerParams, divisor_tile
from repro.kernels.quant_softmax import lut_lookup

NEG_INIT = -(1 << 30)


def _kv_load_i8(k_ref, v_ref, _b_i, _k_i):
    """Default KV tile loader: the pool already holds int8 codes."""
    return k_ref[0, :, 0], v_ref[0, :, 0]


def decode_kv_index_map(bkv):
    """KV BlockSpec index map for the CONTIGUOUS decode kernel.

    Clamps dead KV blocks onto the slot's last live block: the dead grid
    step re-addresses the block already resident in VMEM, so the pipeliner
    issues no DMA.  Module-level (not a closure inside the wrapper) so
    ``repro.analysis.pallas_lint`` can evaluate its bounds over the grid."""
    def kv_map(bb, h, k, lens):
        last_live = jnp.maximum((lens[bb] - 1) // bkv, 0)
        return (bb, jnp.minimum(k, last_live), h, 0)
    return kv_map


def paged_kv_index_map(psize):
    """KV BlockSpec index map shared by BOTH paged decode kernels (int8 and
    int4-packed): clamp the dead logical block to the last live one, THEN
    translate through the slot's scalar-prefetched block-table row.  One
    factory — not two copies — so the int8/q4 agreement is structural and
    ``pallas_lint`` can prove the returned page index stays inside the
    pool for every grid point."""
    def kv_map(bb, h, k, lens, btab):
        last_live = jnp.maximum((lens[bb] - 1) // psize, 0)
        return (btab[bb, jnp.minimum(k, last_live)], 0, h, 0)
    return kv_map


def dequant_kv_tile(w_u8, scale):
    """Fused in-VMEM dequant of one nibble-planar int4 KV tile.

    (rows, D//2) uint8 -> (rows, D) int8: sign-extend both nibble planes
    (same branch-free ``(x ^ 8) - 8`` as ``int4_matmul``/``core.packing``),
    concatenate along the head dim (planar layout), multiply by the page's
    shared fp32 scale, round, clip.  Bit-identical to
    ``packing.dequantize_kv_page`` — the oracles depend on it."""
    w = w_u8.astype(jnp.int32)
    lo = ((w & 15) ^ 8) - 8
    hi = (((w >> 4) & 15) ^ 8) - 8
    c4 = jnp.concatenate([lo, hi], axis=-1).astype(jnp.float32)
    return jnp.clip(jnp.round(c4 * scale), -127, 127).astype(jnp.int8)


def _decode_body(g, bkv, kv_load, len_ref, q_ref, k_ref, v_ref, lut_ref,
                 mi_ref, si_ref, inv_ref, osc_ref, o_ref, m_scr, den_scr,
                 acc_scr):
    # shared datapath of every decode-attention variant: the int8 and the
    # int4-packed kernels differ ONLY in ``kv_load`` (identity load vs
    # fused nibble dequant), so the int8 path stays byte-identical and the
    # packed path inherits the oracle-exact accumulation order for free
    b_i = pl.program_id(0)
    k_i = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        den_scr[...] = jnp.zeros_like(den_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b_i]                         # this slot's valid prefix
    live = (k_i * bkv) < length                   # dead blocks: no compute
                                                  # (and no DMA — index map
                                                  # re-addresses a resident
                                                  # block)

    @pl.when(live)
    def _block():
        q = q_ref[0, 0]                           # (G, D) int8 — whole group
        k, v = kv_load(k_ref, v_ref, b_i, k_i)    # (bkv, D) int8 each
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.int32)  # (G, bkv)
        kpos = k_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (g, bkv), 1)
        s = jnp.where(kpos < length, s, s - MASK_OFFSET)
        lm = jnp.max(s, axis=-1, keepdims=True)
        m_old = m_scr[:, :1]
        m_new = jnp.maximum(m_old, lm)
        idx = jnp.clip(fxp.rescale(m_new - s, mi_ref[0], si_ref[0], out_bits=9),
                       0, LUT_SIZE - 1)
        num = lut_lookup(idx, lut_ref[...].astype(jnp.int32))      # Q0.7
        den_b = jnp.sum(num, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(num.astype(jnp.int8), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)  # (G, D)
        f = jnp.exp((m_old - m_new).astype(jnp.float32) * inv_ref[0])
        f = jnp.where(m_old == NEG_INIT, 0.0, f)
        den_scr[...] = den_scr[...] * f + den_b.astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * f + pv.astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(k_i == nk - 1)
    def _epilogue():
        den = jnp.maximum(den_scr[:, :1], 1.0)
        o = acc_scr[...] / den * osc_ref[0]
        o_ref[0, 0] = jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


def _decode_kernel(g, bkv, len_ref, *rest):
    _decode_body(g, bkv, _kv_load_i8, len_ref, *rest)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def decode_qattention(
    q_i8: jax.Array,       # int8 (B, Hkv, G, D) — one token/slot, grouped q
    k_i8: jax.Array,       # int8 (B, Smax, Hkv, D) — cache-NATIVE layout
    v_i8: jax.Array,
    lengths: jax.Array,    # int32 (B,): valid cache prefix per slot
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, bkv: int = 512, interpret: bool = False,
) -> jax.Array:
    """Continuous-batching decode attention: int8 (B, Hkv, G, D) context on
    the attn_out grid, each slot masked to its own ``lengths[b]`` prefix.

    K/V come in the cache's native (B, Smax, Hkv, D) layout — the BlockSpec
    index maps gather the (bkv, D) slab per kv head directly, so no per-step
    transpose of the whole cache ever materializes in HBM."""
    b, hkv, g, d = q_i8.shape
    smax = k_i8.shape[1]
    bkv = divisor_tile(bkv, smax)
    grid = (b, hkv, smax // bkv)
    kernel = functools.partial(_decode_kernel, g, bkv)
    kv_map = decode_kv_index_map(bkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                    # lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, k, lens: (bb, h, 0, 0)),
            pl.BlockSpec((1, bkv, 1, d), kv_map),
            pl.BlockSpec((1, bkv, 1, d), kv_map),
            pl.BlockSpec((LUT_SIZE,), lambda bb, h, k, lens: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, h, k, lens: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.int32),     # running max (col-broadcast)
            pltpu.VMEM((g, 128), jnp.float32),   # running denominator
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32).reshape(-1),
      q_i8, k_i8, v_i8, lut_q7,
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1))


def _paged_decode_kernel(g, psize, len_ref, _btab_ref, *rest):
    # the block table feeds only the BlockSpec index maps (which pool page
    # backs this slot's k-th logical KV block); the body is exactly the
    # contiguous kernel with block size = page size
    _decode_kernel(g, psize, len_ref, *rest)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_qattention(
    q_i8: jax.Array,          # int8 (B, Hkv, G, D) — one token/slot, grouped q
    k_pool: jax.Array,        # int8 (n_pages, P, Hkv, D) — global page pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # int32 (B, max_blocks): slot -> pool pages
    lengths: jax.Array,       # int32 (B,): valid rows per slot
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, interpret: bool = False,
) -> jax.Array:
    """Paged continuous-batching decode attention: the KV BlockSpec index
    map follows the slot's scalar-prefetched block-table entry instead of a
    linear offset, so one grid step streams one *pool page* per kv head.

    Same clamping machinery as the contiguous kernel: grid steps past a
    slot's length re-address the slot's last live page — already resident
    in VMEM, so the pipeliner issues no DMA and short slots pay no HBM
    traffic for table entries beyond their chain.  One logical KV block ==
    one page, so the grid tiles exactly (no divisor fallback needed)."""
    b, hkv, g, d = q_i8.shape
    psize = k_pool.shape[1]
    nb = block_tables.shape[1]
    grid = (b, hkv, nb)
    kernel = functools.partial(_paged_decode_kernel, g, psize)
    kv_map = paged_kv_index_map(psize)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # lengths, block_tables
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bb, h, k, lens, btab: (bb, h, 0, 0)),
            pl.BlockSpec((1, psize, 1, d), kv_map),
            pl.BlockSpec((1, psize, 1, d), kv_map),
            pl.BlockSpec((LUT_SIZE,), lambda bb, h, k, lens, btab: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, h, k, lens, btab: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.int32),     # running max (col-broadcast)
            pltpu.VMEM((g, 128), jnp.float32),   # running denominator
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32).reshape(-1),
      jnp.asarray(block_tables, jnp.int32),
      q_i8, k_pool, v_pool, lut_q7,
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1))


def _paged_decode_q4_kernel(g, psize, len_ref, btab_ref, q_ref, k_ref, v_ref,
                            lut_ref, ks_ref, vs_ref, mi_ref, si_ref, inv_ref,
                            osc_ref, o_ref, m_scr, den_scr, acc_scr):
    # int4-packed pool: the KV tile arrives as (psize, D//2) planar nibbles;
    # dequant happens here in VMEM under the page's shared scale (looked up
    # through the block table — for a live step the clamped index map loaded
    # exactly page btab[b, k], so scale and payload always agree)
    def load(kr, vr, b_i, k_i):
        pg = btab_ref[b_i, k_i]
        return (dequant_kv_tile(kr[0, :, 0], ks_ref[pg]),
                dequant_kv_tile(vr[0, :, 0], vs_ref[pg]))

    _decode_body(g, psize, load, len_ref, q_ref, k_ref, v_ref, lut_ref,
                 mi_ref, si_ref, inv_ref, osc_ref, o_ref, m_scr, den_scr,
                 acc_scr)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_qattention_q4(
    q_i8: jax.Array,          # int8 (B, Hkv, G, D) — one token/slot, grouped q
    k_pool: jax.Array,        # uint8 (n_pages, P, Hkv, D//2) — packed pool
    v_pool: jax.Array,
    k_scale: jax.Array,       # fp32 (n_pages,): shared dequant scale per page
    v_scale: jax.Array,
    block_tables: jax.Array,  # int32 (B, max_blocks): slot -> pool pages
    lengths: jax.Array,       # int32 (B,): valid rows per slot
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, interpret: bool = False,
) -> jax.Array:
    """Paged decode attention over the int4-PACKED page pool: identical
    grid/clamping/datapath to ``paged_decode_qattention``, but each page
    streams HBM->VMEM at half the bytes (nibble-planar uint8 along the head
    dim) and is dequantized inside the kernel body under its shared fp32
    scale — exactly the fused-unpack idiom ``int4_matmul`` uses for
    weights; no dequantized KV view ever materializes in HBM.  Bit-exact
    vs ``ref.py::paged_decode_qattention_q4_ref``."""
    b, hkv, g, d = q_i8.shape
    psize = k_pool.shape[1]
    dp = k_pool.shape[3]                          # D//2 packed bytes
    assert dp * 2 == d, (dp, d)
    nb = block_tables.shape[1]
    grid = (b, hkv, nb)
    kernel = functools.partial(_paged_decode_q4_kernel, g, psize)
    kv_map = paged_kv_index_map(psize)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # lengths, block_tables
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bb, h, k, lens, btab: (bb, h, 0, 0)),
            pl.BlockSpec((1, psize, 1, dp), kv_map),
            pl.BlockSpec((1, psize, 1, dp), kv_map),
            pl.BlockSpec((LUT_SIZE,), lambda bb, h, k, lens, btab: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),    # k page scales
            pl.BlockSpec(memory_space=pltpu.SMEM),    # v page scales
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, h, k, lens, btab: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.int32),     # running max (col-broadcast)
            pltpu.VMEM((g, 128), jnp.float32),   # running denominator
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32).reshape(-1),
      jnp.asarray(block_tables, jnp.int32),
      q_i8, k_pool, v_pool, lut_q7,
      jnp.asarray(k_scale, jnp.float32).reshape(-1),
      jnp.asarray(v_scale, jnp.float32).reshape(-1),
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1))
