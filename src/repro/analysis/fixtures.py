"""Intentionally-broken fixtures that prove the analysis subsystem works.

A static checker that never fires is indistinguishable from one that
can't.  Each fixture here plants exactly one of the bugs the auditor and
pallas lint exist to catch — a seeded f32 matmul on the int path, an int8
dot that accumulates narrow, a whole-pool float cast outside a kernel
boundary, a clobbered donation, aliased pool leaves, an out-of-range /
unclamped index map — and ``run_self_test`` asserts the expected rule id
is raised (and that the two blessed negative controls stay clean).  The
CI analyze lane runs this before trusting a zero-violation report.
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_audit, pallas_lint
from repro.analysis.jaxpr_audit import (audit_cache_aliasing, audit_graph,
                                        pool_threshold_elems)


class Fixture(NamedTuple):
    expected_rule: str    # "" for a negative control (must stay clean)
    run: Callable[[], List[jaxpr_audit.Violation]]


def _cache():
    # miniature paged pool: two >=4-D int8 payload leaves, 1024 elems each
    # (pool threshold = 512, far above the fixtures' activations)
    return {"k": jnp.zeros((1, 4, 16, 2, 8), jnp.int8),
            "v": jnp.ones((1, 4, 16, 2, 8), jnp.int8)}


def _args():
    cache = _cache()
    params = {"w": jnp.ones((8, 8), jnp.int8)}
    x = jnp.ones((2, 8), jnp.float32)
    return params, cache, x


def _audit(fn, *, donate: bool = True) -> List[jaxpr_audit.Violation]:
    args = _args()
    jitted = jax.jit(fn, donate_argnums=(1,)) if donate else jax.jit(fn)
    res = audit_graph(jitted, args, graph=f"fixture:{fn.__name__}",
                      pool_threshold=pool_threshold_elems(args[1]))
    return res.violations


# --- jaxpr-rule fixtures -------------------------------------------------

def _bad_fdot(params, cache, x):
    # launders the int8 weight into a float matmul: INT-DOT-FLOAT
    wf = params["w"].astype(jnp.float32) / 127.0
    return jnp.dot(x, wf), cache


def _bad_acc(params, cache, x):
    # int8 x int8 dot without preferred_element_type=int32: INT-DOT-ACC
    xq = jnp.clip(jnp.round(x * 16.0), -127, 127).astype(jnp.int8)
    y = jax.lax.dot_general(xq, params["w"], (((1,), (0,)), ((), ())))
    return y, cache


def _bad_pool_cast(_params, cache, x):
    # dequantizes the whole pool in open code: POOL-FLOAT-CAST
    kf = cache["k"].astype(jnp.float32)
    return x + kf.sum(), cache


def _clean(params, cache, x):
    # the shape of a correct hot graph: int dot with wide accumulate,
    # activation-scale casts only
    xq = jnp.clip(jnp.round(x * 16.0), -127, 127).astype(jnp.int8)
    y = jax.lax.dot_general(xq, params["w"], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) / 256.0, cache


@jax.jit
def _blessed_dequant(pool):
    return pool.astype(jnp.float32)


def _blessed_pool_cast(_params, cache, x):
    # same whole-pool cast as _bad_pool_cast, but inside a registered
    # kernel-boundary scope: must NOT be flagged
    kf = _blessed_dequant(cache["k"])
    return x + kf.sum(), cache


def _run_blessed() -> List[jaxpr_audit.Violation]:
    args = _args()
    jitted = jax.jit(_blessed_pool_cast, donate_argnums=(1,))
    res = audit_graph(jitted, args, graph="fixture:_blessed_pool_cast",
                      pool_threshold=pool_threshold_elems(args[1]),
                      boundaries={"_blessed_dequant": "fixture boundary"})
    return res.violations


def _run_aliased() -> List[jaxpr_audit.Violation]:
    # one jnp array reused for two pool leaves — the PR 7 double-donation
    shared = jnp.zeros((1, 4, 16, 2, 8), jnp.int8)
    return audit_cache_aliasing({"k": shared, "v": shared},
                                graph="fixture:aliased")


# --- pallas index-map fixtures ------------------------------------------

def _oob_decode_map(_bkv):
    def kv_map(bb, h, k, lens):    # noqa: ARG001 - index-map signature
        return (bb, k + 1, h, 0)   # off-by-one: last block out of range
    return kv_map


def _dead_unclamped_decode_map(_bkv):
    def kv_map(bb, h, k, lens):    # noqa: ARG001 - index-map signature
        return (bb, k, h, 0)       # in range, but dead blocks re-DMA
    return kv_map


def _trash_paged_map(_psize):
    def kv_map(bb, h, k, lens, btab):    # noqa: ARG001
        return (btab[bb, k], 0, h, 0)    # dead k reads the trash page
    return kv_map


FIXTURES: Dict[str, Fixture] = {
    "seeded_f32_matmul": Fixture(
        "INT-DOT-FLOAT", lambda: _audit(_bad_fdot)),
    "narrow_accumulate": Fixture(
        "INT-DOT-ACC", lambda: _audit(_bad_acc)),
    "open_pool_dequant": Fixture(
        "POOL-FLOAT-CAST", lambda: _audit(_bad_pool_cast)),
    "clobbered_donation": Fixture(
        "DONATION", lambda: _audit(_clean, donate=False)),
    "aliased_pool_leaves": Fixture(
        "DONATION-ALIAS", _run_aliased),
    "idxmap_out_of_range": Fixture(
        "IDXMAP-RANGE",
        lambda: pallas_lint.check_decode_kv_map(
            _oob_decode_map, kernel="fixture:oob_decode")),
    "idxmap_dead_unclamped": Fixture(
        "IDXMAP-CLAMP",
        lambda: pallas_lint.check_decode_kv_map(
            _dead_unclamped_decode_map, kernel="fixture:dead_unclamped")),
    "idxmap_paged_trash": Fixture(
        "IDXMAP-RANGE",
        lambda: pallas_lint.check_paged_decode_kv_map(
            _trash_paged_map, kernel="fixture:paged_trash")),
    # negative controls: a correct graph and a boundary-blessed pool cast
    "clean_int_graph": Fixture("", lambda: _audit(_clean)),
    "blessed_pool_cast": Fixture("", _run_blessed),
}


def run_self_test() -> Dict:
    """Run every fixture; each broken one must raise its expected rule id,
    each negative control must stay clean.  Returns a JSON-able summary
    with an overall ``ok`` flag."""
    results = {}
    for name, fx in FIXTURES.items():
        viols = fx.run()
        rules = sorted({v.rule for v in viols})
        ok = fx.expected_rule in rules if fx.expected_rule else not rules
        results[name] = {
            "expected_rule": fx.expected_rule,
            "flagged_rules": rules,
            "ok": ok,
            "violations": [v.to_dict() for v in viols],
        }
    return {"ok": all(r["ok"] for r in results.values()),
            "fixtures": results}
