"""Per-shape tile-size selection for the attention kernels.

``benchmarks/roofline.py`` and ``benchmarks/hlo_cost.py`` can price a kernel
but fed no kernel decisions until now: the decode kernel always ran
``bkv=512`` and the paged prefill kernel ``bq=128`` regardless of batch
size, page size, head geometry, or KV bit width.  This module closes the
loop with a tiny roofline-derived cost table:

* ``decode_bkv(...)``  — KV tile length for the contiguous decode kernel.
* ``prefill_bq(...)``  — q-block length for the paged prefill kernel.

Selections are cached per shape key, overridable by environment
(``REPRO_DECODE_BKV`` / ``REPRO_PREFILL_BQ`` pin a value,
``REPRO_AUTOTUNE=off`` restores the legacy fixed defaults), and — because
the paged kernels' dead-block clamping makes their outputs tile-size
independent (see the kernel docstrings) — NEVER change numerics: autotune
moves DMA/grid overhead around, not bits.

The cost model mirrors ``benchmarks/roofline.py``'s v4-lite ceilings.  A
grid step costs ``max(tile_bytes / HBM_BW, tile_flops / PEAK_INT8)`` plus a
fixed per-step overhead (DMA issue + grid bookkeeping); fewer, larger steps
amortize the overhead until the double-buffered tiles overflow the VMEM
budget.  For prefill, every KV page is streamed once per (head, q-block),
so the KV traffic itself scales with ``ceil(sq / bq)`` — the dominant term
for long chains at big batch.

``measure_best`` is the optional measured mode: given a timer it races the
candidate set and caches the winner under the same key/override discipline
(used by benchmarks; the serving path sticks to the analytic table so cold
starts pay no compile storm).
"""
from __future__ import annotations

import os

# v4-lite ceilings — keep in sync with benchmarks/roofline.py (that module
# sits outside the package, so the constants are mirrored, not imported).
PEAK_INT8_FLOPS = 197e12     # int8 MXU ops/s
HBM_BW = 819e9               # bytes/s
VMEM_BUDGET = 16 * 2**20     # bytes/core
VMEM_FILL = 0.5              # leave headroom for double-buffering + scratch
STEP_OVERHEAD_S = 2e-6       # DMA issue + grid step bookkeeping

DECODE_BKV_CANDIDATES = (128, 256, 512, 1024)
PREFILL_BQ_CANDIDATES = (32, 64, 128, 256)

DEFAULT_DECODE_BKV = 512     # legacy fixed defaults (REPRO_AUTOTUNE=off)
DEFAULT_PREFILL_BQ = 128

_cache: dict = {}


def clear_cache() -> None:
    _cache.clear()


def _mode() -> str:
    return os.environ.get("REPRO_AUTOTUNE", "roofline")


def _env_int(name: str):
    v = os.environ.get(name)
    return int(v) if v else None


def _fit(c: int, n: int) -> int:
    """Largest divisor of ``n`` that is <= c (mirrors divisor_tile)."""
    c = min(c, n)
    while n % c:
        c -= 1
    return c


def _kv_bytes(hd: int, kv_bits: int) -> float:
    return hd * (0.5 if kv_bits == 4 else 1.0)


def decode_bkv(smax: int, *, batch_slots: int, hkv: int, hd: int,
               kv_bits: int = 8) -> int:
    """KV tile length for the contiguous decode kernel at this shape."""
    env = _env_int("REPRO_DECODE_BKV")
    if env:
        return _fit(env, smax)
    if _mode() == "off":
        return _fit(DEFAULT_DECODE_BKV, smax)
    key = ("decode_bkv", batch_slots, hkv, hd, smax, kv_bits)
    got = _cache.get(key)
    if got is None:
        got = _roofline_pick(
            DECODE_BKV_CANDIDATES, smax,
            tile_bytes=lambda bkv: 2 * bkv * _kv_bytes(hd, kv_bits),
            tile_flops=lambda bkv: 2 * 2 * bkv * hd,       # QK^T + P@V
            steps=lambda bkv: batch_slots * hkv * (smax // bkv),
        )
        _cache[key] = got
    return got


def prefill_bq(sq: int, *, batch_slots: int, page_size: int, hkv: int,
               hd: int, kv_bits: int = 8, n_blocks: int = 1,
               n_heads: int | None = None) -> int:
    """q-block length for the paged prefill kernel at this shape.

    Safe to vary freely: block-level causal skipping makes the kernel
    output bq-independent, so two engines tuned differently still agree
    bit-for-bit.
    """
    env = _env_int("REPRO_PREFILL_BQ")
    if env:
        return _fit(env, sq)
    if _mode() == "off":
        return _fit(DEFAULT_PREFILL_BQ, sq)
    h = n_heads or hkv
    key = ("prefill_bq", batch_slots, page_size, hkv, hd, sq, kv_bits,
           n_blocks, h)
    got = _cache.get(key)
    if got is None:
        kvb = page_size * _kv_bytes(hd, kv_bits)
        got = _roofline_pick(
            PREFILL_BQ_CANDIDATES, sq,
            # each page streams once per (head, q-block): q tile + KV page
            tile_bytes=lambda bq: bq * hd + 2 * kvb,
            tile_flops=lambda bq: 2 * 2 * bq * page_size * hd,
            steps=lambda bq: batch_slots * h * (sq // bq) * n_blocks,
            extra_vmem=lambda bq: 2 * bq * hd * 4,          # fp32 scratch
        )
        _cache[key] = got
    return got


def _roofline_pick(candidates, n, *, tile_bytes, tile_flops, steps,
                   extra_vmem=lambda c: 0) -> int:
    """Pick the candidate minimizing modeled wall time within VMEM budget."""
    best, best_t = None, None
    for raw in candidates:
        c = _fit(raw, n)
        # double-buffered in/out tiles must fit the fill fraction of VMEM
        if 2 * tile_bytes(c) + extra_vmem(c) > VMEM_BUDGET * VMEM_FILL:
            continue
        t = steps(c) * (STEP_OVERHEAD_S +
                        max(tile_bytes(c) / HBM_BW,
                            tile_flops(c) / PEAK_INT8_FLOPS))
        if best_t is None or t < best_t or (t == best_t and c > best):
            best, best_t = c, t
    if best is None:                      # every candidate overflowed VMEM
        best = _fit(candidates[0], n)
    return best


def measure_best(candidates, timer, *, key=None):
    """Measured mode: time ``timer(candidate)`` (seconds) over the candidate
    set and cache the argmin under ``key``.  Used by benchmarks; returns the
    winning candidate."""
    if key is not None and key in _cache:
        return _cache[key]
    best, best_t = None, None
    for c in candidates:
        t = timer(c)
        if best_t is None or t < best_t:
            best, best_t = c, t
    if key is not None:
        _cache[key] = best
    return best
