"""Llama-3.1 405B  [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16_384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53_248, vocab_size=128_256,
    rope_theta=500_000.0, param_dtype="bfloat16",
))
