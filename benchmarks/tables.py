"""One benchmark per paper table (Tables I-IV of FQ-BERT).

All run on CPU in minutes; each returns rows of (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, iters=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


# --- Table I: compression ratio + accuracy proxy -------------------------------

def table1_compression() -> List[Row]:
    from repro.configs import get_config, smoke_config
    from repro.models import transformer as T
    from repro.models import fold as F
    from repro.models import serve_int as S

    rows: List[Row] = []
    cfg = get_config("bert-base")
    # model-size accounting at the paper's exact dims (no allocation needed)
    p_shapes = jax.eval_shape(lambda k: T.init_params(cfg, k),
                              jax.random.PRNGKey(0))
    a_shapes = jax.eval_shape(lambda: T.init_amax(cfg))
    f_shapes = jax.eval_shape(lambda p, a: F.fold_params(cfg, p, a),
                              p_shapes, a_shapes)

    def nbytes(tree):
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(tree))

    fp32_bytes = sum(int(np.prod(x.shape)) * 4 for x in jax.tree.leaves(p_shapes))
    # weight-stream compression (the paper's 7.94x is weights: fp32 -> int4+scales)
    blocks32 = sum(int(np.prod(x.shape)) * 4
                   for x in jax.tree.leaves(p_shapes["blocks"]))
    blocks_q = nbytes(f_shapes["blocks"])
    rows.append(("table1/encoder_weight_compression", 0.0,
                 f"ratio={blocks32 / blocks_q:.2f}x_target=7.94x"))
    rows.append(("table1/full_model_compression", 0.0,
                 f"ratio={fp32_bytes / nbytes(f_shapes):.2f}x"))

    # accuracy proxy at smoke scale: fp32 vs FQ logit agreement after QAT fold
    cfg_s = smoke_config("bert-base")
    params = T.init_params(cfg_s, jax.random.PRNGKey(0))
    amax = T.init_amax(cfg_s)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg_s.vocab_size)
    lg_f, obs, _ = T.forward(cfg_s, params, amax, toks)
    folded = F.fold_params(cfg_s, params, obs)
    lg_i, _ = S.serve_forward(cfg_s, folded, toks, mode="prefill")
    pf = jax.nn.softmax(lg_f, -1)
    kl = float(jnp.mean(jnp.sum(
        pf * (jax.nn.log_softmax(lg_f, -1) - jax.nn.log_softmax(lg_i, -1)),
        -1)))
    agree = float((jnp.argmax(lg_f, -1) == jnp.argmax(lg_i, -1)).mean())
    rows.append(("table1/fq_vs_fp_logit_kl", 0.0, f"kl={kl:.5f}"))
    rows.append(("table1/fq_vs_fp_argmax_agreement", 0.0, f"acc={agree:.3f}"))
    return rows


# --- Table II: quantization ablation ------------------------------------------

def table2_ablation() -> List[Row]:
    import dataclasses
    from repro.configs import smoke_config
    from repro.core.policy import TABLE2_ROWS
    from repro.models import transformer as T

    rows: List[Row] = []
    base = smoke_config("bert-base")
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              base.vocab_size)
    ref_logits = None
    from repro.core.policy import POLICY_W8A8
    for name, pol in TABLE2_ROWS + [("w8a8 (Q8BERT pt)", POLICY_W8A8)]:
        cfg = dataclasses.replace(base, quant=pol)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        amax = T.init_amax(cfg)
        lg, obs, _ = T.forward(cfg, params, amax, toks)
        lg, _, _ = T.forward(cfg, params, obs, toks)  # calibrated pass
        if ref_logits is None:
            ref_logits = lg
            rows.append((f"table2/{name}", 0.0, "kl=0.0(reference)"))
            continue
        pf = jax.nn.softmax(ref_logits, -1)
        kl = float(jnp.mean(jnp.sum(pf * (
            jax.nn.log_softmax(ref_logits, -1) - jax.nn.log_softmax(lg, -1)),
            -1)))
        rows.append((f"table2/{name.replace(' ', '_')}", 0.0, f"kl={kl:.5f}"))
    return rows


# --- Table III: PE/BIM scaling analog (kernel tile sweep) -----------------------

def table3_kernel_scaling() -> List[Row]:
    from repro.core import packing as pk
    from repro.core import fixedpoint as fxp
    from repro.kernels import ref as R

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    M, K, N = 128, 768, 768  # BERT-base projection at seq 128
    x = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    codes = jnp.asarray(rng.integers(-8, 8, (K, N)), jnp.int8)
    wp = pk.pack_int4_planar(codes, axis=0)
    bias = jnp.zeros((N,), jnp.int32)
    Mq, sh = fxp.quantize_multiplier(0.001)
    f = jax.jit(lambda a, b: R.int4_matmul_ref(a, b, bias, jnp.int32(Mq),
                                               jnp.int32(sh)))
    us = _timeit(f, x, wp)
    rows.append(("table3/w4a8_768x768_xla", us, f"gops={2*M*K*N/us/1e3:.1f}"))
    f8 = jax.jit(lambda a, w: R.int8_bitsplit_matmul_ref(
        a, w, bias, jnp.int32(Mq), jnp.int32(sh)))
    w8 = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    us8 = _timeit(f8, x, w8)
    rows.append(("table3/w8a8_bitsplit_768x768_xla", us8,
                 f"gops={2*M*K*N/us8/1e3:.1f}"))
    # (N, M) analog: Pallas tile configs -> VMEM working set per grid step
    for bm, bn, bk2 in ((128, 128, 256), (256, 128, 256), (128, 256, 512)):
        vmem = bm * bk2 * 2 + bk2 * bn + bm * bn * 4 + bm * bn
        rows.append((f"table3/tile_bm{bm}_bn{bn}_bk2{bk2}", 0.0,
                     f"vmem_kb={vmem/1024:.0f}"))
    return rows


# --- Table IV: fp32 vs quantized latency (CPU analog of CPU/GPU/FPGA) -----------

def table4_latency() -> List[Row]:
    import dataclasses
    from repro.configs import get_config
    from repro.core.policy import POLICY_FP32
    from repro.models import transformer as T
    from repro.models import fold as F
    from repro.models import serve_int as S

    rows: List[Row] = []
    # paper operating point: BERT-base, seq 128, batch 1 — but at a reduced
    # depth so the CPU benchmark stays in seconds; latency scales linearly in
    # depth (scan), so report per-layer too.
    cfg = get_config("bert-base", n_layers=4, remat=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    amax = T.init_amax(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0,
                              cfg.vocab_size)
    cfg_fp = dataclasses.replace(cfg, quant=POLICY_FP32)
    fp = jax.jit(lambda p, a, t: T.forward(cfg_fp, p, a, t)[0])
    us_fp = _timeit(fp, params, amax, toks, iters=3)
    _, obs, _ = T.forward(cfg, params, amax, toks)
    folded = F.fold_params(cfg, params, obs)
    qt = jax.jit(lambda f, t: S.serve_forward(cfg, f, t, mode="prefill")[0])
    us_q = _timeit(qt, folded, toks, iters=3)
    rows.append(("table4/bert4L_fp32_cpu", us_fp, f"fps={1e6/us_fp:.2f}"))
    rows.append(("table4/bert4L_int_cpu", us_q, f"fps={1e6/us_q:.2f}"))
    rows.append(("table4/speedup", 0.0, f"x={us_fp/us_q:.2f}"))
    # bytes-moved proxy for fps/W (the paper's energy win is weight bytes)
    import numpy as _np
    p_bytes = sum(int(_np.prod(x.shape)) * 4 for x in jax.tree.leaves(params))
    f_bytes = sum(int(_np.prod(_np.asarray(x).shape)) * _np.asarray(x).dtype.itemsize
                  for x in jax.tree.leaves(folded))
    rows.append(("table4/weight_bytes_fp32", 0.0, f"mb={p_bytes/2**20:.1f}"))
    rows.append(("table4/weight_bytes_int", 0.0, f"mb={f_bytes/2**20:.1f}"))
    return rows
