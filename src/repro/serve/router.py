"""SLO-aware data-parallel router over N engine replicas.

The router is a thin, deterministic dispatch layer speaking the same
event-driven protocol as a single :class:`~repro.serve.engine.Engine`
(``submit`` / ``cancel`` / ``poll`` / ``has_work`` / ``stats``), so the
asyncio server and ``serve_bench.py`` drive either interchangeably.  Each
replica is an independent Engine (internally TP-sharded or not); the
router holds a bounded FIFO queue in front of them and makes one
admission decision per queued request per tick:

* **dispatch** when some replica is *admissible* — its ``stats()`` gauges
  show queue depth at or under ``max_replica_waiting``, prefill backlog
  at or under ``max_replica_chunks``, and (paged) at least
  ``min_free_pages`` pages free.  With ``affinity`` on (the default) the
  admissible set is first narrowed to the replicas whose prefix registry
  holds the longest chain for the request's leading page-aligned prompt
  chunk (the registry chain key is content-addressed, so the probe is an
  exact pages-held count, read-only through each replica's
  ``prefix_store``) — a conversation's turns stick to the replica that
  already paid for their shared prefix instead of recomputing it
  elsewhere.  Among the surviving candidates the least loaded wins,
  compared lexicographically on ``(waiting, prefill_chunks_pending,
  -pages_free, index)`` (:meth:`ReplicaRouter._least_loaded`) — the
  explicit replica-index tiebreak keeps placement deterministic and
  reproducible across runs, which is what makes a routed run
  token-identical to a single-engine run on the same trace and the
  affinity A/B compare like for like.  Placement never changes tokens
  (greedy decoding is batch-independent), so affinity preserves the
  identity contract while cutting redundant prefix prefills.
* **queue** when no replica is admissible: the head request waits (FIFO
  is never reordered — later requests do not jump the line).
* **shed** queued requests whose ``deadline_tick`` passes before
  dispatch, through the same CANCELLED/"deadline" exit the engine uses.
* **reject** at ``submit`` when the bounded queue is full —
  :class:`RouterBusy` is the backpressure signal the asyncio frontend
  turns into an HTTP-busy style error instead of letting the tail grow.

Ticks: ``poll()`` polls every replica exactly once, so for replicas
constructed fresh for this router (the supported configuration) replica
tick counters advance in lockstep with the router's own and
``deadline_tick`` means the same thing queued or dispatched.

Token identity holds for greedy requests (``temperature == 0``): a
replica computes the same tokens for a request regardless of which other
requests share its batch.  Sampled requests draw from per-replica PRNG
streams and are excluded from the contract, exactly as they are from the
single-engine identity benches.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional

import numpy as np

from repro.serve import stats as stats_schema
from repro.serve.engine import Request, RequestStatus, TokenEvent


class RouterBusy(RuntimeError):
    """Submission refused: the router's bounded queue is full."""


class RouterConfigError(ValueError):
    """A RouterConfig is invalid or incompatible with the replicas."""


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Typed, frozen router construction options, validated at
    construction like ``EngineConfig``.  The admissibility defaults
    dispatch eagerly (a replica with an empty queue and any free pages is
    admissible) and bound only the router queue; tighten them to shed
    earlier under overload.  ``affinity`` steers requests to the replica
    whose registry already holds their prefix chain (placement only —
    greedy outputs are unchanged); ``shared_tier`` additionally builds a
    :class:`~repro.serve.prefix.SharedPrefixTier` every paged tp=1
    replica publishes sealed chains to and adopts pages from."""
    max_queue: int = 64            # router queue bound (submit -> RouterBusy)
    max_replica_waiting: int = 0   # dispatch only if replica waiting <= this
    max_replica_chunks: int = 8    # ... and prefill_chunks_pending <= this
    min_free_pages: int = 1        # ... and pages_free >= this (paged only)
    affinity: bool = True          # prefix-affinity steering
    max_affinity_pages: int = 8    # probe at most this many leading pages
    shared_tier: bool = False      # cross-replica publish/adopt tier
    shared_tier_pages: int = 256   # tier LRU capacity (page payloads)
    shed_policy: str = "deadline"  # "deadline" sheds queued requests at
    #                                their deadline_tick; "none" never
    #                                sheds at the router (deadlines still
    #                                apply inside the replicas)

    @classmethod
    def from_kwargs(cls, **kw) -> "RouterConfig":
        """Build from keyword options; unknown names raise a TypeError
        listing the valid fields."""
        valid = [f.name for f in dataclasses.fields(cls)]
        unknown = sorted(set(kw) - set(valid))
        if unknown:
            raise TypeError(
                f"unknown router option(s) {', '.join(unknown)}; valid "
                f"RouterConfig fields: {', '.join(valid)}")
        return cls(**kw)

    def validate(self) -> "RouterConfig":
        def bad(msg):
            raise RouterConfigError(f"invalid RouterConfig: {msg}")
        if self.max_queue < 1:
            bad(f"max_queue must be >= 1 (got {self.max_queue})")
        if self.max_replica_waiting < 0 or self.max_replica_chunks < 0 \
                or self.min_free_pages < 0:
            bad("admissibility thresholds must be >= 0 (got "
                f"max_replica_waiting={self.max_replica_waiting}, "
                f"max_replica_chunks={self.max_replica_chunks}, "
                f"min_free_pages={self.min_free_pages})")
        if self.max_affinity_pages < 1:
            bad(f"max_affinity_pages must be >= 1 "
                f"(got {self.max_affinity_pages})")
        if self.shared_tier_pages < 1:
            bad(f"shared_tier_pages must be >= 1 "
                f"(got {self.shared_tier_pages})")
        if self.shed_policy not in ("deadline", "none"):
            bad(f"shed_policy must be deadline|none "
                f"(got {self.shed_policy!r})")
        return self


class ReplicaRouter:
    """Dispatch requests across engine replicas; see the module docstring
    for the admission policy.  Request ids handed out by the router are
    global; per-replica engine rids are internal."""

    def __init__(self, replicas: List, config: Optional[RouterConfig] = None,
                 **kw):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if kw:     # one-release deprecation shim for loose keywords
            if config is not None:
                raise TypeError(
                    "pass RouterConfig fields either as a config or as "
                    "keywords, not both")
            warnings.warn(
                "ReplicaRouter(replicas, max_queue=..., ...) keyword "
                "options are deprecated; pass ReplicaRouter(replicas, "
                "RouterConfig(...)) — this shim goes away next release",
                DeprecationWarning, stacklevel=2)
            config = RouterConfig.from_kwargs(**kw)
        self.replicas = list(replicas)
        self.config = (config or RouterConfig()).validate()
        self.prefix_tier = None
        if self.config.shared_tier:
            self.prefix_tier = self._build_tier()
        self.queue: List[tuple] = []       # [(grid, Request)] FIFO
        self.requests: Dict[int, Request] = {}   # live (queued + inflight)
        # per-replica engine-rid -> global-rid translation
        self._rev: List[Dict[int, int]] = [dict() for _ in self.replicas]
        self._next_rid = 0
        self._events: List[TokenEvent] = []
        self.counters = {k: 0 for k in stats_schema.ROUTER_COUNTERS}

    def _build_tier(self):
        """Construct the shared tier and attach it to every eligible
        replica (paged layout, single rank — TP per-rank publish slices
        are a tracked follow-up).  At least one replica must be eligible,
        else the tier could never hold a page."""
        from repro.serve.prefix import SharedPrefixTier
        eligible = [eng for eng in self.replicas
                    if getattr(eng, "layout", None) == "paged"
                    and getattr(eng, "mesh", None) is None]
        if not eligible:
            raise RouterConfigError(
                "RouterConfig(shared_tier=True) needs at least one paged "
                "tp=1 replica to publish/adopt prefix chains")
        sizes = {eng.page_size for eng in eligible}
        if len(sizes) > 1:
            raise RouterConfigError(
                f"shared_tier needs one page_size across replicas, "
                f"got {sorted(sizes)}")
        tier = SharedPrefixTier(page_size=sizes.pop(),
                                max_pages=self.config.shared_tier_pages)
        for eng in eligible:
            eng.attach_prefix_tier(tier)
        return tier

    # --- protocol: submit / cancel ---------------------------------------

    def submit(self, request: Request) -> int:
        if len(self.queue) >= self.config.max_queue:
            self.counters["rejected"] += 1
            raise RouterBusy(
                f"router queue full ({self.config.max_queue}); retry later")
        grid = self._next_rid
        self._next_rid += 1
        request.rid = grid
        request.status = RequestStatus.WAITING
        request.finish_reason = None
        request.out = None
        self.queue.append((grid, request))
        self.requests[grid] = request
        self.counters["submitted"] += 1
        return grid

    def cancel(self, grid: int) -> bool:
        """Cancel wherever the request lives.  Queued: terminal here, event
        on the next poll.  Dispatched: forwarded to the owning replica,
        whose terminal event flows back translated."""
        req = self.requests.get(grid)
        if req is None:
            return False
        for i, (g, _r) in enumerate(self.queue):
            if g == grid:
                del self.queue[i]
                self.requests.pop(grid)
                self._terminate(req, RequestStatus.CANCELLED, "cancelled")
                self.counters["cancelled"] += 1
                return True
        for i, rev in enumerate(self._rev):
            for erid, g in rev.items():
                if g == grid:
                    ok = self.replicas[i].cancel(erid)
                    if ok:
                        self.counters["cancelled"] += 1
                    return ok
        raise AssertionError(f"rid {grid} tracked but neither queued "
                             f"nor dispatched")

    def _terminate(self, req: Request, status: RequestStatus, reason: str):
        req.out = np.asarray([], np.int32)
        req.status = status
        req.finish_reason = reason
        self._events.append(TokenEvent(req.rid, None, 0, True, reason))

    # --- admission --------------------------------------------------------

    def _admissible(self, stats: Dict) -> bool:
        c = self.config
        if stats["waiting"] > c.max_replica_waiting:
            return False
        if stats["prefill_chunks_pending"] > c.max_replica_chunks:
            return False
        if "pages_free" in stats and stats["pages_free"] < c.min_free_pages:
            return False
        return True

    def _shed_expired(self):
        if self.config.shed_policy == "none":
            return
        t = self.counters["ticks"]
        for grid, req in [q for q in self.queue]:
            if req.deadline_tick is None or t < req.deadline_tick:
                continue
            self.queue.remove((grid, req))
            self.requests.pop(grid)
            self._terminate(req, RequestStatus.CANCELLED, "deadline")
            self.counters["shed_deadline"] += 1

    def _affinity_pages(self, eng, prompt: List[int]) -> int:
        """How many leading full pages of ``prompt`` the replica's prefix
        registry already holds (0 for contiguous-layout replicas).  Probes
        the replica's ``prefix_store`` read-only — no references are
        taken, no LRU state moves — capped at ``max_affinity_pages`` so
        hashing cost stays bounded on long prompts."""
        store = getattr(eng, "prefix_store", None)
        if store is None:
            return 0
        cap = min((len(prompt) - 1) // store.page_size,
                  self.config.max_affinity_pages)
        if cap <= 0:
            return 0
        return store.match(prompt, cap).n_pages

    @staticmethod
    def _least_loaded(snaps: List[Dict], cands: List[int]) -> int:
        """The least-loaded replica among ``cands``, compared
        lexicographically on ``(waiting, prefill_chunks_pending,
        -pages_free, replica_index)``.  The replica INDEX is the explicit
        final tiebreak: equally loaded replicas always resolve to the
        lowest index, never to dict/iteration order, so dispatch traces
        are reproducible run-to-run and the affinity A/B compares like
        for like."""
        return min(cands, key=lambda i: (
            snaps[i]["waiting"], snaps[i]["prefill_chunks_pending"],
            -snaps[i].get("pages_free", 0), i))

    def _dispatch(self):
        """Place queued requests head-first onto the least-loaded
        admissible replica — narrowed first, when ``affinity`` is on, to
        the replicas holding the longest registered chain for the head
        request's leading page-aligned prompt chunk; stop at the first
        head that doesn't fit (FIFO: nothing jumps the line)."""
        while self.queue:
            snaps = [eng.stats() for eng in self.replicas]
            cands = [i for i, s in enumerate(snaps) if self._admissible(s)]
            if not cands:
                return
            grid, req = self.queue[0]
            if self.config.affinity:
                prompt = [int(t) for t in
                          np.asarray(req.prompt).reshape(-1)]
                aff = {i: self._affinity_pages(self.replicas[i], prompt)
                       for i in cands}
                best = max(aff.values())
                if best > 0:
                    cands = [i for i in cands if aff[i] == best]
                    self.counters["affinity_hits"] += 1
                else:
                    self.counters["affinity_misses"] += 1
            i = self._least_loaded(snaps, cands)
            self.queue.pop(0)
            try:
                erid = self.replicas[i].submit(req)
            except ValueError as e:
                # the request can never run (too big for any replica built
                # like this one): FAILED, not retried elsewhere
                self.requests.pop(grid)
                req.rid = grid
                req.out = np.asarray([], np.int32)
                req.status = RequestStatus.FAILED
                req.finish_reason = f"error: {e}"
                self._events.append(
                    TokenEvent(grid, None, 0, True, req.finish_reason))
                continue
            req.rid = grid                 # engine stamped its local rid
            self._rev[i][erid] = grid
            self.counters["dispatched"] += 1

    # --- the tick ---------------------------------------------------------

    def poll(self) -> List[TokenEvent]:
        """One router tick: shed expired queued requests, dispatch while
        replicas are admissible, then poll every replica once and return
        the merged, rid-translated event stream."""
        self.counters["ticks"] += 1
        self._shed_expired()
        self._dispatch()
        events = self._events
        self._events = []
        for i, eng in enumerate(self.replicas):
            rev = self._rev[i]
            for e in eng.poll():
                grid = rev.get(e.rid)
                if grid is None:           # replica-local traffic, not ours
                    continue
                if e.final:
                    del rev[e.rid]
                    self.requests.pop(grid, None)
                    if e.finish_reason in ("length", "eos"):
                        self.counters["completed"] += 1
                events.append(dataclasses.replace(e, rid=grid))
        return events

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._events) \
            or any(rev for rev in self._rev) \
            or any(eng.has_work for eng in self.replicas)

    def stats(self) -> Dict:
        """Router gauges + counters wrapping each replica's payload;
        validated against the frozen ``repro.serve.stats`` schema."""
        s = {
            "schema_version": stats_schema.STATS_SCHEMA_VERSION,
            "queued": len(self.queue),
            "inflight": sum(len(rev) for rev in self._rev),
            "n_replicas": len(self.replicas),
            "replicas": [eng.stats() for eng in self.replicas],
            "shared_tier_pages": (0 if self.prefix_tier is None
                                  else self.prefix_tier.n_pages),
            "counters": dict(self.counters),
        }
        return stats_schema.validate_router_stats(s)
