"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --ckpt-dir /tmp/ck

``--smoke`` uses the reduced same-family config (CPU-runnable); without it
the full published config is used (needs a real TPU slice; the mesh comes
from make_production_mesh or the host mesh fallback).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeConfig, SHAPES
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quant-moments", action="store_true")
    ap.add_argument("--grad-compress", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.grad_compress:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant,
                                           grad_compress_bits=args.grad_compress))
    shape = SHAPES[args.shape]
    if args.seq_len or args.global_batch:
        shape = ShapeConfig("custom", args.seq_len or shape.seq_len,
                            args.global_batch or shape.global_batch, "train")
    if args.smoke and args.shape == "train_4k" and not args.seq_len:
        shape = ShapeConfig("smoke", 128, min(8, len(jax.devices()) * 4), "train")

    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    opt_cfg = AdamWConfig(lr=args.lr, quantize_moments=args.quant_moments)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, accum_steps=args.accum)

    def log(step, m):
        print(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
              f"gnorm {m['grad_norm']:.3f} {m['step_s']*1e3:.0f} ms",
              flush=True)

    train(cfg, shape, mesh, opt_cfg, tcfg, fsdp=not args.smoke, log_fn=log)


if __name__ == "__main__":
    main()
