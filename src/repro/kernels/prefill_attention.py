"""Paged chunked-prefill flash attention — the block-table-walking prefill
kernel of the token-budget serving loop.

A prefill chunk runs ``S`` queries at absolute positions ``[pos0, pos0+S)``
for each slot, attending causally over the slot's WHOLE logical KV chain
``[0, pos0+S)``.  The chain lives in the global int8 page pool; earlier
chunks and shared prefix pages were written by previous forwards.  Before
this kernel the TPU path gathered the chain into a contiguous HBM view and
called the q7 flash family on it — a full copy of the slot's KV per chunk.
Here the KV BlockSpec index map walks the slot's scalar-prefetched
block-table row instead, so each pool page is streamed into VMEM exactly
once per (head, q-block) and no gathered view ever materializes.

Grid = (slot, q head, q block, logical KV block).  Dead-block clamping is
the same trick as ``paged_decode_qattention``, with the causal frontier of
the current q block standing in for the decode slot's length: KV blocks
past ``(pos0 + (q_i+1)*bq - 1) // P`` re-address the frontier page — already
resident in VMEM — so the pipeliner issues no DMA for them and a chunk at a
small ``pos0`` genuinely pays only for the pages that exist so far.

Per KV block the datapath is exactly the paper's Softmax Core (int8 QK^T ->
int32 scores -> LUT Q0.7 numerators -> int8 P@V on the MXU) with the fp32
cross-block carry of ``flash_qattention``; it is BIT-EXACT against the
block-online oracle ``kernels/ref.py::paged_prefill_qattention_ref`` for
any page count and any q-block size (see the oracle's docstring for why
block-level causal skipping is an exact identity).

GQA: queries arrive ungrouped (B, H, S, D); the KV index map divides the q
head by the group size, so each page is shared by the whole group without
duplicating KV in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixedpoint as fxp
from repro.core.qsoftmax import LUT_SIZE, MASK_OFFSET
from repro.kernels.pallas_compat import CompilerParams, divisor_tile
from repro.kernels.quant_softmax import lut_lookup

NEG_INIT = -(1 << 30)


def _kv_load_i8(k_ref, v_ref, _b_i, _k_i):
    """Default int8 page load: the pool tile IS the code tile."""
    return k_ref[0, :, 0], v_ref[0, :, 0]


def prefill_kv_index_map(bq, psize, group):
    """KV BlockSpec index map shared by BOTH paged prefill kernels (int8
    and int4-packed): clamp dead logical blocks onto the q block's causal
    frontier, THEN translate through the block table — dead grid steps
    re-address a page already resident in VMEM, so the pipeliner skips the
    DMA.  Module-level so ``repro.analysis.pallas_lint`` can prove the
    returned page index stays inside the pool for every grid point (under
    the kernel's contract ``pos0 + sq <= nb * psize``)."""
    def kv_map(bb, hh, qi, ki, pos0s, btab):
        frontier = (pos0s[bb] + (qi + 1) * bq - 1) // psize
        return (btab[bb, jnp.minimum(ki, frontier)], 0, hh // group, 0)
    return kv_map


def _prefill_body(bq, psize, kv_load, pos0_ref, q_ref, k_ref, v_ref,
                  lut_ref, mi_ref, si_ref, inv_ref, osc_ref, o_ref,
                  m_scr, den_scr, acc_scr):
    b_i = pl.program_id(0)
    q_i = pl.program_id(2)
    k_i = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        den_scr[...] = jnp.zeros_like(den_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos0 = pos0_ref[b_i]
    # causal skip at q-block granularity: the block contributes only if its
    # first key position is <= the q block's last query position (skipped
    # blocks are exact identities of the online update — see the oracle)
    live = (k_i * psize) <= (pos0 + (q_i + 1) * bq - 1)

    @pl.when(live)
    def _block():
        q = q_ref[0, 0]                       # (bq, D) int8
        k, v = kv_load(k_ref, v_ref, b_i, k_i)   # (psize, D) int8 — one page
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.int32)  # (bq,P)
        qpos = pos0 + q_i * bq + \
            jax.lax.broadcasted_iota(jnp.int32, (bq, psize), 0)
        kpos = k_i * psize + \
            jax.lax.broadcasted_iota(jnp.int32, (bq, psize), 1)
        s = jnp.where(kpos <= qpos, s, s - MASK_OFFSET)
        lm = jnp.max(s, axis=-1, keepdims=True)
        m_old = m_scr[:, :1]
        m_new = jnp.maximum(m_old, lm)
        idx = jnp.clip(fxp.rescale(m_new - s, mi_ref[0], si_ref[0],
                                   out_bits=9), 0, LUT_SIZE - 1)
        num = lut_lookup(idx, lut_ref[...].astype(jnp.int32))      # Q0.7
        den_b = jnp.sum(num, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(num.astype(jnp.int8), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)  # (bq,D)
        f = jnp.exp((m_old - m_new).astype(jnp.float32) * inv_ref[0])
        f = jnp.where(m_old == NEG_INIT, 0.0, f)
        den_scr[...] = den_scr[...] * f + den_b.astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * f + pv.astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(k_i == nk - 1)
    def _epilogue():
        den = jnp.maximum(den_scr[:, :1], 1.0)
        o = acc_scr[...] / den * osc_ref[0]
        o_ref[0, 0] = jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


def _paged_prefill_kernel(bq, psize, pos0_ref, _btab_ref, *rest):
    # int8 pool: the block table is consumed only by the index map
    _prefill_body(bq, psize, _kv_load_i8, pos0_ref, *rest)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def paged_prefill_qattention(
    q_i8: jax.Array,          # int8 (B, H, S, D) — chunk queries, ungrouped
    k_pool: jax.Array,        # int8 (n_pages, P, Hkv, D) — global page pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # int32 (B, max_blocks): slot -> pool pages
    pos0: jax.Array,          # int32 (B,): page-aligned chunk start per slot
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, bq: int = 128, interpret: bool = False,
) -> jax.Array:
    """Chunked-prefill attention over the paged int8 KV cache: int8
    (B, H, S, D) context for queries at positions [pos0, pos0+S) attending
    over each slot's whole block-table chain.  The chunk's own K/V rows
    must already be scattered into the pool (the chunk forward writes
    before it attends, so intra-chunk causality falls out of the mask)."""
    b, h, sq, d = q_i8.shape
    psize = k_pool.shape[1]
    hkv = k_pool.shape[2]
    group = h // hkv
    nb = block_tables.shape[1]
    bq = divisor_tile(bq, sq)
    grid = (b, h, sq // bq, nb)
    kernel = functools.partial(_paged_prefill_kernel, bq, psize)
    kv_map = prefill_kv_index_map(bq, psize, group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # pos0, block_tables
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bb, hh, qi, ki, pos0s, btab: (bb, hh, qi, 0)),
            pl.BlockSpec((1, psize, 1, d), kv_map),
            pl.BlockSpec((1, psize, 1, d), kv_map),
            pl.BlockSpec((LUT_SIZE,),
                         lambda bb, hh, qi, ki, pos0s, btab: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d),
            lambda bb, hh, qi, ki, pos0s, btab: (bb, hh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.int32),    # running max (col-broadcast)
            pltpu.VMEM((bq, 128), jnp.float32),  # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(pos0, jnp.int32).reshape(-1),
      jnp.asarray(block_tables, jnp.int32),
      q_i8, k_pool, v_pool, lut_q7,
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1))


def _paged_prefill_q4_kernel(bq, psize, pos0_ref, btab_ref, q_ref, k_ref,
                             v_ref, lut_ref, ks_ref, vs_ref, mi_ref, si_ref,
                             inv_ref, osc_ref, o_ref, m_scr, den_scr,
                             acc_scr):
    from repro.kernels.decode_attention import dequant_kv_tile

    # int4-packed pool: dequantize the half-width page tile in VMEM under
    # its shared scale (a live block's index map loaded exactly page
    # btab[b, k], so btab_ref[b_i, k_i] names the scale of the loaded tile)
    def load(kr, vr, b_i, k_i):
        pg = btab_ref[b_i, k_i]
        return (dequant_kv_tile(kr[0, :, 0], ks_ref[pg]),
                dequant_kv_tile(vr[0, :, 0], vs_ref[pg]))

    _prefill_body(bq, psize, load, pos0_ref, q_ref, k_ref, v_ref, lut_ref,
                  mi_ref, si_ref, inv_ref, osc_ref, o_ref, m_scr, den_scr,
                  acc_scr)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def paged_prefill_qattention_q4(
    q_i8: jax.Array,          # int8 (B, H, S, D) — chunk queries, ungrouped
    k_pool: jax.Array,        # uint8 (n_pages, P, Hkv, D//2) — packed pool
    v_pool: jax.Array,
    k_scale: jax.Array,       # fp32 (n_pages,): shared dequant scale per page
    v_scale: jax.Array,
    block_tables: jax.Array,  # int32 (B, max_blocks): slot -> pool pages
    pos0: jax.Array,          # int32 (B,): page-aligned chunk start per slot
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, bq: int = 128, interpret: bool = False,
) -> jax.Array:
    """Chunked-prefill attention over the int4-PACKED page pool: the same
    grid/frontier clamping/datapath as ``paged_prefill_qattention``, with
    each pool page streamed HBM->VMEM at half the bytes and dequantized
    in-kernel under its shared fp32 page scale.  Bit-exact vs
    ``ref.py::paged_prefill_qattention_q4_ref``."""
    b, h, sq, d = q_i8.shape
    psize = k_pool.shape[1]
    hkv = k_pool.shape[2]
    dp = k_pool.shape[3]                          # D//2 packed bytes
    assert dp * 2 == d, (dp, d)
    group = h // hkv
    nb = block_tables.shape[1]
    bq = divisor_tile(bq, sq)
    grid = (b, h, sq // bq, nb)
    kernel = functools.partial(_paged_prefill_q4_kernel, bq, psize)
    kv_map = prefill_kv_index_map(bq, psize, group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # pos0, block_tables
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bb, hh, qi, ki, pos0s, btab: (bb, hh, qi, 0)),
            pl.BlockSpec((1, psize, 1, dp), kv_map),
            pl.BlockSpec((1, psize, 1, dp), kv_map),
            pl.BlockSpec((LUT_SIZE,),
                         lambda bb, hh, qi, ki, pos0s, btab: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),    # k page scales
            pl.BlockSpec(memory_space=pltpu.SMEM),    # v page scales
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d),
            lambda bb, hh, qi, ki, pos0s, btab: (bb, hh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.int32),    # running max (col-broadcast)
            pltpu.VMEM((bq, 128), jnp.float32),  # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(pos0, jnp.int32).reshape(-1),
      jnp.asarray(block_tables, jnp.int32),
      q_i8, k_pool, v_pool, lut_q7,
      jnp.asarray(k_scale, jnp.float32).reshape(-1),
      jnp.asarray(v_scale, jnp.float32).reshape(-1),
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1))
