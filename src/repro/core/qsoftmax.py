"""LUT-based fully-quantized softmax — paper §III-B "Softmax Core".

The paper's trick: softmax is shift-invariant, so subtract the row max first;
then exp(x - max) is always in (0, 1], and because the OUTPUT of exp is
quantized to 8 bits, a 256-entry lookup table covers the whole function.

Fixed-point semantics (shared bit-exactly by kernels/ref.py, the Pallas kernel
and this module):

  input   x_I : int32 codes with real value x = x_I / s_x
  1. m    = rowmax(x_I)
  2. d    = m - x_I                       (>= 0, int32)
  3. idx  = clamp(rescale(d, M_idx, sh),  0, 255)    # fixed-point d/s_x/DELTA
  4. num  = LUT[idx]                      (codes of exp(-idx*DELTA), Q0.8)
  5. den  = sum(num)                      (int32; >= 255 since max -> LUT[0])
  6. p_I  = clamp((num << 7 + den/2) // den, 0, 127)  (int8, scale 128)

LUT construction: LUT[i] = round(exp(-i*DELTA) * 255) with
DELTA = T / 255, T = 16*ln2 (so the table spans 16 octaves; entries underflow
to 0 well before the end).  LUT[255] is forced to 0 so that a saturated index
doubles as the attention-mask value: masked logits add -2^30 to d's input,
clamp to index 255, contribute exactly zero probability.

TPU note: the paper stores probabilities as 8-bit fixed point; the MXU's
integer dot is signed-8-bit, so the output code here is Q1.7 (scale 128,
max code 127) — one bit spent on sign, documented in DESIGN.md.  The P@V
accumulator then stays far inside int32 even at 500k context because the
codes sum to ~128 per row (probabilities sum to 1).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp

LUT_SIZE = 256
LUT_T = math.log(1024.0)            # table domain: exp(-t), t in [0, ln 1024];
                                    # entries past t ~ ln(510) quantize to 0, so
                                    # the whole 8-bit output range is covered with
                                    # the finest index step that still reaches 0

LUT_DELTA = LUT_T / (LUT_SIZE - 1)  # index step in real units
MASK_OFFSET = 1 << 30               # subtracted from masked logit codes


def make_exp_lut() -> np.ndarray:
    """(256,) int32 table of round(exp(-i*DELTA)*255); LUT[255] forced to 0."""
    i = np.arange(LUT_SIZE, dtype=np.float64)
    vals = np.round(np.exp(-i * LUT_DELTA) * 255.0).astype(np.int32)
    vals[-1] = 0
    return vals


def index_multiplier(s_x: float) -> Tuple[int, int]:
    """Fixed-point (M, shift) for idx = d / (s_x * DELTA).

    d is in code units (real = d / s_x); dividing by DELTA converts to table
    steps.  s_x is the scale of the softmax INPUT (logits), typically
    s_q * s_k / sqrt(head_dim) folded together.
    """
    return fxp.quantize_multiplier(1.0 / (s_x * LUT_DELTA))


def quant_softmax(
    x_int: jax.Array,
    M_idx: jax.Array,
    shift_idx: jax.Array,
    lut: jax.Array,
    mask: jax.Array | None = None,
    axis: int = -1,
) -> jax.Array:
    """Reference (pure-jnp) fully-quantized softmax.  Returns uint8-coded
    probabilities (stored int32 for downstream matmul convenience), scale 256.

    ``mask``: optional boolean, True = attend, False = masked out.
    """
    x_int = x_int.astype(jnp.int32)
    if mask is not None:
        # masked positions become "infinitely far below the max"
        x_int = jnp.where(mask, x_int, x_int - MASK_OFFSET)
    m = jnp.max(x_int, axis=axis, keepdims=True)
    d = (m - x_int).astype(jnp.int32)             # >= 0
    idx = fxp.rescale(d, M_idx, shift_idx, out_bits=9)
    idx = jnp.clip(idx, 0, LUT_SIZE - 1)
    num = jnp.take(lut.astype(jnp.int32), idx)    # Q0.8 codes
    den = jnp.sum(num, axis=axis, keepdims=True)
    den = jnp.maximum(den, 1)
    p = (num * 128 + den // 2) // den
    return jnp.clip(p, 0, 127).astype(jnp.int8)


SOFTMAX_OUT_SCALE = 128.0  # p_real = p_I / 128
