"""The PrefixStore subsystem: one conformance suite over BOTH
implementations (the allocator-owned ``RegistryPrefixStore`` and the
cross-replica ``SharedPrefixTier``), the tier's payload roundtrip / LRU
mechanics as pure unit tests, and the engine-level contract the tentpole
rests on: a replica that ADOPTS a published chain emits greedy tokens
bit-identical to a cold replica that prefills everything itself, while
running strictly less prefill work.

The unit half needs no model; the engine half shares one module-scoped
folded checkpoint like the other serve test files.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import Engine, EngineConfig, EngineConfigError, \
    Request
from repro.serve.prefix import (RegistryPrefixStore, SealedChain,
                                SharedPrefixTier, chain_keys)

KEY = jax.random.PRNGKey(0)
PS = 4          # page size for all unit tests


# --- helpers -------------------------------------------------------------

def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(0, 1000, (n,))]


def _sealed(tokens, n_pages, seed=0):
    """A payload-backed chain over ``tokens`` with deterministic fake
    pool bytes: two leaves shaped like real pool leaves (page axis 1)."""
    pairs = list(chain_keys(tokens, PS, n_pages))
    keys = tuple(k for k, _ in pairs)
    segs = tuple(s for _, s in pairs)
    rng = np.random.default_rng(seed)
    payload = {
        "kv": rng.integers(-128, 128, (2, n_pages, PS, 3), dtype=np.int8),
        "scale": rng.random((2, n_pages, 1)).astype(np.float32),
    }
    return SealedChain(PS, keys, segs, payload)


def _make_registry():
    store = RegistryPrefixStore(PS)

    def populate(tokens, n_pages):
        return store.register(tokens[:n_pages * PS],
                              list(range(100, 100 + n_pages)))
    return store, populate


def _make_tier():
    store = SharedPrefixTier(PS, max_pages=64)

    def populate(tokens, n_pages):
        return store.publish(_sealed(tokens, n_pages))
    return store, populate


@pytest.fixture(params=["registry", "tier"])
def store_populate(request):
    return (_make_registry if request.param == "registry"
            else _make_tier)()


# --- chain_keys: the one shared key definition ---------------------------

def test_chain_keys_deterministic_and_cumulative():
    toks = _toks(16)
    a = list(chain_keys(toks, PS, 4))
    b = list(chain_keys(toks, PS, 4))
    assert a == b                               # deterministic
    assert len(a) == 4
    assert len({k for k, _ in a}) == 4          # keys distinct per depth
    # key_i commits to the WHOLE prefix: flip a token in page 0 and every
    # downstream key changes, not just page 0's
    mut = list(toks)
    mut[1] += 1
    c = list(chain_keys(mut, PS, 4))
    assert all(ka != kc for (ka, _), (kc, _) in zip(a, c))
    # same page-3 segment under a different prefix gets a different key
    assert a[3][1] == c[3][1] and a[3][0] != c[3][0]


# --- conformance: laws every PrefixStore obeys ---------------------------

def test_conformance_fresh_store_is_lawfully_empty(store_populate):
    store, _ = store_populate
    assert store.page_size == PS and store.version == 0
    chain = store.match(_toks(16))
    assert chain.n_pages == 0 and chain.rows == 0 and chain.tokens() == []
    assert store.seal(_toks(16)).n_pages == 0
    assert store.adopt(_toks(16)) is None


def test_conformance_match_longest_chain_and_caps(store_populate):
    store, populate = store_populate
    toks = _toks(16)
    populate(toks, 3)
    full = store.match(toks)
    assert full.n_pages == 3 and full.rows == 3 * PS
    assert full.tokens() == toks[:12]
    assert list(full.keys) == [k for k, _ in chain_keys(toks, PS, 3)]
    # max_pages caps the walk; shorter token runs match fewer full pages
    assert store.match(toks, max_pages=2).n_pages == 2
    assert store.match(toks[:9]).n_pages == 2
    assert store.match(toks[:3]).n_pages == 0
    # a mismatched token truncates the chain AT ITS PAGE, not after it
    mut = list(toks)
    mut[5] += 1                                 # inside page 1
    assert store.match(mut).n_pages == 1


def test_conformance_match_is_readonly_and_populate_idempotent(
        store_populate):
    store, populate = store_populate
    toks = _toks(16)
    assert populate(toks, 3) == 3               # three pages newly stored
    v = store.version
    assert v >= 3
    for _ in range(3):                          # match never mutates
        store.match(toks)
        store.seal(toks)
    assert store.version == v
    assert populate(toks, 3) == 0               # re-store is a no-op
    assert store.version == v


# --- RegistryPrefixStore specifics (the allocator-side surface) ----------

def test_registry_register_skips_known_keys_and_bound_pages():
    store = RegistryPrefixStore(PS)
    toks = _toks(16)
    assert store.register(toks[:8], [10, 11]) == 2
    # same chain, different pages: keys known, nothing re-bound
    assert store.register(toks[:8], [20, 21]) == 0
    # a page already bound to one key cannot serve a second chain
    other = _toks(16, seed=9)
    assert store.register(other[:8], [10, 30]) == 1
    assert store.match(other).n_pages == 0      # chain broke at page 0
    assert store.cached_count == 3
    store.check_invariants()


def test_registry_park_revive_reclaim_cycle():
    store = RegistryPrefixStore(PS)
    toks = _toks(16)
    store.register(toks[:12], [5, 6, 7])
    for p in (5, 6, 7):
        assert store.is_registered(p)
        store.park(p)
    assert store.lru_count == 3 and store.lru_pages == frozenset({5, 6, 7})
    store.revive(6)
    assert store.lru_pages == frozenset({5, 7})
    # reclaim pops OLDEST parked first and forgets its registry entry —
    # page 5 is the chain head, so the whole chain stops matching even
    # though pages 6/7 stay registered (stranded tail, never stale data)
    assert store.pop_reclaim() == 5
    assert not store.is_registered(5) and store.cached_count == 2
    assert store.match(toks).n_pages == 0
    assert store.pop_reclaim() == 7
    assert store.pop_reclaim() is None          # page 6 is revived, not LRU
    store.check_invariants()


def test_registry_publish_adopt_are_lawful_noops():
    store = RegistryPrefixStore(PS)
    toks = _toks(16)
    store.register(toks[:12], [1, 2, 3])
    assert store.publish(_sealed(toks, 3)) == 0
    assert store.adopt(toks) is None            # no host payloads behind it


# --- SharedPrefixTier specifics (payload roundtrip + LRU bound) ----------

def test_tier_publish_adopt_payload_roundtrip():
    tier = SharedPrefixTier(PS, max_pages=16)
    toks = _toks(16)
    sealed = _sealed(toks, 4)
    assert tier.publish(sealed) == 4
    assert tier.n_pages == 4 and tier.version == 4
    got = tier.adopt(toks)
    assert got is not None and got.keys == sealed.keys
    assert got.segs == sealed.segs
    for leaf in sealed.payload:                 # byte-exact roundtrip
        assert np.array_equal(got.payload[leaf], sealed.payload[leaf])
    # partial adoption: cap and shorter prompts slice the chain
    assert tier.adopt(toks, max_pages=2).n_pages == 2
    assert tier.adopt(toks[:9]).n_pages == 2
    assert tier.adopt(_toks(16, seed=3)) is None
    # slice() composes with adoption the way the engine installs tails
    tail = got.slice(1, 3)
    assert tail.keys == sealed.keys[1:3]
    assert np.array_equal(tail.payload["kv"], sealed.payload["kv"][:, 1:3])
    tier.check_invariants()


def test_tier_publish_dedups_and_page_size_guard():
    tier = SharedPrefixTier(PS, max_pages=16)
    toks = _toks(16)
    assert tier.publish(_sealed(toks, 3)) == 3
    assert tier.publish(_sealed(toks, 3)) == 0  # known keys skipped
    assert tier.n_pages == 3
    with pytest.raises(ValueError, match="page_size"):
        tier.publish(SealedChain(PS + 1, (1,), ((0,),),
                                 {"kv": np.zeros((2, 1, PS + 1, 3))}))


def test_tier_lru_eviction_and_recency_refresh():
    tier = SharedPrefixTier(PS, max_pages=3)
    a, b = _toks(8, seed=1), _toks(4, seed=2)
    tier.publish(_sealed(a, 2, seed=1))         # pages: a0 a1
    tier.publish(_sealed(b, 1, seed=2))         # pages: a0 a1 b0
    assert tier.n_pages == 3
    assert tier.adopt(a).n_pages == 2           # adoption refreshes a0/a1
    tier.publish(_sealed(_toks(4, seed=5), 1, seed=5))
    assert tier.n_pages == 3                    # bound held: b0 evicted
    assert tier.adopt(b) is None
    assert tier.adopt(a).n_pages == 2           # survivors intact
    tier.check_invariants()


def test_tier_head_eviction_strands_tail_safely():
    tier = SharedPrefixTier(PS, max_pages=2)
    toks = _toks(12, seed=4)
    tier.publish(_sealed(toks, 3, seed=4))      # 3 pages into capacity 2:
    assert tier.n_pages == 2                    # head page evicted on entry
    # adoption walks from key 0 and stops at the first miss — a stranded
    # tail wastes capacity until it ages out but never serves wrong bytes
    assert tier.adopt(toks) is None
    tier.check_invariants()


def test_tier_register_is_a_lawful_noop():
    tier = SharedPrefixTier(PS)
    assert tier.register(_toks(8), [1, 2]) == 0
    assert tier.n_pages == 0 and tier.version == 0


# --- engine level: publish/adopt preserve token identity -----------------

@pytest.fixture(scope="module")
def folded_cfg():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def _paged_cfg(**kw):
    base = dict(batch_slots=2, max_len=64, cache_layout="paged", page_size=4)
    base.update(kw)
    return EngineConfig(**base)


def _run_one(eng, prompt, max_new=6):
    req = Request(prompt=np.asarray(prompt, np.int32), max_new_tokens=max_new)
    eng.submit(req)
    ticks = 0
    while eng.has_work:
        assert ticks < 500, "engine livelocked"
        ticks += 1
        eng.poll()
        eng.stats(check=True)
    return req.result().tolist()


def test_adopted_chain_bit_identical_to_cold_replica(folded_cfg):
    """Engine A publishes its prefilled chain; engine B adopts it and must
    emit byte-identical greedy tokens to cold engine C — while running
    strictly less prefill work (the whole point of the tier)."""
    cfg, folded = folded_cfg
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (18,)).astype(np.int32)

    cold = Engine(cfg, folded, _paged_cfg())
    truth = _run_one(cold, prompt)
    cold_prefill = cold.counters["prefill_tokens"]

    tier = SharedPrefixTier(page_size=4)
    a = Engine(cfg, folded, _paged_cfg())
    a.attach_prefix_tier(tier)
    assert _run_one(a, prompt) == truth
    n_chain = (len(prompt) - 1) // 4            # registered = published = 4
    assert a.counters["published_pages"] == n_chain
    assert tier.n_pages == n_chain

    b = Engine(cfg, folded, _paged_cfg())
    b.attach_prefix_tier(tier)
    assert _run_one(b, prompt) == truth         # bit-identical via adoption
    assert b.counters["adopted_pages"] == n_chain
    assert b.counters["prefix_hits"] == 1
    assert b.counters["shared_rows"] == n_chain * 4
    assert b.counters["suffix_prefills"] == 1
    assert b.counters["prefill_tokens"] < cold_prefill
    # B re-publishing its (tier-sourced) chain dedups to zero new pages
    assert b.counters["published_pages"] == 0
    assert b.alloc.live == 0
    b.stats(check=True)


def test_tier_survives_source_registry_reclaim(folded_cfg):
    """LRU reclaim on the PUBLISHING replica must not invalidate the tier:
    the host copies outlive the source's pool pages, and an adopter still
    gets byte-identical outputs after the source forgot everything."""
    cfg, folded = folded_cfg
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab_size, (18,)).astype(np.int32)

    cold = Engine(cfg, folded, _paged_cfg())
    truth = _run_one(cold, prompt)

    tier = SharedPrefixTier(page_size=4)
    a = Engine(cfg, folded, _paged_cfg())
    a.attach_prefix_tier(tier)
    assert _run_one(a, prompt) == truth
    assert tier.n_pages == 4
    # drain A's pool through the allocator: every parked registered page
    # is reclaimed (registry forgets it), exactly like cache pressure
    taken = a.alloc.alloc(a.alloc.available())
    assert taken is not None
    assert a.alloc.prefix.cached_count == 0
    a.alloc.free_pages(taken)
    a.stats(check=True)
    assert tier.n_pages == 4                    # host copies unaffected

    b = Engine(cfg, folded, _paged_cfg())
    b.attach_prefix_tier(tier)
    assert _run_one(b, prompt) == truth
    assert b.counters["adopted_pages"] == 4


def test_adoption_skipped_gracefully_under_pool_pressure(folded_cfg):
    """A dry pool must turn adoption into a no-op (recompute), never a
    preemption or a crash: the engine waits for pages like any admission,
    then either adopts or prefills — outputs identical either way."""
    cfg, folded = folded_cfg
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, (18,)).astype(np.int32)

    cold = Engine(cfg, folded, _paged_cfg())
    truth = _run_one(cold, prompt)

    tier = SharedPrefixTier(page_size=4)
    a = Engine(cfg, folded, _paged_cfg())
    a.attach_prefix_tier(tier)
    assert _run_one(a, prompt) == truth

    b = Engine(cfg, folded, _paged_cfg())
    b.attach_prefix_tier(tier)
    hold = b.alloc.alloc(b.alloc.available())   # pool completely dry
    req = Request(prompt=prompt.copy(), max_new_tokens=6)
    b.submit(req)
    b.poll()                                    # adoption skips, no crash
    assert b.counters["adopted_pages"] == 0
    b.alloc.free_pages(hold)
    ticks = 0
    while b.has_work:
        assert ticks < 500
        ticks += 1
        b.poll()
        b.stats(check=True)
    assert req.result().tolist() == truth
    assert b.alloc.live == 0


def test_attach_prefix_tier_rejects_incompatible_engines(folded_cfg):
    cfg, folded = folded_cfg
    contiguous = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="contiguous"))
    with pytest.raises(EngineConfigError, match="paged"):
        contiguous.attach_prefix_tier(SharedPrefixTier(page_size=4))
    paged = Engine(cfg, folded, _paged_cfg())
    with pytest.raises(EngineConfigError, match="page_size"):
        paged.attach_prefix_tier(SharedPrefixTier(page_size=8))
    assert paged.prefix_tier is None and contiguous.prefix_store is None
