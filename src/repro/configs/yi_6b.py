"""Yi-6B  [arXiv:2403.04652] — llama-arch GQA."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11_008, vocab_size=64_000,
    rope_theta=5_000_000.0, param_dtype="bfloat16",
))
