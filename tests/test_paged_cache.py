"""BlockAllocator + paged Scheduler unit and property tests.

Pure-python bookkeeping: page refcounts, prefix registry, LRU reclaim,
FIFO-preserving admission stalls.  No model or jax required.
"""
import collections
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency (requirements-dev.txt).  Without it
    # the property tests are skipped but every deterministic test still runs,
    # so the tier-1 suite collects cleanly in minimal environments.
    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install -r "
                   "requirements-dev.txt)")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies`` at decoration time only."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.serve.scheduler import BlockAllocator, Scheduler


@dataclasses.dataclass
class Req:
    prompt: np.ndarray
    max_new_tokens: int = 4


def _req(tokens, max_new=4):
    return Req(prompt=np.asarray(tokens, np.int32), max_new_tokens=max_new)


# --- allocator unit tests -----------------------------------------------------

def test_alloc_exhaustion_and_free_returns_pages():
    al = BlockAllocator(n_pages=5, page_size=4)   # 4 allocatable (page 0 trash)
    assert al.capacity == 4
    a = al.alloc(3)
    assert a is not None and len(a) == 3 and 0 not in a
    assert al.alloc(2) is None                    # over capacity: no partial
    assert al.available() == 1 and al.live == 3
    al.free_pages(a[:2])
    assert al.available() == 3
    b = al.alloc(2)
    assert b is not None and set(b) <= set(a[:2]) | {4}
    assert al.peak_live == 3


def test_refcounted_shared_pages_stay_while_sharer_live():
    al = BlockAllocator(n_pages=6, page_size=2)
    prompt = [1, 2, 3, 4, 5]                      # 2 full pages + 1 tail row
    owner = al.alloc(3)
    al.register_prefix(prompt, owner)             # registers pages 0..1 only
    shared = al.match_prefix(prompt, (len(prompt) - 1) // 2)
    assert shared == owner[:2]
    assert al.ref[shared[0]] == 2
    al.free_pages(owner)                          # owner evicted first
    # sharer still holds the prefix pages: they must NOT be reallocatable
    assert al.ref[shared[0]] == 1 and al.ref[shared[1]] == 1
    grabbed = al.alloc(al.available())
    assert grabbed is not None and not (set(grabbed) & set(shared))
    al.free_pages(grabbed)
    al.free_pages(shared)                         # last sharer gone
    # registered pages become LRU-cached (still matchable), not free-listed
    again = al.match_prefix(prompt, 2)
    assert again == shared


def test_lru_reclaim_under_pressure_invalidates_registry():
    al = BlockAllocator(n_pages=4, page_size=2)
    prompt = [7, 8, 9]
    pages = al.alloc(2)
    al.register_prefix(prompt, pages)
    al.free_pages(pages)                          # rc 0, cached on the LRU
    assert al.match_prefix(prompt, 1) == pages[:1]
    al.free_pages(pages[:1])
    got = al.alloc(3)                             # needs every pool page
    assert got is not None and len(got) == 3
    assert al.match_prefix(prompt, 1) == []       # registry entry reclaimed


def test_ensure_exclusive_cow():
    al = BlockAllocator(n_pages=5, page_size=2)
    prompt = [1, 2, 3]
    chain = al.alloc(1)
    al.register_prefix(prompt, chain)
    shared = al.match_prefix(prompt, 1)           # rc -> 2
    pages = list(shared)
    page, copy_src = al.ensure_exclusive(pages, 0)
    assert copy_src == shared[0] and page != shared[0]   # fresh copy target
    # the caller still holds its reference on the copy source until the row
    # copy lands; dropping it afterwards is the caller's job
    assert al.ref[page] == 1 and al.ref[copy_src] == 2
    al.free_pages([copy_src])                     # "copy done"
    assert al.ref[copy_src] == 1                  # registry owner remains
    # exclusive unregistered page: no copy needed
    mine = al.alloc(1)
    page2, src2 = al.ensure_exclusive(mine, 0)
    assert page2 == mine[0] and src2 is None
    al.check_invariants()


def test_ensure_exclusive_source_not_reallocatable_before_copy():
    """Regression (use-after-free): ensure_exclusive used to drop the
    caller's reference on the copy source before returning it, so on a
    nearly-full pool a refcount-1 REGISTERED source parked on the LRU and
    the next allocation — e.g. a concurrent slot's growth, or the very CoW
    of another page — could reclaim and overwrite it before its rows were
    copied.  The source must stay pinned until the caller frees it."""
    al = BlockAllocator(n_pages=3, page_size=2)   # 2 allocatable pages
    prompt = [5, 6, 7]
    chain = al.alloc(1)
    al.register_prefix(prompt, chain)             # page registered
    al.free_pages(chain)                          # rc 0: parked on the LRU
    held = al.match_prefix(prompt, 1)             # revived, rc 1 — but still
    assert held == chain                          # registered => CoW needed
    pages = list(held)
    page, copy_src = al.ensure_exclusive(pages, 0)
    assert copy_src == chain[0] and page != chain[0]
    # mid-CoW, the pool is now FULL (source + fresh page).  Any allocation
    # must fail rather than hand the pending copy source back out.
    assert al.alloc(1) is None
    assert al.ref[copy_src] == 1                  # still pinned
    al.check_invariants()
    al.free_pages([copy_src])                     # copy done: rc 0 -> LRU
    grabbed = al.alloc(1)                         # NOW it may be reclaimed
    assert grabbed == [copy_src]
    al.check_invariants()


def test_bytes_per_page_accounting():
    """Observational byte accounting: pool_bytes reflects the per-page
    footprint the engine reports (int8 rows vs nibble-packed rows + two
    fp32 per-page scales)."""
    al8 = BlockAllocator(n_pages=5, page_size=4, bytes_per_page=2048)
    al4 = BlockAllocator(n_pages=5, page_size=4, bytes_per_page=1040)
    assert al8.pool_bytes == 4 * 2048              # capacity excludes trash
    assert al4.pool_bytes == 4 * 1040
    # same byte budget fits >= 1.5x more packed pages
    assert al8.bytes_per_page >= 1.5 * al4.bytes_per_page
    assert BlockAllocator(n_pages=5, page_size=4).pool_bytes is None


def test_ensure_exclusive_cow_moves_scale_with_payload():
    """kv4 pages are (packed payload, per-page scale) pairs named by ONE
    page id, so allocator-level CoW moves both or neither by construction.
    Model the pool as parallel payload/scale stores keyed by page id and
    replay the engine's CoW dance: after the copy lands, the fresh page
    must carry the source's payload AND scale, and the registered source
    must be untouched."""
    al = BlockAllocator(n_pages=5, page_size=2, bytes_per_page=1040)
    payload = {p: None for p in range(1, 5)}
    scale = {p: 1.0 / 7 for p in range(1, 5)}      # trash-scale default
    chain = al.alloc(1)
    payload[chain[0]] = b"packed-nibble-rows"
    scale[chain[0]] = 0.42
    prompt = [1, 2, 3]
    al.register_prefix(prompt, chain)
    shared = al.match_prefix(prompt, 1)            # rc -> 2: CoW required
    pages = list(shared)
    page, copy_src = al.ensure_exclusive(pages, 0)
    assert copy_src == chain[0] and page != copy_src
    # the copy the engine performs: payload and scale travel together —
    # there is no path that copies rows without the page's scale
    payload[page] = payload[copy_src]
    scale[page] = scale[copy_src]
    al.free_pages([copy_src])                      # copy done, drop pin
    assert payload[page] == b"packed-nibble-rows" and scale[page] == 0.42
    assert payload[copy_src] == b"packed-nibble-rows"
    assert scale[copy_src] == 0.42                 # source untouched
    al.check_invariants()


# --- scheduler + allocator ----------------------------------------------------

def _paged_sched(n_slots, n_pages, page_size):
    al = BlockAllocator(n_pages, page_size)
    return Scheduler(n_slots, allocator=al), al


def test_admission_waits_when_pool_exhausted_fifo_preserved():
    sched, al = _paged_sched(n_slots=3, n_pages=5, page_size=4)
    # head request needs 3 pages ((8 + 4 - 1)/4), the pool has 4
    sched.submit(_req(range(100, 108), max_new=4))    # rid 0: 3 pages
    sched.submit(_req(range(200, 208), max_new=4))    # rid 1: 3 pages
    sched.submit(_req([1], max_new=2))                # rid 2: 1 page
    placed = sched.admit()
    # rid 0 seats; rid 1 stalls on pages; rid 2 must NOT jump the queue
    assert [st.rid for _, st in placed] == [0]
    assert [rid for rid, _ in sched.waiting] == [1, 2]
    assert sched.admit() == []                        # still stalled
    st0 = sched.evict(0)                              # completion frees pages
    assert all(al.ref[p] == 0 for p in st0.pages)
    placed = sched.admit()
    assert [st.rid for _, st in placed] == [1, 2]     # FIFO across the stall


def test_eviction_returns_pages_to_free_list():
    sched, al = _paged_sched(n_slots=1, n_pages=5, page_size=4)
    sched.submit(_req(range(10), max_new=3))
    (b, st), = sched.admit()
    assert al.live == len(st.pages) == 3
    sched.evict(b)
    assert al.live == 0 and al.available() == 4


def test_admission_maps_shared_prefix_pages():
    sched, al = _paged_sched(n_slots=2, n_pages=9, page_size=2)
    prompt = list(range(50, 57))                      # 7 tokens, 3 full pages
    sched.submit(_req(prompt, max_new=2))
    (b0, st0), = sched.admit()
    al.register_prefix([int(t) for t in st0.request.prompt], st0.pages)
    sched.submit(_req(prompt, max_new=2))
    (b1, st1), = sched.admit()
    assert st1.shared_rows == 6                       # (7-1)//2 pages shared
    assert st1.pages[:3] == st0.pages[:3]
    assert all(al.ref[p] == 2 for p in st1.pages[:3])
    sched.evict(b0)
    assert all(al.ref[p] == 1 for p in st1.pages[:3])  # sharer keeps them


# --- allocator invariants (property test) -------------------------------------

@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4)), min_size=1,
                max_size=60), st.integers(4, 9))
@settings(max_examples=50, deadline=None)
def test_allocator_invariants_random_traffic(ops, n_pages):
    """Random alloc/free traffic: no page is ever handed out twice, the
    trash page is never allocated, and free+cached+live always partitions
    the pool."""
    al = BlockAllocator(n_pages=n_pages, page_size=4)
    chains = []
    for is_alloc, n in ops:
        if is_alloc:
            got = al.alloc(n)
            if got is None:
                assert al.available() < n         # refusal only when short
            else:
                assert 0 not in got
                chains.append(got)
        elif chains:
            al.free_pages(chains.pop())
        held = [p for c in chains for p in c]
        assert len(held) == len(set(held))        # exclusive ownership
        assert al.live == len(held)
        assert len(al.free) + al.lru_pages + al.live == al.capacity
        assert al.peak_live >= al.live
        al.check_invariants()


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 3)), min_size=1,
                max_size=80), st.integers(4, 9))
@settings(max_examples=60, deadline=None)
def test_allocator_invariants_with_registry_traffic(ops, n_pages):
    """Random interleaving of alloc / free / register_prefix /
    match_prefix REVIVAL against concurrent LRU reclaim (the revival path
    resurrects refcount-0 cached pages while allocation pressure is
    popping that same LRU): every step must hold the full pool partition
    and the registry bijection, matched chains must stay exclusively
    owned or refcounted, and a revived page must never be concurrently
    handed out by alloc."""
    al = BlockAllocator(n_pages=n_pages, page_size=2)
    chains = []          # (pages, prompt_or_None) we hold references on
    prompts = []         # registered prompts that may still be cached
    tok = iter(range(10_000))

    for op, n in ops:
        if op == 0:                                   # alloc a fresh chain
            got = al.alloc(n)
            if got is None:
                assert al.available() < n
            else:
                chains.append((got, None))
        elif op == 1 and chains:                      # drop one reference
            pages, _ = chains.pop()
            al.free_pages(pages)
        elif op == 2 and chains:                      # register newest chain
            pages, registered = chains[-1]
            if registered is None:
                prompt = [next(tok) for _ in range(len(pages) * 2 + 1)]
                al.register_prefix(prompt, pages)
                chains[-1] = (pages, prompt)
                prompts.append((prompt, pages))
        elif op == 3 and prompts:                     # revive via match
            prompt, pages = prompts[n % len(prompts)]
            matched = al.match_prefix(prompt, len(pages))
            # a hit must be a prefix of the original chain; a miss means
            # reclaim got there first — both legal, never a third thing
            assert matched == pages[:len(matched)]
            if matched:
                chains.append((matched, None))
        held = collections.Counter(
            p for pages, _ in chains for p in pages)
        assert al.live == len(held)
        for p, k in held.items():
            assert al.ref[p] == k, f"page {p}: ref {al.ref[p]} != held {k}"
        al.check_invariants()

    for pages, _ in chains:                           # full teardown
        al.free_pages(pages)
    assert al.live == 0
    al.check_invariants()


# --- chunk planning anti-starvation (pure scheduler simulation) ---------------

def _drive_chunk_ticks(sched, n_ticks, n_decode=0, on_complete=None):
    """Mimic the engine's per-tick chunk loop: pick chunks until the budget
    is spent, advance cursors, update starvation counters, evict completed
    slots (``on_complete`` refills the queue)."""
    for _ in range(n_ticks):
        sched.admit()
        used, chunked = 0, set()
        while True:
            plan = sched.next_chunk(n_decode, used, frozenset(chunked))
            if plan is None:
                break
            b, st, pos0, take = plan
            st.prefill_pos = pos0 + take
            st.chunks_done += 1
            chunked.add(b)
            used += take + (st.prefill_pos >= st.prompt_len)
        for b in sched.prefilling:
            st = sched.slots[b]
            st.starved_ticks = 0 if b in chunked else st.starved_ticks + 1
        for b in list(sched.decoding):      # prefill done -> pretend EOS
            sched.evict(b)
            if on_complete is not None:
                on_complete()


@pytest.mark.parametrize("budget,n_decode", [
    (16, 0),      # reservation regime: head gets its page every tick
    (8, 0),       # one-page budget: starved-head override alternates
    (8, 1),       # decode eats the whole budget: override must FORCE a
                  # chunk (reordering alone would stall the head for the
                  # decoding slot's entire lifetime)
])
def test_chunked_head_of_line_not_starved_by_short_stream(budget, n_decode):
    """A steady stream of short prompts (or a budget permanently consumed
    by decode tokens) must not starve the head-of-line long prompt: with
    budget >= 2 pages the page reservation holds against LATER short picks
    too (the reservation is gated on the tick-start budget, not the
    remaining budget a second short sees); under a tighter budget the
    starved-head override forces one page every third tick."""
    al = BlockAllocator(n_pages=64, page_size=8)
    # 3 slots: the long head + TWO short slots, so a second short pick in
    # the same tick is what would eat the head's reserved page if the
    # reservation were gated on the remaining (not tick-start) budget
    sched = Scheduler(3, allocator=al, max_batched_tokens=budget,
                      max_prefill_chunk=16)
    tok = iter(range(10_000, 60_000))

    def fresh_short():
        sched.submit(Req(np.asarray([next(tok) for _ in range(16)],
                                    np.int32), max_new_tokens=1))

    long_req = Req(np.asarray([next(tok) for _ in range(64)], np.int32),
                   max_new_tokens=1)
    sched.submit(long_req)                  # rid 0: the head of line
    fresh_short()
    fresh_short()
    _drive_chunk_ticks(sched, 40, n_decode=n_decode,
                       on_complete=fresh_short)
    # the long prompt finished prefilling despite a short arriving the
    # moment each previous one completed (>= 1 page of progress per 3
    # ticks is the documented floor: 8 pages x 3 < 40 ticks)
    long_slots = [st for st in sched.slots
                  if st is not None and st.rid == 0]
    assert not long_slots or not long_slots[0].prefilling


# --- on-demand reservation + preemption (pure scheduler) ----------------------

def _ondemand_sched(n_slots, n_pages, page_size):
    al = BlockAllocator(n_pages, page_size)
    return Scheduler(n_slots, allocator=al, reserve="ondemand"), al


def test_ondemand_reserves_prompt_pages_only():
    sched, al = _ondemand_sched(n_slots=2, n_pages=9, page_size=4)
    sched.submit(_req(range(10), max_new=20))    # full policy would need 8
    (b, st), = sched.admit()
    assert len(st.pages) == 3                    # ceil(10 / 4) prompt pages
    assert al.live == 3
    # the decode tail is granted page by page as the cursor crosses
    st.prefill_pos = 10                          # "prefill done"
    st.pos = 10
    assert sched.grow(st, 11) == 0               # row 10 sits in page 3
    assert sched.grow(st, 13) == 1               # row 12 crosses into page 4
    assert len(st.pages) == 4 and al.live == 4
    assert sched.grow(st, 33) is None            # 9 pages > capacity: refuse
    assert len(st.pages) == 4                    # never partially grown


def test_pick_victim_prefers_young_prefiller_then_long_decoder():
    sched, al = _ondemand_sched(n_slots=4, n_pages=32, page_size=4)
    for i, (ln, mn) in enumerate([(4, 2), (4, 12), (4, 6), (8, 3)]):
        sched.submit(_req(range(100 * i, 100 * i + ln), max_new=mn))
    placed = sched.admit()
    assert len(placed) == 4
    # rids 0..2 decoding, rid 3 still prefilling
    for b, st in placed[:3]:
        st.prefill_pos = st.prompt_len
        st.pos = st.prompt_len
    # prefilling slot first, regardless of decode budgets
    assert sched.pick_victim() == 3
    # without prefilling candidates: the longest-remaining decoder (rid 1)
    sched.slots[3].prefill_pos = sched.slots[3].prompt_len
    assert sched.pick_victim() == 1
    assert sched.pick_victim(exclude=frozenset({1})) == 2
    # the oldest seated request is never chosen while another remains
    sched.slots[0].request.max_new_tokens = 100
    assert sched.pick_victim() == 1
    # ... unless it is the only candidate left
    assert sched.pick_victim(exclude=frozenset({1, 2, 3})) == 0
    # slot index != rid (regression: the victim is a SLOT, not a rid)
    sched.evict(1)
    sched.submit(_req(range(900, 904), max_new=50))   # rid 4, longest left
    (b4, st4), = sched.admit()
    assert b4 == 1 and st4.rid == 4
    st4.prefill_pos = st4.prompt_len
    st4.pos = st4.prompt_len
    assert sched.pick_victim() == 1


def test_preempt_prefilling_victim_registers_boundary_and_requeues_front():
    sched, al = _ondemand_sched(n_slots=2, n_pages=16, page_size=4)
    prompt = list(range(700, 714))                    # 14 tokens, 4 pages
    sched.submit(_req(prompt, max_new=4))             # rid 0
    sched.submit(_req([1, 2], max_new=2))             # rid 1
    placed = sched.admit()
    st0 = placed[0][1]
    st0.prefill_pos = 8                               # two chunks done
    st0.chunks_done = 2
    st0 = sched.preempt(0)
    assert st0.spilled_rows == 8 and st0.preemptions == 1
    assert st0.pages == [] and st0.prefill_pos == 0 and st0.chunks_done == 0
    assert sched.slots[0] is None
    # requeued at the FRONT: it outranks everything submitted after it
    assert sched.waiting[0][0] == 0
    al.check_invariants()
    # its two finished pages were registered before the references dropped:
    # still matchable, so re-admission restores them as a prefix hit
    placed = sched.admit()
    st0b = placed[0][1]
    assert st0b is st0 and st0b.shared_rows == 8 and st0b.prefill_pos == 8
    assert al.ref[st0b.pages[0]] == 1
    al.check_invariants()


def test_preempt_decode_victim_folds_emitted_into_replay():
    sched, al = _ondemand_sched(n_slots=1, n_pages=16, page_size=4)
    prompt = list(range(40, 46))                      # 6 tokens
    sched.submit(_req(prompt, max_new=8))
    (b, st), = sched.admit()
    st.prefill_pos = 6
    st.pos = 6
    sched.grow(st, 9)                                 # decode grew a page
    st.pos = 9                                        # wrote rows [0, 9)
    st.emitted = [91, 92, 93]                         # handoff + 2 decodes
    sched.preempt(b)
    # replay covers prompt + every emitted token; rows [0,8) survive as
    # registered pages, row 8 (the partial page) is the recompute cost
    assert list(st.prompt_tokens()) == prompt + [91, 92, 93]
    assert st.prompt_len == 9 and st.spilled_rows == 9
    al.check_invariants()
    placed = sched.admit()
    assert placed[0][1] is st
    assert st.shared_rows == 8 and st.prefill_pos == 8
    assert len(st.pages) == 3                         # 2 shared + 1 fresh
    al.check_invariants()
