"""xLSTM blocks: mLSTM (matrix memory, parallel/recurrent dual form) and
sLSTM (scalar memory, sequential), per arXiv:2405.04517, with QAT projections.

The exponential gating is exactly the function class the paper's 256-entry
exp LUT covers, so the integer serving path reuses the same table
(DESIGN.md §4).  Recurrent states stay fp32 (documented).

The mLSTM dual form is a property-test target: the parallel (training) form
and the step-by-step recurrence must agree.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.models.layers import Obs, qdense, fake_quant_act, rmsnorm


def _heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h)


def mlstm_parallel(qh, kh, vh, gi, logf):
    """Row-chunked stabilized parallel mLSTM (shared by QAT + integer serve).

    qh/kh/vh (B,S,H,E); gi/logf (B,S,H).  The (B,S,S,H) decay matrix is the
    worst activation in the zoo at long S — rows only need their own a_i, so
    512-row chunking is exact (measured 19x HBM cut on xlstm train_4k)."""
    b, s, nh, _ = qh.shape
    a = jnp.cumsum(logf, axis=1)                            # (B, S, H)

    def rows(q_rows, a_rows, row0, cq):
        logd = (a_rows[:, :, None, :] - a[:, None, :, :]) + gi[:, None, :, :]
        qpos = row0 + jnp.arange(cq)[:, None]
        kpos = jnp.arange(s)[None, :]
        logd = jnp.where((kpos <= qpos)[None, :, :, None], logd, -jnp.inf)
        m = jnp.max(logd, axis=2, keepdims=True)            # (B, cq, 1, H)
        dmat = jnp.exp(logd - m)
        sc = jnp.einsum("bqhe,bkhe->bqkh", q_rows, kh) * dmat
        nrm = jnp.maximum(jnp.abs(sc.sum(2)), jnp.exp(-m[:, :, 0]))
        return jnp.einsum("bqkh,bkhe->bqhe", sc, vh) / nrm[..., None]

    chunk = 512
    if s > chunk and s % chunk == 0:
        qr = qh.reshape(b, s // chunk, chunk, nh, -1).transpose(1, 0, 2, 3, 4)
        ar = a.reshape(b, s // chunk, chunk, nh).transpose(1, 0, 2, 3)

        def body(_, inp):
            i, qq, aa = inp
            return None, rows(qq, aa, i * chunk, chunk)

        body = jax.checkpoint(body)
        _, ys = jax.lax.scan(body, None, (jnp.arange(s // chunk), qr, ar))
        return ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, -1)
    return rows(qh, a, 0, s)


def mlstm_qat(
    x: jax.Array,            # (B, S, d)
    p: Dict,
    amax: Dict[str, jax.Array],
    policy: QuantPolicy,
    cfg,
    state: Dict | None = None,
) -> Tuple[jax.Array, Obs, Dict | None]:
    """mLSTM: linear attention with exponential input/forget gates and a
    (D x D) matrix memory per head."""
    b, s, d = x.shape
    nh = cfg.n_heads
    obs: Obs = {}
    qp, obs["mlstm_in"] = qdense(x, p["wq"], None, amax["mlstm_in"], policy)
    kp, _ = qdense(x, p["wk"], None, amax["mlstm_in"], policy)
    vp, _ = qdense(x, p["wv"], None, amax["mlstm_in"], policy)
    qh = _heads(qp, nh).astype(jnp.float32)
    kh = _heads(kp, nh).astype(jnp.float32) / jnp.sqrt(qh.shape[-1] * 1.0)
    vh = _heads(vp, nh).astype(jnp.float32)
    # gates: scalars per head per step
    gi = (x.astype(jnp.float32) @ p["w_ig"].astype(jnp.float32) + p["b_ig"])  # (B,S,H)
    gf = (x.astype(jnp.float32) @ p["w_fg"].astype(jnp.float32) + p["b_fg"])
    logf = jax.nn.log_sigmoid(gf)

    if state is None:
        y = mlstm_parallel(qh, kh, vh, gi, logf)
        new_state = None
    else:
        # recurrent: C (B,H,E,E), n (B,H,E), m (B,H); s == 1
        qt, kt, vt = qh[:, 0], kh[:, 0], vh[:, 0]           # (B, H, E)
        git, logft = gi[:, 0], logf[:, 0]                   # (B, H)
        m_new = jnp.maximum(logft + state["m"], git)
        fdec = jnp.exp(logft + state["m"] - m_new)[..., None]
        iinc = jnp.exp(git - m_new)[..., None]
        C = fdec[..., None] * state["C"] + iinc[..., None] * (
            kt[..., :, None] * vt[..., None, :])            # (B,H,E,E)
        nvec = fdec * state["n"] + iinc * kt
        num = jnp.einsum("bhe,bhef->bhf", qt, C)
        den = jnp.maximum(jnp.abs(jnp.sum(nvec * qt, -1)), jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]                 # (B,1,H,E)
        new_state = {"C": C, "n": nvec, "m": m_new}
    y = y.reshape(b, s, d).astype(x.dtype)
    # output gate + norm (simplified block epilogue)
    og = jax.nn.sigmoid(x @ p["w_og"] + p["b_og"])
    y = rmsnorm(y, p["ln_y"]) * og
    y, obs["mlstm_y"] = fake_quant_act(y, amax["mlstm_y"], policy.a_bits,
                                       policy.quantize_wa)
    out, obs["mlstm_out"] = qdense(y, p["wo"], None, amax["mlstm_out"], policy)
    return out, obs, new_state


def slstm_qat(
    x: jax.Array,
    p: Dict,
    amax: Dict[str, jax.Array],
    policy: QuantPolicy,
    cfg,
    state: Dict | None = None,
) -> Tuple[jax.Array, Obs, Dict | None]:
    """sLSTM: scalar memory, exponential gating, sequential recurrence with a
    per-head recurrent matrix.  lax.scan over time."""
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    obs: Obs = {}
    zi, obs["slstm_in"] = qdense(x, p["w_z"], p["b_z"], amax["slstm_in"], policy)
    ii, _ = qdense(x, p["w_i"], p["b_i"], amax["slstm_in"], policy)
    ff, _ = qdense(x, p["w_f"], p["b_f"], amax["slstm_in"], policy)
    oo, _ = qdense(x, p["w_o"], p["b_o"], amax["slstm_in"], policy)
    zi, ii, ff, oo = (t.astype(jnp.float32) for t in (zi, ii, ff, oo))
    r = p["r"].astype(jnp.float32)                          # (H, dh, 4*dh)

    if state is None:
        c0 = jnp.zeros((b, nh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        h0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.zeros((b, nh, dh), jnp.float32)
        init = (c0, n0, h0, m0)
    else:
        init = (state["c"], state["n"], state["h"], state["m"])

    def step(carry, inp):
        c, n, h, m = carry
        zt, it, ft, ot = inp                                # (B, d) each
        rec = jnp.einsum("bhe,hef->bhf", h, r)              # (B, H, 4dh)
        rz, ri, rf, ro = jnp.split(rec, 4, axis=-1)
        zt = jnp.tanh(zt.reshape(b, nh, dh) + rz)
        it = it.reshape(b, nh, dh) + ri
        ft = ft.reshape(b, nh, dh) + rf
        ot = jax.nn.sigmoid(ot.reshape(b, nh, dh) + ro)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * zt
        n = jnp.maximum(f_p * n + i_p, jnp.exp(-m_new))
        h = ot * (c / n)
        return (c, n, h, m_new), h

    xs = (zi.transpose(1, 0, 2), ii.transpose(1, 0, 2),
          ff.transpose(1, 0, 2), oo.transpose(1, 0, 2))
    (c, n, h, m), hs = jax.lax.scan(step, init, xs)
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y, obs["slstm_y"] = fake_quant_act(y, amax["slstm_y"], policy.a_bits,
                                       policy.quantize_wa)
    out, obs["slstm_out"] = qdense(y, p["w_out"], None, amax["slstm_out"], policy)
    new_state = None if state is None else {"c": c, "n": n, "h": h, "m": m}
    return out, obs, new_state


MLSTM_SITES = ("mlstm_in", "mlstm_y", "mlstm_out")
SLSTM_SITES = ("slstm_in", "slstm_y", "slstm_out")
