# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks import tables

    all_rows = []
    for fn in (tables.table1_compression, tables.table2_ablation,
               tables.table3_kernel_scaling, tables.table4_latency):
        try:
            all_rows.extend(fn())
        except Exception as e:  # noqa: BLE001
            all_rows.append((f"{fn.__name__}/ERROR", 0.0,
                             f"{type(e).__name__}:{e}"))
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
