"""32-bit fixed-point requantization — paper Eq. 5, int32-ONLY arithmetic.

After an integer matmul the int32 accumulator must be rescaled to the output
activation's 8-bit grid:

    y_I = round(y * s_y) = (sum_i a_I w_I + b_I) * s_f,   s_f = s_y / (s_a s_w)

The paper stores s_f as "a 32-bit integer" — a fixed-point multiplier.  TPU
(and this JAX config) has no fast 64-bit path, so the datapath here is
strictly 32-bit, exactly like the FPGA's DSP48 chain:

    s_f ~= M * 2^(-shift),  M a Q15 mantissa in [2^14, 2^15),  shift >= 0

    rescale(acc) = ((clamp(acc >>r pre) * M) + rnd) >> (shift - pre)

where ``pre = max(0, shift + out_bits - 30)`` pre-drops bits so the
multiplicand fits 15 bits: any accumulator value large enough to be clamped
by the pre-shift would have saturated the out_bits output anyway, so the
clamp is exact w.r.t. the saturating output.  ``>>r`` = rounding right shift.

Error budget vs. the real product: <= 0.5 output LSB (final shift) +
2^-14 relative (M mantissa) + ~0.002 LSB (pre-shift) — comfortably inside
the 1-LSB contract the tests enforce.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANT_BITS = 15  # Q15 mantissa


def quantize_multiplier(s_f: float) -> Tuple[int, int]:
    """Real multiplier -> (M, shift): s_f ~= M * 2^-shift, M in [2^14, 2^15)."""
    if s_f <= 0:
        return 0, 0
    m, e = np.frexp(np.float64(s_f))  # s_f = m * 2^e, m in [0.5, 1)
    M = int(np.round(m * (1 << MANT_BITS)))
    if M == (1 << MANT_BITS):
        M //= 2
        e += 1
    shift = MANT_BITS - int(e)
    if shift < 0:  # s_f >= 2^15-ish: fold into M (never hits for requant scales)
        M = min(M << (-shift), (1 << 31) - 1)
        shift = 0
    return M, shift


def quantize_multiplier_array(s_f: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Traced version for scales computed inside a jitted graph."""
    s_f = jnp.maximum(s_f.astype(jnp.float32), 1e-30)
    e = jnp.floor(jnp.log2(s_f)) + 1.0  # s_f = m * 2^e, m in [0.5, 1)
    m = s_f * jnp.exp2(-e)
    M = jnp.round(m * (1 << MANT_BITS))
    renorm = M >= (1 << MANT_BITS)
    M = jnp.where(renorm, M / 2, M)
    e = jnp.where(renorm, e + 1, e)
    shift = MANT_BITS - e
    neg = shift < 0
    M = jnp.where(neg, jnp.minimum(M * jnp.exp2(jnp.where(neg, -shift, 0)), 2.0**31 - 1), M)
    shift = jnp.maximum(shift, 0.0)
    return M.astype(jnp.int32), shift.astype(jnp.int32)


def _rshift_round(x: jax.Array, n: jax.Array) -> jax.Array:
    """Rounding arithmetic right shift (round half away from zero), n >= 0."""
    n = jnp.asarray(n, jnp.int32)
    bias = jnp.where(n > 0, (jnp.int32(1) << jnp.maximum(n - 1, 0)), 0)
    pos = (x + bias) >> n
    neg = -((-x + bias) >> n)
    return jnp.where(x >= 0, pos, neg)


def rescale(
    acc: jax.Array, M: jax.Array, shift: jax.Array, out_bits: int = 8
) -> jax.Array:
    """round(acc * M * 2^-shift) in pure int32, exact up to output saturation.

    ``out_bits`` bounds the useful output magnitude (2^(out_bits-1)); larger
    results are saturated to +-(2^(out_bits) - 1) — callers clamp tighter.
    """
    acc = acc.astype(jnp.int32)
    M = jnp.asarray(M, jnp.int32)
    shift = jnp.asarray(shift, jnp.int32)
    pre = jnp.maximum(shift + (out_bits - 30), 0)
    v = _rshift_round(acc, pre)
    lim = jnp.int32((1 << MANT_BITS) - 1)
    v = jnp.clip(v, -lim - 1, lim)
    t = v * M  # |v| <= 2^15, M < 2^15  ->  |t| <= 2^30, no overflow
    return _rshift_round(t, shift - pre)


def requantize(acc: jax.Array, M, shift, bits: int = 8) -> jax.Array:
    """int32 accumulator -> k-bit code (int8 storage), clamped symmetric."""
    y = rescale(acc, M, shift, out_bits=bits)
    lim = (1 << (bits - 1)) - 1
    return jnp.clip(y, -lim, lim).astype(jnp.int8)


# --- integer rsqrt for the LN core (int32-only Newton, mantissa/exponent) ---

RSQRT_FRAC = 14


def rsqrt_mantexp(x: jax.Array, iters: int = 3) -> Tuple[jax.Array, jax.Array]:
    """Block-normalized integer rsqrt: 1/sqrt(x) = (y / 2^15) * 2^-s.

    x int32 in [1, 2^30).  Returns (y, s) with y the Q15 mantissa in
    (2^14, 2^15] (value 1/sqrt(m), m = x/4^s in [1,4)) and s = floor(e/2).
    Normalizing first keeps every Newton quantity in a narrow range so no
    fixed Q-format ever underflows (the failure mode of a naive global-Q
    iteration): y2 = Y^2 in Q15 in (2^13, 2^15]; t = m*Y^2 in Q14 ~ 2^14;
    f = 3*2^14 - t in [2^14, 2^15]; y*f <= 2^30.  Strictly int32.
    """
    x = jnp.maximum(x.astype(jnp.int32), 1)
    # e = floor(log2 x): float32 log2 is exact-enough for a *branch* decision
    # on powers of two boundaries and identical in kernel & oracle.
    e = jnp.floor(jnp.log2(x.astype(jnp.float32) * (1.0 + 1e-7))).astype(jnp.int32)
    s = e >> 1
    # m in Q14: m14 = x * 2^(14-2s)  in [2^14, 2^16)
    sh = 14 - 2 * s
    m14 = jnp.where(sh >= 0, x << jnp.maximum(sh, 0), x >> jnp.maximum(-sh, 0))
    # 2-entry seed table: m in [1,2) -> Y~0.85;  m in [2,4) -> Y~0.60
    y = jnp.where(m14 < (1 << 15), jnp.int32(27853), jnp.int32(19661))
    three = jnp.int32(3 << 14)
    for _ in range(iters):
        y2 = (y * y) >> 15          # Q15 of Y^2
        t = (m14 * y2) >> 15        # Q14 of m*Y^2  (~2^14 near convergence)
        y = (y * (three - t)) >> 15 # Q15, Y' = Y*(3 - m*Y^2)/2
    return y, s


def fixed_rsqrt(x: jax.Array, iters: int = 3) -> jax.Array:
    """y ~= 2^14 / sqrt(x) for int32 x >= 1 (convenience Q14 form)."""
    y, s = rsqrt_mantexp(x, iters)
    return _rshift_round(y, s + 1)


# --- small Q-format helpers --------------------------------------------------

def to_fixed(x: jax.Array, frac_bits: int, dtype=jnp.int32) -> jax.Array:
    return jnp.round(x * (1 << frac_bits)).astype(dtype)


def from_fixed(x: jax.Array, frac_bits: int) -> jax.Array:
    return x.astype(jnp.float32) / (1 << frac_bits)
