"""Integer-datapath auditor: walk a hot graph's ClosedJaxpr and enforce
the serving engine's declared invariants as machine-checked rules.

The engine's contract (PAPER.md: *fully* quantized BERT; I-BERT's lesson:
integer pipelines silently regress to float one op at a time) is defended
at runtime by bit-identity tests — but those can't localize *which eqn*
broke the contract.  This module can.  Rules, each with a stable id:

``INT-DOT-FLOAT``
    No f32/bf16/f16 ``dot_general`` reachable from quantized operands on
    the serve path.  Taint starts at every narrow-int (int4/int8/uint8)
    invar/const and propagates through all eqns (incl. nested scopes), so
    a float matmul fed — however indirectly — by quantized data is flagged
    even if someone laundered the dtype through elementwise ops first.
    Float *elementwise* islands (RoPE, the fp32 softmax carry, the logits
    exit) are allowed; float MXU work is not.

``INT-DOT-ACC``
    Integer ``dot_general`` must accumulate at >= 32 bits (the kernels pass
    ``preferred_element_type=jnp.int32``).  An int8 dot that comes out int8
    is an overflow bug XLA will happily compile.

``LATTICE-MIXED``
    Dtype-promotion lattice check on every eqn: arithmetic primitives must
    see operands of one kind (all-integer or all-float).  jax's strict
    jaxpr typing makes this unreachable today — the rule exists so a
    future custom primitive or lowering change that smuggles mixed-kind
    arithmetic in gets caught, not absorbed.

``POOL-FLOAT-CAST``
    No pool-scale ``convert_element_type`` from a narrow-int dtype to
    float outside a registered kernel boundary
    (``repro.analysis.boundary``).  The threshold is half the smallest KV
    pool payload leaf — activations sit orders of magnitude below it, a
    dequantized pool (or gathered whole-chain view) above.

``DONATION``
    Every cache pool leaf must appear donated (``donated_invars``) on the
    hot graph's pjit eqn — a dropped donation doubles pool HBM.

``DONATION-ALIAS``
    No two live cache leaves may share a device buffer: XLA refuses (or
    silently copies) double-donated aliased buffers — the class PR 7 hit
    when the kv4 scale leaves shared one ``jnp.full``.

``audit_graph`` runs the jaxpr-level rules on one ``(fn, args)`` hot
graph; ``audit_engine`` runs every hot graph of a live Engine plus the
aliasing check and returns per-graph results.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import jax

try:    # jax >= 0.6 moved the IR types out of jax.core
    from jax.extend import core as jcore
    _ = jcore.Jaxpr, jcore.ClosedJaxpr
except (ImportError, AttributeError):    # jax 0.4.x floor
    from jax import core as jcore

from repro.analysis import boundary as boundary_mod

NARROW_INT = ("int4", "uint4", "int8", "uint8")
FLOAT_KINDS = ("float16", "bfloat16", "float32", "float64")
WIDE_INT = ("int32", "uint32", "int64", "uint64")

# primitives audited by the LATTICE-MIXED rule (operand kinds must agree)
ARITH_PRIMS = frozenset({"add", "sub", "mul", "div", "rem", "pow", "max",
                         "min", "atan2", "nextafter"})


@dataclasses.dataclass
class Violation:
    rule: str
    graph: str
    scope: str       # nested eqn path, e.g. "/decode_step/scan"
    detail: str

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AuditResult:
    graph: str
    n_eqns: int = 0
    violations: List[Violation] = dataclasses.field(default_factory=list)
    # dtype -> primitive name -> eqn count (by first output's dtype)
    op_histogram: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)
    float_prims: Set[str] = dataclasses.field(default_factory=set)

    @property
    def float_eqns(self) -> int:
        return sum(n for dt, prims in self.op_histogram.items()
                   if dt in FLOAT_KINDS for n in prims.values())


def _dtype_name(aval) -> Optional[str]:
    dt = getattr(aval, "dtype", None)
    return None if dt is None else str(dt)


def _is_narrow_int(aval) -> bool:
    return _dtype_name(aval) in NARROW_INT


def _is_float(aval) -> bool:
    return _dtype_name(aval) in FLOAT_KINDS


def _kind(aval) -> Optional[str]:
    dt = _dtype_name(aval)
    if dt is None:
        return None
    if dt in FLOAT_KINDS:
        return "float"
    if dt in NARROW_INT + WIDE_INT:
        return "int"
    return None    # bool, etc. — not lattice-checked


def _sub_jaxprs(eqn) -> List[Tuple[str, jcore.Jaxpr, Optional[List[bool]]]]:
    """(scope_name, sub_jaxpr, invar_taint_map) for every sub-jaxpr of an
    eqn.  ``invar_taint_map`` is None when the mapping is 1:1 positional
    with ``eqn.invars`` (the recursion derives it); otherwise it is the
    explicit per-sub-invar taint seed (conservative where unknown)."""
    prim, params = eqn.primitive.name, eqn.params
    subs: List[Tuple[str, jcore.Jaxpr, Optional[List[bool]]]] = []
    if prim in ("pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
                "remat", "checkpoint", "shard_map"):
        j = (params.get("jaxpr") or params.get("call_jaxpr")
             or params.get("fun_jaxpr"))
        if j is not None:
            name = params.get("name") or prim
            subs.append((str(name), _as_open(j), None))
    elif prim == "scan":
        subs.append(("scan", _as_open(params["jaxpr"]), None))
    elif prim == "while":
        subs.append(("while_cond", _as_open(params["cond_jaxpr"]), "all"))
        subs.append(("while_body", _as_open(params["body_jaxpr"]), "all"))
    elif prim == "cond":
        for i, br in enumerate(params["branches"]):
            subs.append((f"cond_branch{i}", _as_open(br), "skip_pred"))
    else:
        # unknown higher-order primitive: recurse conservatively into any
        # jaxpr-valued param with every sub-invar tainted
        for v in params.values():
            if isinstance(v, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                subs.append((prim, _as_open(v), "all"))
    return subs


def _as_open(j) -> jcore.Jaxpr:
    return j.jaxpr if isinstance(j, jcore.ClosedJaxpr) else j


def audit_graph(fn, args, *, graph: str, pool_threshold: int,
                boundaries: Optional[Dict[str, str]] = None,
                check_donation: bool = True,
                donate_argnums: Tuple[int, ...] = (1,)) -> AuditResult:
    """Trace ``fn(*args)`` to a jaxpr and run every jaxpr-level rule.

    ``pool_threshold`` is the element count above which an int->float
    convert counts as pool-scale; ``donate_argnums`` names the positional
    args whose leaves must be donated (the cache), checked against the
    traced pjit eqn's ``donated_invars``."""
    if boundaries is None:
        boundaries = dict(boundary_mod.REGISTRY)
    closed = jax.make_jaxpr(fn)(*args)
    res = AuditResult(graph=graph)

    taint: Dict[int, bool] = {}

    def seed(var, is_tainted):
        taint[id(var)] = bool(is_tainted)

    def tainted(atom) -> bool:
        if isinstance(atom, jcore.Literal):
            return _is_narrow_int(atom.aval)
        return taint.get(id(atom), _is_narrow_int(atom.aval))

    def walk(jaxpr: jcore.Jaxpr, scope: str, in_boundary: bool):
        for cv in jaxpr.constvars:
            seed(cv, _is_narrow_int(cv.aval))
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            res.n_eqns += 1
            in_taint = any(tainted(a) for a in eqn.invars)
            out_aval = eqn.outvars[0].aval if eqn.outvars else None
            dt = _dtype_name(out_aval) if out_aval is not None else None
            if dt is not None:
                hist = res.op_histogram.setdefault(dt, {})
                hist[prim] = hist.get(prim, 0) + 1
                if dt in FLOAT_KINDS:
                    res.float_prims.add(prim)

            if prim == "dot_general":
                operand_kinds = {_kind(a.aval) for a in eqn.invars}
                out_float = out_aval is not None and _is_float(out_aval)
                if (out_float or "float" in operand_kinds) and in_taint:
                    res.violations.append(Violation(
                        "INT-DOT-FLOAT", graph, scope,
                        f"dot_general with float dtype ({dt}) reachable "
                        f"from quantized operands"))
                if operand_kinds == {"int"} and dt not in WIDE_INT:
                    res.violations.append(Violation(
                        "INT-DOT-ACC", graph, scope,
                        f"integer dot_general accumulates in {dt}; "
                        "pass preferred_element_type=jnp.int32"))
            elif prim in ARITH_PRIMS:
                kinds = {_kind(a.aval) for a in eqn.invars
                         if getattr(a.aval, "shape", None) is not None}
                kinds.discard(None)
                if len(kinds) > 1:
                    res.violations.append(Violation(
                        "LATTICE-MIXED", graph, scope,
                        f"{prim} mixes operand kinds {sorted(kinds)}"))
            elif prim == "convert_element_type" and not in_boundary:
                src = eqn.invars[0].aval
                if (_is_narrow_int(src) and _is_float(out_aval)
                        and src.size >= pool_threshold):
                    res.violations.append(Violation(
                        "POOL-FLOAT-CAST", graph, scope,
                        f"pool-scale convert {_dtype_name(src)}->{dt} of "
                        f"{src.size} elems (threshold {pool_threshold}) "
                        "outside a registered kernel boundary"))

            for name, sub, taint_map in _sub_jaxprs(eqn):
                sub_boundary = in_boundary or name in boundaries
                if taint_map is None and len(sub.invars) == len(eqn.invars):
                    seeds = [tainted(a) for a in eqn.invars]
                elif taint_map == "skip_pred" \
                        and len(sub.invars) == len(eqn.invars) - 1:
                    seeds = [tainted(a) for a in eqn.invars[1:]]
                else:
                    seeds = [True] * len(sub.invars)
                for var, s in zip(sub.invars, seeds, strict=True):
                    seed(var, s)
                walk(sub, f"{scope}/{name}", sub_boundary)
                # taint of sub outvars flows to this eqn's outvars where
                # the arity matches (scan: carry+ys align; cond branches
                # OR together)
                if len(sub.outvars) == len(eqn.outvars):
                    for ov, sv in zip(eqn.outvars, sub.outvars, strict=True):
                        seed(ov, tainted(sv) or taint.get(id(ov), False))

            for ov in eqn.outvars:
                if id(ov) not in taint:
                    seed(ov, in_taint)

    for iv in closed.jaxpr.invars:
        seed(iv, _is_narrow_int(iv.aval))
    walk(closed.jaxpr, "", False)

    if check_donation:
        res.violations.extend(_audit_donation(
            closed, args, graph=graph, donate_argnums=donate_argnums))
    return res


def _audit_donation(closed, args, *, graph: str,
                    donate_argnums: Tuple[int, ...]) -> List[Violation]:
    """The traced fn is jitted, so the outer jaxpr is a single pjit eqn
    whose ``donated_invars`` must cover every leaf of the donated args."""
    out: List[Violation] = []
    pjit_eqns = [e for e in closed.jaxpr.eqns if e.primitive.name == "pjit"]
    if not pjit_eqns:
        return [Violation("DONATION", graph, "",
                          "no pjit eqn found — hot graph is not jitted")]
    eqn = pjit_eqns[0]
    donated = eqn.params.get("donated_invars")
    if donated is None:
        return [Violation("DONATION", graph, "",
                          "pjit eqn carries no donated_invars")]
    # flat positions of each positional arg's leaves
    sizes = [len(jax.tree_util.tree_leaves(a)) for a in args]
    offsets = [sum(sizes[:i]) for i in range(len(sizes))]
    if len(donated) != sum(sizes):
        return [Violation("DONATION", graph, "",
                          f"donated_invars length {len(donated)} != "
                          f"{sum(sizes)} flat args — cannot map leaves")]
    for argnum in donate_argnums:
        for j in range(sizes[argnum]):
            flat = offsets[argnum] + j
            if not donated[flat]:
                out.append(Violation(
                    "DONATION", graph, "",
                    f"cache leaf {j} (flat invar {flat}) of arg {argnum} "
                    "is not donated"))
    return out


def audit_cache_aliasing(cache, *, graph: str = "cache") -> List[Violation]:
    """No two pool leaves may share a device buffer (the double-donation
    class: XLA either refuses or silently copies aliased donated buffers).
    Checked on the LIVE pytree — jaxpr tracing cannot see value aliasing."""
    out: List[Violation] = []
    seen: Dict[Tuple, str] = {}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache) \
        if hasattr(jax.tree_util, "tree_flatten_with_path") else (None, None)
    if leaves is None:    # very old jax fallback
        leaves = [((i,), l) for i, l in
                  enumerate(jax.tree_util.tree_leaves(cache))]
    for path, leaf in leaves:
        if not isinstance(leaf, jax.Array):
            continue
        for shard in leaf.addressable_shards:
            key = (repr(shard.device), shard.data.unsafe_buffer_pointer())
            name = jax.tree_util.keystr(path)
            if key in seen:
                out.append(Violation(
                    "DONATION-ALIAS", graph, name,
                    f"leaf shares a device buffer with {seen[key]} — "
                    "double donation (one jnp array reused across leaves)"))
            else:
                seen[key] = name
    return out


def pool_threshold_elems(cache) -> int:
    """Half the smallest KV pool payload leaf's element count: activations
    sit far below, any whole-pool (or gathered whole-chain) dequant above.
    Payload leaves are the >=4-D pool arrays; 2-D kv4 scale leaves and
    non-paged layouts fall back to the largest leaf."""
    leaves = [l for l in jax.tree_util.tree_leaves(cache)
              if hasattr(l, "ndim")]
    pools = [l.size for l in leaves if l.ndim >= 4]
    if not pools:
        pools = [max((l.size for l in leaves), default=2)]
    return max(min(pools) // 2, 1)


def audit_engine(engine, *, graphs=None) -> Dict[str, AuditResult]:
    """Run every jaxpr-level rule over each hot graph of a live Engine,
    plus the live-buffer aliasing check (attached to the first graph)."""
    hot = engine.hot_graphs()
    if graphs is not None:
        hot = {k: v for k, v in hot.items() if k in graphs}
    thr = pool_threshold_elems(engine.cache)
    results: Dict[str, AuditResult] = {}
    for name, (fn, args) in hot.items():
        results[name] = audit_graph(fn, args, graph=name,
                                    pool_threshold=thr)
    if results:
        first = next(iter(results.values()))
        first.violations.extend(audit_cache_aliasing(engine.cache))
    return results


def lowered_hlo(fn, args) -> str:
    """Post-optimization HLO text of a hot graph (for bytes-by-dtype via
    ``repro.analysis.hlo_cost``)."""
    return fn.lower(*args).compile().as_text()


__all__ = [
    "AuditResult", "Violation", "audit_graph", "audit_cache_aliasing",
    "audit_engine", "pool_threshold_elems", "lowered_hlo",
    "NARROW_INT", "FLOAT_KINDS",
]
