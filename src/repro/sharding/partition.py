"""Name-based parameter -> PartitionSpec rules (Megatron TP + FSDP over DP),
plus activation-sharding helpers.

Axes: ``data`` (+ ``pod`` composed in front on multi-pod meshes) carry the
batch and the FSDP shard of weights; ``model`` carries tensor parallelism:
column-parallel on QKV/gate/up (output dim), row-parallel on O/down
(contraction dim, XLA inserts the all-reduce), vocab-parallel embeddings and
LM head, expert-FFN-dim parallelism for MoE, state-dim parallelism for Mamba.

FSDP: the non-'model' weight dim is additionally sharded over the DP axes;
XLA all-gathers per layer (ZeRO-3 semantics).  Toggled per step-build —
serving never uses FSDP (weights are int4 and must be resident).
"""
from __future__ import annotations

import re
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --- rule tables --------------------------------------------------------------
# (regex on 'path/like/this', spec builder given (dp, fsdp) axis names)
# Paths are relative; leading 'blocks/slotN/' has a stacked (n_reps) dim 0
# which is always unsharded (scan axis).

def _qat_rules(_dp, fs):
    return [
        (r"embed/tokens$",      P("model", fs)),
        (r"embed/pos$",         P(None, None)),
        (r"embed/codebooks$",   P(None, "model", fs)),
        (r"attn/wq$",           P(None, fs, "model")),
        (r"attn/wk$",           P(None, fs, "model")),
        (r"attn/wv$",           P(None, fs, "model")),
        (r"attn/wo$",           P(None, "model", fs)),
        (r"attn/b[qkv]$",       P(None, "model")),
        (r"attn/bo$",           P(None, None)),
        (r"attn/[qk]n$",        P(None, None)),
        (r"mlp/wg$",            P(None, fs, "model")),
        (r"mlp/wu$",            P(None, fs, "model")),
        (r"mlp/wd$",            P(None, "model", fs)),
        (r"mlp/w1$",            P(None, fs, "model")),
        (r"mlp/w2$",            P(None, "model", fs)),
        (r"mlp/b1$",            P(None, "model")),
        (r"mlp/b2$",            P(None, None)),
        (r"moe/router$",        P(None, None, None)),
        (r"moe/(experts|shared)/wg$", P(None, None, fs, "model")),
        (r"moe/(experts|shared)/wu$", P(None, None, fs, "model")),
        (r"moe/(experts|shared)/wd$", P(None, None, "model", fs)),
        # mamba: d_in dims sharded over model (elementwise-parallel scan)
        (r"mixer/w_in$",        P(None, fs, "model")),
        (r"mixer/conv_w$",      P(None, None, "model")),
        (r"mixer/conv_b$",      P(None, "model")),
        (r"mixer/w_x$",         P(None, "model", None)),
        (r"mixer/w_dt$",        P(None, None, "model")),
        (r"mixer/dt_bias$",     P(None, "model")),
        (r"mixer/A_log$",       P(None, "model", None)),
        (r"mixer/D$",           P(None, "model")),
        (r"mixer/w_out$",       P(None, "model", fs)),
        # xlstm: project onto model over the wide dim
        (r"mixer/w[qkv]$",      P(None, fs, "model")),
        (r"mixer/wo$",          P(None, "model", fs)),
        (r"mixer/w_[io]g$",     P(None, None, None)),
        (r"mixer/w_fg$",        P(None, None, None)),
        (r"mixer/w_[zifo]$",    P(None, fs, "model")),
        (r"mixer/b_[zifo]g?$",  P(None, "model")),
        (r"mixer/r$",           P(None, None, None, None)),
        (r"mixer/ln_y$",        P(None, None)),
        (r"lm_head$",           P(fs, "model")),           # (d, V) or (K,d,V)
        (r"(norm1|norm2|final_norm)/(gamma|beta)$", P(None)),
        (r"pooler/w$",          P(None, None)),
        (r"classifier/w$",      P(None, None)),
    ]


def _serve_rules(_dp):
    """Folded-int serving: no FSDP; packed dim0 = K//2 follows K's spec."""
    return [
        (r"embed/tokens_i8$",    P("model", None)),
        (r"embed/pos_i8$",       P(None, None)),
        (r"embed/codebooks_i8$", P(None, "model", None)),
        (r"w[qkv]/(w|b)$",       P(None, None, "model")),
        (r"wo/w$",               P(None, "model", None)),
        (r"wo/b$",               P(None, None)),
        (r"(wg|wu|w1)/(w|b)$",   P(None, None, "model")),
        (r"(wd|w2)/w$",          P(None, "model", None)),
        (r"(wd|w2)/b$",          P(None, None)),
        (r"experts/w[gu1]/(w|b)$", P(None, None, None, "model")),
        (r"experts/wd/w$",       P(None, None, "model", None)),
        (r"shared/w[gu1]/(w|b)$", P(None, None, None, "model")),
        (r"shared/wd/w$",        P(None, None, "model", None)),
        (r"mx/w_in/w$",          P(None, None, "model")),
        (r"mx/w_x/w$",           P(None, "model", None)),
        (r"mx/w_out/w$",         P(None, "model", None)),
        (r"mx/conv_w$",          P(None, None, "model")),
        (r"mx/conv_b$",          P(None, "model")),
        (r"mx/w_dt$",            P(None, None, "model")),
        (r"mx/(dt_bias|D)$",     P(None, "model")),
        (r"mx/A_log$",           P(None, "model", None)),
        (r"mx/w[qkv]/w$",        P(None, None, "model")),
        (r"mx/wo/w$",            P(None, "model", None)),
        (r"mx/w_[zifo]/w$",      P(None, None, "model")),
        (r"lm_head/w$",          P(None, "model")),
        (r"lm_head/w$",          P(None, "model")),
    ]


def _spec_for(path: str, rules, ndim: int) -> P:
    for rx, spec in rules:
        if re.search(rx, path):
            parts = list(spec)
            # pad/trim to rank (stacked multi-head lm_head etc.)
            while len(parts) < ndim:
                parts.insert(0, None)
            return P(*parts[:ndim])
    return P()  # replicate


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (explicit pjit
    shardings require exact divisibility — e.g. batch 1 at long_500k, or
    4-head gate tensors vs a 16-way model axis)."""
    if shape is None:
        return spec
    parts = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            parts.append(None if i >= len(shape) else ax)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        keep = []
        rem = shape[i]
        for a in axes:
            n = mesh.shape[a]
            if rem % n == 0:
                keep.append(a)
                rem //= n
        parts.append(tuple(keep) if len(keep) > 1 else
                     (keep[0] if keep else None))
    return P(*parts[:len(shape)])


def _tree_paths_specs(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def path_str(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            elif hasattr(k, "name"):
                out.append(str(k.name))
        return "/".join(out)

    return [(path_str(kp), v) for kp, v in flat]


def make_param_shardings(mesh: Mesh, tree, *, mode: str = "qat",
                         fsdp: bool = True):
    """Pytree of NamedShardings matching ``tree`` (works on ShapeDtypeStructs)."""
    dp = "data"
    fs = ("pod", "data") if ("pod" in mesh.axis_names and fsdp) else (
        "data" if fsdp else None)
    rules = _qat_rules(dp, fs) if mode == "qat" else _serve_rules(dp)
    leaves = _tree_paths_specs(tree)
    specs = []
    for p, v in leaves:
        # quantized-moment NamedTuples flatten to <param>/codes (shaped like
        # the param) and <param>/scale (per-slice scales -> replicate)
        if p.endswith("/scale") or p.endswith("/1"):
            specs.append(P())
            continue
        if p.endswith("/codes"):
            p = p[: -len("/codes")]
        elif p.endswith("/0"):
            p = p[:-2]
        sp = _spec_for(p, rules, getattr(v, "ndim", 0))
        specs.append(_fit_spec(sp, getattr(v, "shape", None), mesh))
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, s) for s in specs])


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_sharding(mesh: Mesh, ndim: int, shape=None) -> NamedSharding:
    spec = P(batch_axes(mesh), *([None] * (ndim - 1)))
    return NamedSharding(mesh, _fit_spec(spec, shape, mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_sharding(mesh: Mesh, tree):
    """KV/SSM cache: (n_reps, B, ...) -> batch over DP axes; int8 K/V shard
    head_dim over model (works for every GQA config; scores psum once)."""
    dp = batch_axes(mesh)

    def spec(path, v):
        nd = v.ndim
        if path.endswith("/k") or path.endswith("/v"):
            sp = P(None, dp, None, None, "model")     # (L,B,S,Hkv,hd)
        elif nd >= 2:
            sp = P(None, dp, *([None] * (nd - 2)))
        else:
            sp = P()
        return _fit_spec(sp, v.shape, mesh)

    leaves = _tree_paths_specs(tree)
    specs = [spec(p, v) for p, v in leaves]
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, s) for s in specs])


# --- TP-sharded paged KV pool ---------------------------------------------------

def kv_pool_pspec() -> P:
    """PartitionSpec of one paged-pool K/V leaf (n_reps, n_pages, P, Hkv, hd):
    KV heads shard over the model axis, everything else — crucially the PAGE
    axis — stays unsharded.  Page ids are therefore global: every rank holds
    its heads' slice of EVERY page, so one host-side block table / allocator
    decision addresses all ranks identically and spill/restore never moves
    data across ranks."""
    return P(None, None, None, "model", None)


def paged_pool_shardings(mesh: Mesh, tree):
    """Pytree of NamedShardings for the paged KV pool (``init_paged_cache``
    output): every k/v leaf sharded per ``kv_pool_pspec``.  Axes that do not
    divide (Hkv % tp != 0) are dropped by ``_fit_spec`` — callers that
    require a real shard must assert divisibility themselves (the serving
    engine does)."""
    leaves = _tree_paths_specs(tree)
    specs = [_fit_spec(kv_pool_pspec(), v.shape, mesh) for _, v in leaves]
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, s) for s in specs])


def kv_pool_specs(tree, mesh: Mesh):
    """Pytree of PartitionSpecs (not NamedShardings) mirroring the paged
    cache — the ``in_specs``/``out_specs`` form for a shard_map over the
    pool.  5-D payload leaves take ``kv_pool_pspec`` (Hkv over the model
    axis); lower-rank leaves — the packed pool's per-page (n_reps, n_pages)
    scale leaves — are replicated, since scales are derived from FULL-head
    codes and every rank holds all of them."""
    def spec(v):
        if v.ndim == 5:
            return _fit_spec(kv_pool_pspec(), v.shape, mesh)
        return P(*([None] * v.ndim))
    return jax.tree.map(spec, tree)


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` with per-rank (unchecked) replication semantics across
    the jax rename: 0.4.x has ``jax.experimental.shard_map`` with
    ``check_rep``; newer jax promotes it to ``jax.shard_map`` and renames
    the flag ``check_vma``.  Callers use collectives (all_gather) and
    promise replicated outputs themselves, so the check is always off."""
    try:
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    except (ImportError, TypeError):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)


# --- activation-constraint context ---------------------------------------------

_CTX = threading.local()


def set_mesh_ctx(mesh: Optional[Mesh]):
    _CTX.mesh = mesh


def get_mesh_ctx() -> Optional[Mesh]:
    return getattr(_CTX, "mesh", None)


def constrain(x, *spec) -> jax.Array:
    """with_sharding_constraint that no-ops when no mesh context is set
    (keeps model code runnable in plain single-device tests) and silently
    drops axes that don't divide the corresponding dim."""
    mesh = get_mesh_ctx()
    if mesh is None:
        return x
    fitted = _fit_spec(P(*spec), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))


def dp_axes_or_none():
    mesh = get_mesh_ctx()
    if mesh is None:
        return None
    return batch_axes(mesh)


def model_axis_size() -> int:
    mesh = get_mesh_ctx()
    if mesh is None or "model" not in mesh.axis_names:
        return 0
    return mesh.shape["model"]
