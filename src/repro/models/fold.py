"""Fold trained QAT params + EMA activation stats into the integer serving
form (paper Eq. 4/5): packed-int4 weights, int32 biases, 32-bit fixed-point
requantization multipliers, integer LN constants, LUT index multipliers.

Everything here is **traceable jnp** so ``jax.eval_shape(fold_params, ...)``
yields the serving param ShapeDtypeStructs for the dry-run without ever
materializing a tensor, and the same code runs for real at deployment.

Grid/scale bookkeeping: every quantized activation site s has scale
``s(site) = 127 / amax[site]``; a tensor's int8 codes live on exactly one
site grid at a time, and every grid change is an explicit fixed-point
rescale folded here as (M, shift).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fixedpoint as fxp
from repro.core import packing
from repro.core import quant as q
from repro.core.policy import QuantPolicy
from repro.core.qsoftmax import LUT_DELTA
from repro.models import transformer as T
from repro.models import mamba as Mb


def _scale8(s, policy: QuantPolicy):
    """Traceable 8-significant-bit scale quantization (Table II 'scale')."""
    if not policy.quantize_scale:
        return s
    e = jnp.floor(jnp.log2(jnp.maximum(s, 1e-30))) - 7.0
    return jnp.round(s * jnp.exp2(-e)) * jnp.exp2(e)


def site_scale(amax_val, policy: QuantPolicy):
    s = q.qmax(policy.a_bits) / jnp.maximum(amax_val.astype(jnp.float32), 1e-8)
    return _scale8(s, policy)


def fold_linear_t(w, b, s_a, s_y, policy: QuantPolicy) -> Dict:
    """Traceable fold of y = x@w + b into the integer form.

    w_bits == 4: nibble-planar packed (the paper's FQ-BERT).
    w_bits == 8: plain int8 codes (the Q8BERT comparison point); the serving
    path then uses the BIM bit-split 8x8 kernel."""
    w = w.astype(jnp.float32)
    s_w = _scale8(q.qmax(policy.w_bits) / jnp.maximum(q.per_tensor_max(w), 1e-8),
                  policy)
    codes = jnp.clip(jnp.round(w * s_w), -q.qmax(policy.w_bits),
                     q.qmax(policy.w_bits)).astype(jnp.int8)
    w_packed = (codes if policy.w_bits == 8 else
                packing.pack_int4_planar(codes, axis=0))
    bias = jnp.zeros((w.shape[1],), jnp.float32) if b is None else b.astype(jnp.float32)
    bias_i = jnp.clip(jnp.round(bias * (s_a * s_w)), -(2.0**31 - 1), 2.0**31 - 1
                      ).astype(jnp.int32)
    M, sh = fxp.quantize_multiplier_array(s_y / (s_a * s_w))
    return {"w": w_packed, "b": bias_i, "M": M, "sh": sh}


def fold_linear_weightonly(w, b, policy: QuantPolicy) -> Dict:
    """W4-only fold (SSM inner projections: fp activations, int4 weights)."""
    w = w.astype(jnp.float32)
    s_w = q.qmax(policy.w_bits) / jnp.maximum(q.per_tensor_max(w), 1e-8)
    codes = jnp.clip(jnp.round(w * s_w), -q.qmax(policy.w_bits),
                     q.qmax(policy.w_bits)).astype(jnp.int8)
    out = {"w": packing.pack_int4_planar(codes, axis=0), "inv_s_w": 1.0 / s_w}
    if b is not None:
        out["b"] = b.astype(jnp.float32)
    return out


def fold_norm_t(p_norm, s_y, _norm_type: str) -> Dict:
    gamma = p_norm["gamma"].astype(jnp.float32)
    beta = p_norm.get("beta")
    s_g = q.qmax(8) / jnp.maximum(q.per_tensor_max(gamma), 1e-8)
    gamma_i = jnp.clip(jnp.round(gamma * s_g), -127, 127).astype(jnp.int8)
    acc_scale = float(1 << 14) * s_g
    beta_aligned = (
        jnp.clip(jnp.round(beta.astype(jnp.float32) * acc_scale),
                 -(2.0**30), 2.0**30).astype(jnp.int32)
        if beta is not None else jnp.zeros_like(gamma_i, dtype=jnp.int32))
    M, sh = fxp.quantize_multiplier_array(s_y / acc_scale)
    # subtract_mean is cfg-static (norm_type), NOT stored here: bools can't
    # ride through the vmapped fold.
    return {"gamma_i": gamma_i, "beta_al": beta_aligned, "M": M, "sh": sh}


def fold_rescale(s_from, s_to) -> Dict:
    M, sh = fxp.quantize_multiplier_array(s_to / s_from)
    return {"M": M, "sh": sh}


def make_silu_lut(s_in, s_out) -> jax.Array:
    """int8 -> int8 elementwise LUT for SiLU (256 entries; the paper's LUT
    idea applied to the activation function).  Traceable."""
    codes = jnp.arange(-128, 128, dtype=jnp.float32)
    x = codes / s_in
    y = x * jax.nn.sigmoid(x)
    return jnp.clip(jnp.round(y * s_out), -127, 127).astype(jnp.int8)


def make_gelu_lut(s_in, s_out) -> jax.Array:
    codes = jnp.arange(-128, 128, dtype=jnp.float32)
    x = codes / s_in
    y = 0.5 * x * (1 + jnp.tanh(math.sqrt(2 / math.pi) * (x + 0.044715 * x**3)))
    return jnp.clip(jnp.round(y * s_out), -127, 127).astype(jnp.int8)


def fold_slot(cfg: ModelConfig, mixer: str, ffn: str, p: Dict, a: Dict,
              s_res_in) -> Dict:
    """Fold one super-block slot.  ``s_res_in``: scale of the incoming
    residual grid.  Returns (folded dict, s_res_out)."""
    pol = cfg.quant
    f: Dict = {}
    s = lambda name: site_scale(a[name], pol)

    if mixer == "attn":
        s_in, s_q, s_k, s_v = s("attn_in"), s("q"), s("k"), s("v")
        s_qp, s_kp = s("q_pre"), s("k_pre")
        s_ctx, s_ra = s("attn_out_in"), s("resid_a")
        f["ln1"] = fold_norm_t(p["norm1"], s_in, cfg.norm_type)
        f["wq"] = fold_linear_t(p["attn"]["wq"], p["attn"].get("bq"), s_in, s_qp, pol)
        f["wk"] = fold_linear_t(p["attn"]["wk"], p["attn"].get("bk"), s_in, s_kp, pol)
        f["wv"] = fold_linear_t(p["attn"]["wv"], p["attn"].get("bv"), s_in, s_v, pol)
        f["wo"] = fold_linear_t(p["attn"]["wo"], p["attn"].get("bo"), s_ctx, s_ra, pol)
        s_logit = math.sqrt(cfg.hd) * s_q * s_k  # codes per real logit
        M_idx, sh_idx = fxp.quantize_multiplier_array(1.0 / (s_logit * LUT_DELTA))
        M_pv, sh_pv = fxp.quantize_multiplier_array(s_ctx / (128.0 * s_v))
        f["attn_q"] = {
            "M_idx": M_idx, "sh_idx": sh_idx,
            "inv_s_logit": 1.0 / s_logit,
            "out_scale": s_ctx / s_v,          # flash fp epilogue
            "M_pv": M_pv, "sh_pv": sh_pv,      # decode integer P@V requant
            "inv_s_qp": 1.0 / s_qp, "inv_s_kp": 1.0 / s_kp,  # rope island in
            "s_q": s_q, "s_k": s_k,                          # rope island out
        }
        if cfg.qk_norm:
            f["attn_q"]["qn"] = p["attn"]["qn"].astype(jnp.float32)
            f["attn_q"]["kn"] = p["attn"]["kn"].astype(jnp.float32)
        f["res_a"] = fold_rescale(s_res_in, s_ra)
        s_res = s_ra
    elif mixer == "mamba":
        # weight-only int4; fp island inside (DESIGN.md §4)
        s_ra = s("resid_a")
        f["ln1"] = fold_norm_t(p["norm1"], s("mamba_in"), cfg.norm_type)
        f["inv_s_in"] = 1.0 / s("mamba_in")
        m = p["mixer"]
        f["mx"] = {
            "w_in": fold_linear_weightonly(m["w_in"], None, pol),
            "w_x": fold_linear_weightonly(m["w_x"], None, pol),
            "w_out": fold_linear_weightonly(m["w_out"], None, pol),
            "conv_w": m["conv_w"].astype(jnp.float32),
            "conv_b": m["conv_b"].astype(jnp.float32),
            "w_dt": m["w_dt"].astype(jnp.float32),
            "dt_bias": m["dt_bias"], "A_log": m["A_log"], "D": m["D"],
        }
        f["s_ra"] = s_ra
        f["res_a"] = fold_rescale(s_res_in, s_ra)
        s_res = s_ra
    elif mixer in ("mlstm", "slstm"):
        key = "mlstm_in" if mixer == "mlstm" else "slstm_in"
        s_ra = s("resid_a")
        f["ln1"] = fold_norm_t(p["norm1"], s(key), cfg.norm_type)
        f["inv_s_in"] = 1.0 / s(key)
        f["mx"] = jax.tree.map(lambda t: t.astype(jnp.float32)
                               if t.dtype != jnp.float32 else t, p["mixer"])
        f["mx"] = {k: (fold_linear_weightonly(v, None, pol)
                       if k.startswith("w") and v.ndim == 2 and k not in
                       ("w_ig", "w_fg", "w_og") else v)
                   for k, v in f["mx"].items()}
        f["s_ra"] = s_ra
        f["res_a"] = fold_rescale(s_res_in, s_ra)
        s_res = s_ra

    if ffn == "dense":
        s_mi, s_rm = s("mlp_in"), s("resid_m")
        f["ln2"] = fold_norm_t(p["norm2"], s_mi, cfg.norm_type)
        if cfg.act == "swiglu":
            s_gp, s_g, s_u, s_h = s("g_pre"), s("g_out"), s("u_out"), s("h_in")
            f["wg"] = fold_linear_t(p["mlp"]["wg"], None, s_mi, s_gp, pol)
            f["wu"] = fold_linear_t(p["mlp"]["wu"], None, s_mi, s_u, pol)
            f["silu_lut"] = make_silu_lut(s_gp, s_g)
            f["prod"] = fold_rescale(s_g * s_u, s_h)   # (g_i*u_i) int16 -> s_h
            f["wd"] = fold_linear_t(p["mlp"]["wd"], None, s_h, s_rm, pol)
        else:
            s_hp, s_g, s_h = s("h_pre"), s("g_out"), s("h_in")
            f["w1"] = fold_linear_t(p["mlp"]["w1"], p["mlp"].get("b1"),
                                    s_mi, s_hp, pol)
            f["gelu_lut"] = make_gelu_lut(s_hp, s_g)
            f["gelu_rescale"] = fold_rescale(s_g, s_h)
            f["w2"] = fold_linear_t(p["mlp"]["w2"], p["mlp"].get("b2"),
                                    s_h, s_rm, pol)
        f["res_m"] = fold_rescale(s_res, s_rm)
        s_res = s_rm
    elif ffn == "moe":
        s_mi = s("exp_in")
        s_rm = s("resid_m")
        f["ln2"] = fold_norm_t(p["norm2"], s_mi, cfg.norm_type)
        f["router"] = p["moe"]["router"].astype(jnp.float32)
        f["inv_s_mi"] = 1.0 / s_mi

        def fold_expert_group(grp, pre):
            s_g, s_u, s_h = s(f"{pre}_g"), s(f"{pre}_u"), s(f"{pre}_h")
            fe = {}
            fe["wg"] = jax.vmap(lambda w: fold_linear_t(w, None, s_mi, s_g, pol)
                                )(grp["wg"])
            fe["wu"] = jax.vmap(lambda w: fold_linear_t(w, None, s_mi, s_u, pol)
                                )(grp["wu"])
            fe["silu_lut"] = make_silu_lut(s_g, s_g)
            fe["prod"] = fold_rescale(s_g * s_u, s_h)
            fe["wd"] = jax.vmap(lambda w: fold_linear_t(w, None, s_h, 128.0, pol)
                                )(grp["wd"])  # expert out on a fixed Q1.7-ish grid
            fe["inv_s_out"] = 1.0 / 128.0
            return fe

        f["experts"] = fold_expert_group(p["moe"]["experts"], "exp")
        if cfg.n_shared_experts:
            f["shared"] = fold_expert_group(p["moe"]["shared"], "shr")
        f["s_rm"] = s_rm
        f["res_m"] = fold_rescale(s_res, s_rm)
        s_res = s_rm
    return f, s_res


def fold_params(cfg: ModelConfig, params: Dict, amax: Dict) -> Dict:
    """Whole-model fold.  Per-rep slot params are folded under vmap so the
    result keeps the (n_reps, ...) stacked layout the serving scan consumes."""
    pol = cfg.quant
    kinds = T.slot_kinds(cfg)
    s_emb = site_scale(amax["embed_out"], pol)
    folded: Dict = {"embed": {}}
    emb = params["embed"]["tokens"].astype(jnp.float32)
    folded["embed"]["tokens_i8"] = jnp.clip(
        jnp.round(emb * s_emb), -127, 127).astype(jnp.int8)
    if "pos" in params["embed"]:
        folded["embed"]["pos_i8"] = jnp.clip(jnp.round(
            params["embed"]["pos"].astype(jnp.float32) * s_emb), -127, 127
        ).astype(jnp.int8)
    if "codebooks" in params["embed"]:
        folded["embed"]["codebooks_i8"] = jnp.clip(jnp.round(
            params["embed"]["codebooks"].astype(jnp.float32) * s_emb),
            -127, 127).astype(jnp.int8)

    # NOTE on residual grids with scan: the cross-rep residual grid must be
    # rep-independent for a scanned stack, so the residual rescale of slot 0
    # uses the PER-REP incoming grid only through its own folded constants.
    # We chain grids within the super-block and close the loop by rescaling
    # the block output back to the embed grid (one extra 8-bit requant per
    # super-block; <=0.4% added rms error, measured in tests).
    blocks = {}
    s_head = site_scale(amax["head_in"], pol)

    def fold_rep(p_rep, a_rep):
        out = {}
        s_res = s_emb
        for i, (mixer, ffn) in enumerate(kinds):
            out[f"slot{i}"], s_res = fold_slot(
                cfg, mixer, ffn, p_rep[f"slot{i}"], a_rep[f"slot{i}"], s_res)
        out["block_out_rescale"] = fold_rescale(s_res, s_emb)
        return out

    blocks = jax.vmap(fold_rep)(params["blocks"], amax["blocks"])
    folded["blocks"] = blocks
    folded["final_norm"] = fold_norm_t(params["final_norm"], s_head,
                                       cfg.norm_type)
    # LM head keeps the int32 accumulator (logits are consumed in fp32 by
    # sampling/loss): W4 codes + a single dequant scale, no int8 requant.
    def fold_head(w):
        w = w.astype(jnp.float32)
        s_w = q.qmax(pol.w_bits) / jnp.maximum(q.per_tensor_max(w), 1e-8)
        codes = jnp.clip(jnp.round(w * s_w), -q.qmax(pol.w_bits),
                         q.qmax(pol.w_bits)).astype(jnp.int8)
        wq = codes if pol.w_bits == 8 else packing.pack_int4_planar(codes, axis=0)
        return {"w": wq, "inv_acc": 1.0 / (s_head * s_w)}

    if cfg.tied_embeddings:
        folded["lm_head"] = fold_head(params["embed"]["tokens"].T)
    elif cfg.n_lm_heads > 1:
        folded["lm_head"] = jax.vmap(fold_head)(params["lm_head"])
    else:
        folded["lm_head"] = fold_head(params["lm_head"])
    folded["s_embed"] = s_emb
    folded["s_head"] = s_head
    return folded
