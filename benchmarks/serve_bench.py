"""Serving throughput AND latency: cache layouts (paged vs contiguous),
engines (continuous vs lockstep), and prefill scheduling (chunked vs
one-shot) over the same folded integer model.

Workloads (``--workload``):

  * ``poisson`` — N requests from a Poisson arrival process, prompt lengths
    mixed over a palette (16-256 tokens by default), per-request decode
    budgets.
  * ``prefix`` — the millions-of-users shape: every request shares one long
    system prompt (``--prefix-len``) followed by a short unique suffix drawn
    from the length palette.  The paged engine's block-table allocator maps
    the shared prefix pages copy-on-write, so repeated prompts skip both the
    prefill compute and the pages.
  * ``longprompt`` — the tail-latency shape: a few very long prompts
    (``--n-long`` x ``--long-len``) dropped into steady short-request
    traffic.  Runs the paged engine twice — one-shot admission prefill vs
    the chunked token-budget loop (``--max-batched-tokens`` /
    ``--max-prefill-chunk``) — and reports per-class TTFT: chunking bounds
    the short requests' TTFT because a long prompt no longer monopolizes
    the step loop for its whole prefill.
  * ``overload`` — decode-heavy traffic against a page pool deliberately
    too small for the concurrent decode budgets (``--pool-pages``, auto =
    one worst-case request plus one page of headroom).  A/Bs
    ``reserve_policy="full"`` (admission waits until a request's whole
    budget fits — nothing is ever spilled) against ``"ondemand"`` (admit
    on prompt pages, grow decode pages at boundary crossings, preempt a
    victim when the pool runs dry), with an unlimited-pool run supplying
    the truth tokens.  Reports preemption / recomputed-token /
    pool-wait counters per run; exits non-zero if the preempted run's
    greedy outputs diverge from the unlimited pool's, or if the sized
    pool failed to force at least one spill.

``--tp N`` (any workload flag ignored; Poisson shape) runs the
tensor-parallel A/B instead: the paged engine unsharded vs sharded over an
N-way model mesh (KV-head-sharded page pool, replicated block tables).
Divergence always exits non-zero — the sharded forward reassembles int8
head contexts, so it is bit-exact on every backend.  CI runs it in the
test-tp lane under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(artifact BENCH_TP.json).

Engines/layouts (``--layout``, poisson/prefix workloads):

  * ``contiguous`` — lockstep baseline vs the continuous engine on the dense
    per-slot cache (the pre-paging A/B).
  * ``paged``      — continuous engine, contiguous vs PAGED cache layout:
    same requests, same greedy tokens, different cache addressing.
  * ``both``       — all three (default).

Every run reports aggregate tokens/s plus per-request TTFT and inter-token
latency p50/p95 (wall clock, measured on the timed pass).  All randomness —
the Poisson arrival trace, prompt sampling, and the shared prefix — derives
from ONE ``--seed`` through independent SeedSequence streams, so A/B runs
replay the identical workload.

Greedy outputs must be identical per request across every engine / layout /
chunking policy off the compiled pallas backend — scheduling changes
throughput and latency, not tokens; the bench exits non-zero on a mismatch.
Prints ``name,value,derived`` CSV; ``--json`` also writes an artifact
(BENCH_PR.json / BENCH_PREFIX.json / BENCH_CHUNKED.json in CI) for the perf
trajectory; the longprompt artifact includes a per-tick Engine.stats()
trace of the chunked run.

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json BENCH_PR.json
    PYTHONPATH=src python benchmarks/serve_bench.py --workload prefix --layout paged
    PYTHONPATH=src python benchmarks/serve_bench.py --workload longprompt \
        --json BENCH_CHUNKED.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def make_workload(rng, n_requests, lengths, rate, max_new_range,
                  prefix_len=0):
    """Poisson arrivals: exponential interarrival gaps (unit = engine
    ticks), uniform prompt-length palette, uniform decode budgets.  With
    ``prefix_len`` the palette lengths become suffixes after one shared
    system prompt."""
    t = 0.0
    work = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        work.append(dict(
            arrival=t,
            prompt_len=prefix_len + int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
            cls="all",
        ))
    return work


def make_longprompt_workload(rng, n_long, long_len, n_short, lengths, rate,
                             max_new_range):
    """A few very long prompts spread over a steady stream of short
    requests — the workload whose TTFT tail one-shot admission prefill
    ruins and chunked prefill bounds.  Each long prompt lands on a short
    request's arrival tick, AHEAD of it in FIFO order — the collision where
    one-shot admission makes the short wait out the entire long prefill
    (in continuous traffic these collisions are the norm; the virtual-time
    clock would otherwise hide them between ticks)."""
    t = 0.0
    shorts = []
    for _ in range(n_short):
        t += rng.exponential(1.0 / rate)
        shorts.append(dict(
            arrival=t,
            prompt_len=int(rng.choice(lengths)),
            max_new=int(rng.integers(*max_new_range)),
            cls="short",
        ))
    longs = [dict(arrival=shorts[(j * n_short) // n_long]["arrival"],
                  prompt_len=long_len,
                  max_new=int(rng.integers(*max_new_range)),
                  cls="long")
             for j in range(max(n_long, 0))] if shorts else []
    # stable sort: a long precedes its equal-arrival short (FIFO collision)
    return sorted(longs + shorts, key=lambda w: w["arrival"])


def build_requests(Request, rng, work, vocab, prefix=None):
    reqs = []
    for w in work:
        suffix_len = w["prompt_len"] - (len(prefix) if prefix is not None
                                        else 0)
        suffix = rng.integers(0, vocab, (suffix_len,)).astype(np.int32)
        prompt = suffix if prefix is None else np.concatenate([prefix, suffix])
        reqs.append(Request(prompt=prompt, max_new_tokens=w["max_new"]))
    return reqs


def run_lockstep(eng, requests):
    """Static batching: same-length groups (correct per-request outputs),
    each group decoded to its longest budget.  The engine is reset between
    groups — recurrent-state archs (mamba/xLSTM) would otherwise leak the
    previous group's SSM state into the next prefill (attention rows are
    position-masked; SSM state is not)."""
    by_len = {}
    for r in requests:
        by_len.setdefault(len(r.prompt), []).append(r)
    for group in by_len.values():
        for i in range(0, len(group), eng.batch):
            eng.reset()
            eng.generate(group[i:i + eng.batch])
    return requests


def run_continuous(eng, requests, work, lat=None, trace=None):
    """Requests arrive over virtual time (1 tick = one engine step)
    following the workload's arrival process and are submitted when due;
    the clock fast-forwards over idle gaps so lulls cost no wall time.
    ``lat`` (dict) collects per-request submit/token timestamps; ``trace``
    (list) collects Engine.stats() gauges per tick."""
    rid2idx = {}
    i = 0
    n = len(requests)

    def submit(idx, tick):
        rid2idx[eng.submit(requests[idx])] = idx
        if lat is not None:
            lat[idx] = dict(submit_tick=tick,
                            submit_wall=time.perf_counter(), tokens=[])

    while i < n or eng.sched.has_work:
        t = eng.counters["ticks"]
        while i < n and work[i]["arrival"] <= t:
            submit(i, t)
            i += 1
        if not eng.sched.has_work and i < n:
            # idle: jump the clock to the next arrival — and submit EVERY
            # request due at that instant, so same-arrival collisions (the
            # longprompt workload's point) survive the fast-forward
            t_next = work[i]["arrival"]
            while i < n and work[i]["arrival"] <= t_next:
                submit(i, t_next)
                i += 1
        emitted = eng.step()
        now = time.perf_counter()
        tick = eng.counters["ticks"]
        if lat is not None:
            for rid, _tok in emitted:
                lat[rid2idx[rid]]["tokens"].append((tick, now))
        if trace is not None:
            if len(trace) < 5000:
                g = eng.stats()
                g.pop("counters")
                g["tick"] = tick
                trace.append(g)
            elif trace[-1] != "TRUNCATED":
                trace.append("TRUNCATED")   # explicit, not a silent cutoff
    return requests


def latency_summary(work, lat):
    """Per-request TTFT (submit -> first token) p50/p95 per request class,
    and inter-token latency p50/p95 pooled over all gaps.  Milliseconds."""
    def pct(xs, q):
        return round(float(np.percentile(xs, q)) * 1e3, 3) if xs else 0.0

    ttft_by_cls = {}
    itl = []
    for i, w in enumerate(work):
        rec = lat.get(i)
        if not rec or not rec["tokens"]:
            continue
        ttft_by_cls.setdefault(w["cls"], []).append(
            rec["tokens"][0][1] - rec["submit_wall"])
        walls = [wall for _, wall in rec["tokens"]]
        itl.extend(float(d) for d in np.diff(walls))
    out = dict(itl_p50_ms=pct(itl, 50), itl_p95_ms=pct(itl, 95))
    for cls, tt in sorted(ttft_by_cls.items()):
        out[f"ttft_{cls}_p50_ms"] = pct(tt, 50)
        out[f"ttft_{cls}_p95_ms"] = pct(tt, 95)
    return out


def _timed(runner, eng, fresh, *extra, **kw):
    """Warmup pass (compilation) then a timed pass on fresh state."""
    runner(eng, fresh(), *extra)
    eng.reset()
    t0 = time.perf_counter()
    out = runner(eng, fresh(), *extra, **kw)
    return out, time.perf_counter() - t0


def _rng_streams(seed):
    """Independent deterministic streams off ONE seed: arrival process,
    prompt tokens, shared prefix tokens.  A/B runs (and the warmup vs
    timed pass) therefore replay byte-identical workloads."""
    ss = np.random.SeedSequence(seed)
    return [np.random.default_rng(c) for c in ss.spawn(3)]


def bench_chunked(args, cfg, folded, Request):
    """longprompt workload: paged one-shot admission vs the chunked
    token-budget loop, same requests, same tokens — different TTFT tail."""
    from repro.serve.engine import Engine

    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_longprompt_workload(
        r_arrival, args.n_long, args.long_len, args.requests, lengths,
        args.rate, (args.max_new_lo, args.max_new_hi))
    max_len = max(args.long_len, max(lengths)) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size)

    n_tok = sum(w["max_new"] for w in work)
    rows, outs, summaries = [], {}, {}
    artifact = dict(
        bench="serve_chunked", workload="longprompt", arch=cfg.name,
        slots=args.slots, n_long=args.n_long, long_len=args.long_len,
        n_short=args.requests, lengths=lengths, page_size=args.page_size,
        max_batched_tokens=args.max_batched_tokens,
        max_prefill_chunk=args.max_prefill_chunk, seed=args.seed)

    trace = []
    for name, kw, tr in [
        ("oneshot", {}, None),
        ("chunked", dict(max_batched_tokens=args.max_batched_tokens,
                         max_prefill_chunk=args.max_prefill_chunk), trace),
    ]:
        eng = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                     cache_layout="paged", page_size=args.page_size, **kw)
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work,
                           lat=lat, trace=tr)
        outs[name] = [r.out.tolist() for r in out]
        summaries[name] = latency_summary(work, lat)
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_ttft_short_p95_ms",
                     summaries[name].get("ttft_short_p95_ms", 0.0),
                     f"p50={summaries[name].get('ttft_short_p50_ms', 0.0)}"))
        rows.append((f"serve/{name}_itl_p95_ms",
                     summaries[name]["itl_p95_ms"], ""))
        artifact[name] = dict(tok_per_s=round(tps, 2), **summaries[name],
                              engine_counters=eng.counters)

    os_p95 = summaries["oneshot"].get("ttft_short_p95_ms", 0.0)
    ch_p95 = summaries["chunked"].get("ttft_short_p95_ms", 0.0)
    if ch_p95 > 0:
        rows.append(("serve/chunked_ttft_short_p95_speedup",
                     os_p95 / ch_p95, "oneshot_p95/chunked_p95"))
        artifact["ttft_short_p95_speedup"] = round(os_p95 / ch_p95, 3)
    match = outs["chunked"] == outs["oneshot"]
    rows.append(("serve/outputs_match", float(match), "chunked+oneshot"))
    artifact.update(outputs_match=bool(match), stats_trace=trace)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        print("ERROR: greedy outputs diverged between chunked and one-shot "
              "prefill", file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    return 0


def bench_tp(args, cfg, folded, Request):
    """--tp N: sharded-vs-unsharded A/B on the paged engine — same Poisson
    workload, the pool sharded over KV heads on an N-way model mesh (on
    CPU: XLA_FLAGS=--xla_force_host_platform_device_count=N).  Sharding
    must change memory layout only, never greedy tokens; exits non-zero on
    divergence on any backend (the sharded forward all-gathers int8 head
    contexts, which is bit-exact even where prefill kernels are not)."""
    from repro.serve.engine import Engine

    if len(jax.devices()) < args.tp:
        print(f"ERROR: --tp {args.tp} needs {args.tp} devices, found "
              f"{len(jax.devices())}; on CPU set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.tp}",
              file=sys.stderr)
        return 1
    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi))
    max_len = max(lengths) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size)

    n_tok = sum(w["max_new"] for w in work)
    rows, outs = [], {}
    artifact = dict(
        bench="serve_tp", workload="poisson", arch=cfg.name, tp=args.tp,
        slots=args.slots, requests=args.requests, lengths=lengths,
        page_size=args.page_size, seed=args.seed)

    for name, kw in [("unsharded", {}), (f"tp{args.tp}", dict(tp=args.tp))]:
        eng = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                     cache_layout="paged", page_size=args.page_size, **kw)
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work, lat=lat)
        outs[name] = [r.out.tolist() for r in out]
        summ = latency_summary(work, lat)
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_ttft_p95_ms",
                     summ.get("ttft_all_p95_ms", 0.0),
                     f"itl_p95={summ['itl_p95_ms']}"))
        artifact[name] = dict(tok_per_s=round(tps, 2), **summ,
                              engine_counters=eng.counters)

    un, sh = outs["unsharded"], outs[f"tp{args.tp}"]
    match = un == sh
    rows.append(("serve/outputs_match", float(match),
                 f"unsharded+tp{args.tp}"))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")
    if not match:
        print(f"ERROR: greedy outputs diverged between the unsharded and "
              f"TP={args.tp} engines", file=sys.stderr)
        return 1
    return 0


def bench_overload(args, cfg, folded, Request):
    """overload workload: on-demand growth + preemption vs full
    reservation on the same starved pool, plus an unlimited-pool truth
    run.  Preemption must change memory, latency, and throughput — never
    greedy tokens."""
    from repro.serve.engine import Engine
    from repro.serve.scheduler import pages_needed

    r_arrival, _, _ = _rng_streams(args.seed)
    lengths = [int(x) for x in args.lengths.split(",")]
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi))
    max_len = max(lengths) + args.max_new_hi + 1

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size)

    worst = max(pages_needed(w["prompt_len"] + w["max_new"] - 1,
                             args.page_size) for w in work)
    # auto pool: one worst-case request + one page of headroom.  Full
    # reservation can seat roughly one request at a time; on-demand seats
    # every slot on prompt pages and preempts its way through the decode.
    pool = args.pool_pages or (worst + 1)
    if pool < worst:
        # fail BEFORE the engines run: Engine.submit would otherwise raise
        # mid-bench after the unlimited pass already burned its wall time
        print(f"ERROR: --pool-pages {pool} cannot hold the workload's "
              f"largest request ({worst} pages); every request must fit "
              "individually for preemption to make progress",
              file=sys.stderr)
        return 1
    n_tok = sum(w["max_new"] for w in work)
    rows, outs, summaries, counters = [], {}, {}, {}
    artifact = dict(
        bench="serve_preempt", workload="overload", arch=cfg.name,
        slots=args.slots, requests=args.requests, lengths=lengths,
        page_size=args.page_size, pool_pages=pool,
        worst_case_pages=worst, seed=args.seed)

    for name, kw in [
        ("unlimited", {}),                       # ample default pool
        ("full", dict(n_pages=pool + 1, reserve_policy="full")),
        ("ondemand", dict(n_pages=pool + 1, reserve_policy="ondemand")),
    ]:
        eng = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                     cache_layout="paged", page_size=args.page_size, **kw)
        lat = {}
        out, secs = _timed(run_continuous, eng, fresh, work, lat=lat)
        outs[name] = [r.out.tolist() for r in out]
        summaries[name] = latency_summary(work, lat)
        c = dict(eng.counters)
        counters[name] = c
        tps = n_tok / secs
        rows.append((f"serve/{name}_tok_per_s", tps, f"wall={secs:.2f}s"))
        rows.append((f"serve/{name}_preemptions", c["preemptions"],
                     f"recomputed_tokens={c['recomputed_tokens']}"))
        rows.append((f"serve/{name}_pool_wait_ticks", c["pool_wait_ticks"],
                     f"peak_pages={c['cache_pages_peak']}"))
        rows.append((f"serve/{name}_ttft_p95_ms",
                     summaries[name].get("ttft_all_p95_ms", 0.0),
                     f"p50={summaries[name].get('ttft_all_p50_ms', 0.0)}"))
        artifact[name] = dict(tok_per_s=round(tps, 2), **summaries[name],
                              engine_counters=c)

    od = counters["ondemand"]
    od_tps = artifact["ondemand"]["tok_per_s"]
    fl_tps = artifact["full"]["tok_per_s"]
    rows.append(("serve/ondemand_vs_full_tok_per_s_speedup",
                 od_tps / fl_tps, "same starved pool"))
    artifact["ondemand_vs_full_speedup"] = round(od_tps / fl_tps, 3)
    match = outs["ondemand"] == outs["unlimited"] \
        and outs["full"] == outs["unlimited"]
    rows.append(("serve/outputs_match", float(match),
                 "unlimited+full+ondemand"))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")

    from repro.kernels import ops
    if not match and ops.backend() != "pallas":
        print("ERROR: greedy outputs diverged under preemption / full "
              "reservation", file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(prefill kernels are not bit-identical there)",
              file=sys.stderr)
    if counters["unlimited"]["preemptions"]:
        print("ERROR: the unlimited-pool reference run preempted — its "
              "outputs are not a clean truth baseline", file=sys.stderr)
        return 1
    if od["preemptions"] < 1:
        print(f"ERROR: pool_pages={pool} failed to force a single "
              "preemption — the overload A/B measured nothing; shrink "
              "--pool-pages or raise --requests/--max-new-hi",
              file=sys.stderr)
        return 1
    return 0


def bench(args):
    from repro.configs import smoke_config
    from repro.launch.serve import calibrated_folded
    from repro.serve.engine import Engine, LockstepEngine, Request

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    folded = calibrated_folded(cfg, key, calib)

    if args.tp:
        return bench_tp(args, cfg, folded, Request)
    if args.workload == "longprompt":
        return bench_chunked(args, cfg, folded, Request)
    if args.workload == "overload":
        return bench_overload(args, cfg, folded, Request)

    lengths = [int(x) for x in args.lengths.split(",")]
    prefix_len = args.prefix_len if args.workload == "prefix" else 0
    max_len = prefix_len + max(lengths) + args.max_new_hi + 1
    r_arrival, _, r_prefix = _rng_streams(args.seed)
    work = make_workload(r_arrival, args.requests, lengths, args.rate,
                         (args.max_new_lo, args.max_new_hi),
                         prefix_len=prefix_len)
    prefix = (r_prefix.integers(0, cfg.vocab_size, (prefix_len,))
              .astype(np.int32) if prefix_len else None)

    def fresh():
        _, r_prompt, _ = _rng_streams(args.seed)
        return build_requests(Request, r_prompt, work, cfg.vocab_size,
                              prefix=prefix)

    run_lock = args.layout in ("contiguous", "both")
    run_paged = args.layout in ("paged", "both")

    rows, artifact = [], dict(
        bench="serve_layouts", workload=args.workload, arch=cfg.name,
        slots=args.slots, requests=args.requests, lengths=lengths,
        prefix_len=prefix_len, page_size=args.page_size, seed=args.seed)
    n_tok = n_prompt = None
    outs = {}

    cont = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                  cache_layout="contiguous")
    cont_lat = {}
    cont_out, cont_s = _timed(run_continuous, cont, fresh, work, lat=cont_lat)
    n_tok = sum(len(r.out) for r in cont_out)
    n_prompt = sum(len(r.prompt) for r in cont_out)
    cont_tps = n_tok / cont_s
    outs["contiguous"] = [r.out.tolist() for r in cont_out]
    # the dense layout reserves its whole footprint up front: page-equivalent
    # is slots x blocks-per-stripe, the number the paged pool competes with
    cont_pages = args.slots * -(-cont.smax // args.page_size)
    cont_sum = latency_summary(work, cont_lat)
    rows.append(("serve/continuous_tok_per_s", cont_tps,
                 f"wall={cont_s:.2f}s_gen={n_tok}_prompt={n_prompt}"))
    rows.append(("serve/continuous_ttft_p95_ms",
                 cont_sum.get("ttft_all_p95_ms", 0.0),
                 f"itl_p95={cont_sum['itl_p95_ms']}"))
    artifact.update(generated_tokens=n_tok, prompt_tokens=n_prompt,
                    continuous_tok_per_s=round(cont_tps, 2),
                    continuous_latency=cont_sum,
                    contiguous_page_equiv=cont_pages,
                    engine_counters=cont.counters)

    if run_lock:
        lock = LockstepEngine(cfg, folded, batch_slots=args.slots,
                              max_len=max_len)
        lock_out, lock_s = _timed(run_lockstep, lock, fresh)
        lock_tps = n_tok / lock_s
        outs["lockstep"] = [r.out.tolist() for r in lock_out]
        rows.insert(0, ("serve/lockstep_tok_per_s", lock_tps,
                        f"wall={lock_s:.2f}s"))
        rows.append(("serve/continuous_speedup", cont_tps / lock_tps, ""))
        artifact.update(lockstep_tok_per_s=round(lock_tps, 2),
                        speedup=round(cont_tps / lock_tps, 3))

    if run_paged:
        paged = Engine(cfg, folded, batch_slots=args.slots, max_len=max_len,
                       cache_layout="paged", page_size=args.page_size)
        paged_lat = {}
        paged_out, paged_s = _timed(run_continuous, paged, fresh, work,
                                    lat=paged_lat)
        paged_tps = n_tok / paged_s
        outs["paged"] = [r.out.tolist() for r in paged_out]
        peak = paged.counters["cache_pages_peak"]
        paged_sum = latency_summary(work, paged_lat)
        rows.append(("serve/paged_tok_per_s", paged_tps,
                     f"wall={paged_s:.2f}s_prefix_hits="
                     f"{paged.counters['prefix_hits']}"))
        rows.append(("serve/paged_vs_contiguous_speedup",
                     paged_tps / cont_tps, ""))
        rows.append(("serve/paged_peak_pages", peak,
                     f"contiguous_equiv={cont_pages}"))
        rows.append(("serve/paged_ttft_p95_ms",
                     paged_sum.get("ttft_all_p95_ms", 0.0),
                     f"itl_p95={paged_sum['itl_p95_ms']}"))
        artifact.update(paged_tok_per_s=round(paged_tps, 2),
                        paged_vs_contiguous_speedup=round(paged_tps / cont_tps,
                                                          3),
                        paged_peak_pages=peak,
                        paged_latency=paged_sum,
                        paged_engine_counters=paged.counters)

    from repro.kernels import ops
    ref_outputs = outs["contiguous"]
    match = all(o == ref_outputs for o in outs.values())
    # bit-identity between engines/layouts is only guaranteed off the
    # compiled pallas backend (engine.py docstring): there prefill (q7
    # flash) and decode kernels may differ in the last LSB, flipping rare
    # argmax ties
    match_enforced = ops.backend() != "pallas"
    rows.append(("serve/outputs_match", float(match),
                 "+".join(sorted(outs))))
    artifact.update(outputs_match=bool(match))

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")

    if args.json:
        Path(args.json).write_text(json.dumps(artifact, indent=2) + "\n")
    if not match and match_enforced:
        print("ERROR: greedy outputs diverged between engines/layouts",
              file=sys.stderr)
        return 1
    if not match:
        print("note: output mismatch tolerated on the pallas backend "
              "(engines are not bit-identical there)", file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16,
                    help="request count (longprompt: SHORT request count)")
    ap.add_argument("--lengths", default="16,32,64,128,256",
                    help="comma-separated prompt (or suffix) length palette")
    ap.add_argument("--layout", default="both",
                    choices=["contiguous", "paged", "both"],
                    help="contiguous: lockstep-vs-continuous baseline; "
                         "paged: contiguous-vs-paged cache A/B; both: all")
    ap.add_argument("--workload", default="poisson",
                    choices=["poisson", "prefix", "longprompt", "overload"])
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="starved-pool capacity for the overload workload "
                         "(0 = auto: one worst-case request + 1 page)")
    ap.add_argument("--prefix-len", type=int, default=96,
                    help="shared system-prompt length (prefix workload)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.25,
                    help="Poisson arrival rate (requests per engine tick)")
    ap.add_argument("--max-new-lo", type=int, default=8)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--n-long", type=int, default=2,
                    help="long prompts in the longprompt workload")
    ap.add_argument("--long-len", type=int, default=384,
                    help="long-prompt length (longprompt workload)")
    ap.add_argument("--max-batched-tokens", type=int, default=64,
                    help="per-tick token budget of the chunked run")
    ap.add_argument("--max-prefill-chunk", type=int, default=32,
                    help="per-slot prefill chunk cap of the chunked run")
    ap.add_argument("--tp", type=int, default=0,
                    help="run the sharded-vs-unsharded TP A/B at this "
                         "model-parallel degree (needs that many devices; "
                         "CPU: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--seed", type=int, default=0,
                    help="single seed for arrivals, prompts, and prefix")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_*.json artifact here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (fast on 2 CPU cores)")
    args = ap.parse_args()
    if args.smoke:
        # 5 requests (was 6): the overload lane rides in the same CI wall
        # budget, paid for by trimming every workload's request count
        args.requests = min(args.requests, 5)
        args.lengths = "8,16" if args.workload != "prefix" else "4,8"
        args.prefix_len = min(args.prefix_len, 48)
        args.max_new_lo, args.max_new_hi = 4, 8
        args.n_long = min(args.n_long, 2)
        args.long_len = min(args.long_len, 192)
        args.page_size = min(args.page_size, 8)
        # budget fits the largest short prompt + decode slots + the
        # head-of-line page reservation in one tick
        args.max_batched_tokens = min(args.max_batched_tokens, 32)
        args.max_prefill_chunk = min(args.max_prefill_chunk, 16)
        if args.workload == "overload":
            # burst arrivals + decode-heavy budgets: the starved pool must
            # see real concurrency or nothing gets preempted
            args.rate = max(args.rate, 1.0)
            args.max_new_lo, args.max_new_hi = 8, 16
    raise SystemExit(bench(args))


if __name__ == "__main__":
    main()
