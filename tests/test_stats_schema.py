"""serve/stats.py validators as pure unit tests — no engine required.

The engine-integration side (a live ``Engine.stats()`` payload passing
validation) lives in test_serve_api.py; this file pins the validator
MECHANICS: version rejection (a persisted v1 payload must be refused by
the v2 build, not half-read), and the full missing/unknown-key matrices
for the router counter validator that the regression gate leans on.
"""
import pytest

from repro.serve import stats as SS


def _gauges(**over):
    s = {k: 0 for k in SS.GAUGES}
    s["schema_version"] = SS.STATS_SCHEMA_VERSION
    s["counters"] = {k: 0 for k in SS.COUNTERS}
    s.update(over)
    return s


def test_v1_payload_rejected_by_current_build():
    """A payload persisted before the spec-decode keys existed (schema v1:
    no spec_k gauge, no drafted/accepted/rejected/accept_len_hist
    counters, version stamp 1) must be rejected outright — first on the
    version stamp, and even with a forged stamp on its key set."""
    assert SS.STATS_SCHEMA_VERSION == 3
    v1_gauges = {k: 0 for k in SS.GAUGES if k != "spec_k"}
    v1_gauges["schema_version"] = 1
    v1_counters = {k: 0 for k in SS.COUNTERS
                   if k not in ("drafted", "accepted", "rejected",
                                "accept_len_hist")}
    v1 = dict(v1_gauges, counters=v1_counters)
    # the key-set check fires first: the v1 payload is missing spec_k
    with pytest.raises(SS.StatsSchemaError, match="missing.*spec_k"):
        SS.validate_stats(v1, paged=False)
    # even a payload with a forward-ported key set must carry the current
    # version stamp — a stale stamp alone is refused
    stamped_v1 = _gauges(schema_version=1)
    with pytest.raises(SS.StatsSchemaError, match="schema_version=1"):
        SS.validate_stats(stamped_v1, paged=False)
    with pytest.raises(SS.StatsSchemaError, match="drafted"):
        SS.validate_counters(v1_counters)


def test_v2_payload_rejected_by_v3_build():
    """A v2 payload (pre-cross-replica-sharing: no published_pages /
    adopted_pages engine counters, no affinity_hits / affinity_misses
    router counters, version stamp 2) is refused on the stale stamp and,
    with a forged stamp, on its key set."""
    v2 = _gauges(schema_version=2)
    with pytest.raises(SS.StatsSchemaError, match="schema_version=2"):
        SS.validate_stats(v2, paged=False)
    v2_counters = {k: 0 for k in SS.COUNTERS
                   if k not in ("published_pages", "adopted_pages")}
    with pytest.raises(SS.StatsSchemaError,
                       match="missing=\\['adopted_pages', 'published_pages'"):
        SS.validate_counters(v2_counters)
    v2_router = {k: 0 for k in SS.ROUTER_COUNTERS
                 if k not in ("affinity_hits", "affinity_misses")}
    with pytest.raises(SS.StatsSchemaError, match="affinity_hits"):
        SS.validate_router_counters(v2_router)


def test_validate_stats_paged_flag():
    SS.validate_stats(_gauges(), paged=False)
    paged = _gauges(**{k: 0 for k in SS.PAGED_GAUGES})
    SS.validate_stats(paged, paged=True)
    # paged payload against the contiguous expectation: every paged gauge
    # reported unknown; contiguous payload against paged: all missing
    with pytest.raises(SS.StatsSchemaError) as ei:
        SS.validate_stats(paged, paged=False)
    assert all(k in str(ei.value) for k in SS.PAGED_GAUGES)
    with pytest.raises(SS.StatsSchemaError) as ei:
        SS.validate_stats(_gauges(), paged=True)
    assert all(k in str(ei.value) for k in SS.PAGED_GAUGES)


@pytest.mark.parametrize("drop", sorted(SS.ROUTER_COUNTERS))
def test_router_counters_each_missing_key_named(drop):
    counters = {k: 0 for k in SS.ROUTER_COUNTERS if k != drop}
    with pytest.raises(SS.StatsSchemaError) as ei:
        SS.validate_router_counters(counters)
    msg = str(ei.value)
    assert f"missing=['{drop}']" in msg and "unknown=[]" in msg


@pytest.mark.parametrize("extra", ["bogus", "tok_per_s", "spec_k"])
def test_router_counters_each_unknown_key_named(extra):
    counters = {k: 0 for k in SS.ROUTER_COUNTERS}
    counters[extra] = 1
    with pytest.raises(SS.StatsSchemaError) as ei:
        SS.validate_router_counters(counters)
    msg = str(ei.value)
    assert f"unknown=['{extra}']" in msg and "missing=[]" in msg


def test_router_counters_mixed_and_custom_what():
    counters = {k: 0 for k in SS.ROUTER_COUNTERS if k != "ticks"}
    counters["surprise"] = 1
    with pytest.raises(SS.StatsSchemaError,
                       match=r"my router.*missing=\['ticks'\].*"
                             r"unknown=\['surprise'\]"):
        SS.validate_router_counters(counters, what="my router")
    # the validator returns its argument so callers can chain it
    ok = {k: 0 for k in SS.ROUTER_COUNTERS}
    assert SS.validate_router_counters(ok) is ok
