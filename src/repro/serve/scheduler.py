"""Slot-table scheduler for the continuous-batching engine.

The decode graph is compiled once for a fixed number of slots; this module
owns the bookkeeping that lets requests stream through that fixed shape:
a FIFO waiting queue, a slot table, admission of waiting requests into free
slots, and eviction on completion.  It is deliberately model-agnostic — the
engine owns prefill/decode; the scheduler only decides *who sits where*.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional, Tuple


@dataclasses.dataclass
class SlotState:
    """One occupied slot of the decode batch."""
    rid: int
    request: object                 # the engine's Request
    pos: int = 0                    # next cache write position for this slot
    last_token: int = 0             # token to feed at the next decode step
    emitted: List[int] = dataclasses.field(default_factory=list)


class Scheduler:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self.waiting: Deque[Tuple[int, object]] = collections.deque()
        self._next_rid = 0

    # --- queue side -----------------------------------------------------

    def submit(self, request) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append((rid, request))
        return rid

    # --- slot side ------------------------------------------------------

    def admit(self) -> List[Tuple[int, SlotState]]:
        """Seat waiting requests in free slots (FIFO).  Returns the new
        (slot index, state) pairs; the engine prefills them and fills in
        ``pos`` / ``last_token``."""
        placed = []
        for b in range(self.n_slots):
            if self.slots[b] is not None or not self.waiting:
                continue
            rid, request = self.waiting.popleft()
            st = SlotState(rid=rid, request=request)
            self.slots[b] = st
            placed.append((b, st))
        return placed

    def evict(self, b: int) -> SlotState:
        st = self.slots[b]
        assert st is not None, f"evicting empty slot {b}"
        self.slots[b] = None
        return st

    # --- queries --------------------------------------------------------

    @property
    def active(self) -> List[int]:
        return [b for b, st in enumerate(self.slots) if st is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(st is not None for st in self.slots)

    @property
    def n_free(self) -> int:
        return sum(st is None for st in self.slots)
