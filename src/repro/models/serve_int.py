"""Integer serving forward: prefill (+KV-cache build) and single-token decode
over the folded params (models/fold.py).  This is the paper's deployment
datapath: int8 activations end-to-end, packed-int4 weights, LUT softmax,
integer LN, int8 KV cache — with documented fp islands (RoPE rotation, MoE
router/combine, SSM inner recurrence).

Depth is a lax.scan over super-block reps; the KV/SSM cache rides as scan
xs/ys with a leading (n_reps,) axis per slot.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fixedpoint as fxp
from repro.core.qlayernorm import QLNParams
from repro.core.qlinear import FoldedLinear
from repro.core.qsoftmax import MASK_OFFSET, make_exp_lut
from repro.analysis.boundary import kernel_boundary
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.flash_qattention import flash_qattention_jax
from repro.models import layers as L
from repro.models import mamba as Mb
from repro.models import xlstm as Xl
from repro.models.transformer import slot_kinds
from repro.models.fold import make_silu_lut  # noqa: F401  (re-export)


# --- primitive appliers -------------------------------------------------------

def _ln(x_i8, f, cfg):
    p = QLNParams(gamma_i=f["gamma_i"], beta_aligned=f["beta_al"],
                  M_out=f["M"], shift_out=f["sh"],
                  subtract_mean=(cfg.norm_type == "layernorm"))
    return ops.layernorm_q(x_i8, p)


def _lin(x_i8, f, w_bits):
    # w_bits is plumbed explicitly from cfg.quant at every call site — a
    # module global would leak one config's width into another's trace when
    # two configs are traced in the same process
    fl = FoldedLinear(w_packed=f["w"], bias_i=f["b"], M=f["M"], shift=f["sh"],
                      w_bits=w_bits)
    return ops.linear_w4a8(x_i8, fl)


def _lin_wonly(x_f, f):
    """Weight-only int4 linear on fp activations (SSM islands)."""
    from repro.core import packing
    w = packing.unpack_int4_planar(f["w"], axis=0).astype(jnp.float32) * f["inv_s_w"]
    y = x_f @ w
    if "b" in f:
        y = y + f["b"]
    return y


def _rescale_i8(x_i8, f):
    y = fxp.rescale(x_i8.astype(jnp.int32), f["M"], f["sh"])
    return jnp.clip(y, -127, 127).astype(jnp.int8)


def _resid_add(x_i8, f_rescale, delta_i8):
    xr = fxp.rescale(x_i8.astype(jnp.int32), f_rescale["M"], f_rescale["sh"])
    return jnp.clip(xr + delta_i8.astype(jnp.int32), -127, 127).astype(jnp.int8)


def _lut8(x_i8, lut_i8):
    """int8 -> int8 elementwise via 256-entry LUT (one-hot select)."""
    idx = x_i8.astype(jnp.int32) + 128
    return jnp.take(lut_i8, idx).astype(jnp.int8)


def _rope_island(h_i8, inv_s_in, s_out, pos, cfg, qn=None):
    """dequant -> (qk_norm) -> rotate -> requant.  (B,S,H,D) int8."""
    hf = h_i8.astype(jnp.float32) * inv_s_in
    if qn is not None:
        hf = L.rmsnorm(hf, qn)
    if cfg.mrope_sections is not None:
        hf = L.apply_mrope(hf, pos, cfg.rope_theta, cfg.mrope_sections)
    elif not cfg.learned_pos:
        hf = L.apply_rope(hf, pos, cfg.rope_theta, cfg.partial_rotary)
    return jnp.clip(jnp.round(hf * s_out), -127, 127).astype(jnp.int8)


LUT_Q7 = None  # materialized lazily (module-level jnp constants break pallas)


def _lut_q7():
    return jnp.asarray(kref.make_exp_lut_q7())


def _lut_q8():
    return jnp.asarray(make_exp_lut())


# --- attention slot -----------------------------------------------------------

def _pos_vector(pos, b):
    """Normalize a scalar-or-(B,) position argument to a (B,) int32 vector.

    The decode graph is compiled once for the whole slot table; per-slot
    positions are what let requests at different depths share one step.
    """
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))


def _attn_rows_q8(qc, kc, vc, aq, cfg, mask):
    """Materialized row attention through the decode-identical integer
    datapath (q8 LUT softmax + M_pv requant).  ``mask`` is bool or None:
    (S,Skv) shared across the batch, or (B,S,Skv) per-slot (the verify
    forward's ragged causal frontiers).  Row r is bit-identical to a decode
    step at pos r over the same KV, which is what makes one-shot cached
    prefill + continuous decode reproduce lockstep replay token-for-token."""
    group = cfg.n_heads // cfg.n_kv_heads
    kg = jnp.repeat(kc, group, axis=2)
    vg = jnp.repeat(vc, group, axis=2)
    scores = jax.lax.dot_general(
        qc.transpose(0, 2, 1, 3), kg.transpose(0, 2, 3, 1),
        (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.int32)                 # (B,H,S,S)
    if mask is not None:
        m = mask[:, None] if mask.ndim == 3 else mask[None, None]
        scores = jnp.where(m, scores, scores - MASK_OFFSET)
    probs = ops.softmax_q(scores, aq["M_idx"], aq["sh_idx"], _lut_q8())
    pv = jax.lax.dot_general(
        probs.astype(jnp.int8), vg.transpose(0, 2, 1, 3),
        (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.int32)
    return jnp.clip(fxp.rescale(pv, aq["M_pv"], aq["sh_pv"]),
                    -127, 127).astype(jnp.int8)


def _qkv_rope(x_i8, f, cfg, pos):
    """Shared attention front half: LN -> q/k/v projections -> RoPE at
    ``pos`` ((B,S) absolute positions, or (B,S,3) for mrope).  Returns
    (qc (B,S,H,hd), kc/vc (B,S,Hkv,hd)) int8."""
    b, s, _ = x_i8.shape
    wb = cfg.quant.w_bits
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = _ln(x_i8, f["ln1"], cfg)
    qc = _lin(h, f["wq"], wb).reshape(b, s, nh, hd)
    kc = _lin(h, f["wk"], wb).reshape(b, s, nkv, hd)
    vc = _lin(h, f["wv"], wb).reshape(b, s, nkv, hd)
    aq = f["attn_q"]
    qc = _rope_island(qc, aq["inv_s_qp"], aq["s_q"], pos, cfg, aq.get("qn"))
    kc = _rope_island(kc, aq["inv_s_kp"], aq["s_k"], pos, cfg, aq.get("kn"))
    return qc, kc, vc


def _flash_bkv(rows: int) -> int:
    """Largest KV block <= 512 that divides ``rows`` (flash_qattention_jax
    tiles the KV axis exactly)."""
    from repro.kernels.pallas_compat import divisor_tile
    return divisor_tile(512, rows)


def _attn_prefill(x_i8, f, cfg, pos, row_exact: bool = False):
    b, s, d = x_i8.shape
    wb = cfg.quant.w_bits
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qc, kc, vc = _qkv_rope(x_i8, f, cfg, pos)
    aq = f["attn_q"]
    if cfg.causal and row_exact:
        # decode-identical rows (see _attn_rows_q8) with a causal/SWA mask
        qpos = jnp.arange(s, dtype=jnp.int32)[:, None]
        kpos = jnp.arange(s, dtype=jnp.int32)[None, :]
        live = kpos <= qpos
        if cfg.sliding_window:
            live &= kpos > qpos - cfg.sliding_window
        ctx = _attn_rows_q8(qc, kc, vc, aq, cfg, live)
    elif cfg.causal:
        # blocked integer flash over KV (fp32 carry), per-batch vmap
        fn = lambda qq, kk, vv: flash_qattention_jax(
            qq, kk, vv, aq["M_idx"], aq["sh_idx"], _lut_q7(),
            aq["inv_s_logit"], aq["out_scale"], window=cfg.sliding_window,
            bkv=_flash_bkv(s))
        ctx = jax.vmap(fn)(qc.transpose(0, 2, 1, 3), kc.transpose(0, 2, 1, 3),
                           vc.transpose(0, 2, 1, 3))      # (B,H,S,D) int8
    else:
        # bidirectional (BERT): paper-style row LUT softmax, materialized
        ctx = _attn_rows_q8(qc, kc, vc, aq, cfg, None)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = _lin(ctx, f["wo"], wb)
    return out, kc, vc


def _decode_qkv(x_i8, f, cfg, pos_vec):
    """Decode-step front half: per-slot (B,) positions broadcast to the
    single query row, then the shared LN/qkv/RoPE path."""
    b, s, _ = x_i8.shape
    pos = (jnp.broadcast_to(pos_vec[:, None, None], (b, s, 3))
           if cfg.mrope_sections is not None
           else jnp.broadcast_to(pos_vec[:, None], (b, s)))
    return _qkv_rope(x_i8, f, cfg, pos)


def _gqa_decode_jnp(qg, k_cache, v_cache, lengths, aq):
    """Masked single-query GQA over a (B, S*, Hkv, hd) int8 KV view WITHOUT
    materializing repeated KV: q heads grouped per kv head and batched into
    the dot.  (The jnp.repeat formulation multiplies KV-cache HBM traffic
    by `group` — 16x on llama3-405b; EXPERIMENTS.md §Perf it.3.)  Rows at
    ``>= lengths[b]`` are masked to LUT-zero, so the result is independent
    of the view's padding — the contiguous (Smax) and paged (gathered
    block-table) layouts produce bit-identical context."""
    srows = k_cache.shape[1]
    kt = k_cache.transpose(0, 2, 3, 1)                # (B,kv,hd,S*) int8
    scores = jax.lax.dot_general(
        qg, kt, (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.int32)             # (B,kv,g,S*)
    slot = jnp.arange(srows)
    valid = slot[None, :] < lengths[:, None]          # (B,S*)
    scores = jnp.where(valid[:, None, None, :], scores,
                       scores - MASK_OFFSET)
    probs = ops.softmax_q(scores, aq["M_idx"], aq["sh_idx"], _lut_q8())
    vt = v_cache.transpose(0, 2, 1, 3)                # (B,kv,S*,hd)
    pv = jax.lax.dot_general(
        probs.astype(jnp.int8), vt, (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.int32)             # (B,kv,g,hd)
    return jnp.clip(fxp.rescale(pv, aq["M_pv"], aq["sh_pv"]),
                    -127, 127).astype(jnp.int8)


def _attn_decode(x_i8, f, cfg, cache, pos_offset):
    """x (B,1,d); cache {'k','v'}: (B, Smax, Hkv, hd) int8.

    ``pos_offset`` may be a traced scalar (lockstep: all slots at the same
    depth) or a traced (B,) vector of per-slot positions (continuous
    batching: every slot decodes at its own depth within one compiled step).
    """
    b, s, d = x_i8.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    smax = cache["k"].shape[1]
    pos_vec = _pos_vector(pos_offset, b)                  # (B,) int32
    qc, kc, vc = _decode_qkv(x_i8, f, cfg, pos_vec)
    aq = f["attn_q"]
    # match the cache layout before the in-place update (avoids the SPMD
    # "involuntary full rematerialization" reshard of the whole cache)
    from repro.sharding import partition as Pt
    dpax = Pt.dp_axes_or_none()
    if dpax:
        kc = Pt.constrain(kc, dpax, None, None, "model")
        vc = Pt.constrain(vc, dpax, None, None, "model")
    # per-slot ring-buffer write for SWA; plain per-slot append otherwise
    widx = (pos_vec % smax) if cfg.sliding_window else pos_vec
    upd = jax.vmap(lambda c, u, w: jax.lax.dynamic_update_slice(c, u, (w, 0, 0)))
    k_cache = upd(cache["k"], kc, widx)
    v_cache = upd(cache["v"], vc, widx)
    group = nh // nkv
    assert s == 1
    lengths = (jnp.minimum(pos_vec + 1, smax)    # valid ring prefix
               if cfg.sliding_window else pos_vec + 1)
    qg = qc.reshape(b, nkv, group, hd)                    # (B,kv,g,hd) int8
    if ops.backend() == "pallas":
        # TPU fast path: cache-native layout straight into the kernel (no
        # per-step transpose of the whole cache), one KV stream per block
        # shared by the whole q group, per-slot length masking inside.
        from repro.kernels.decode_attention import decode_qattention
        ctx = decode_qattention(
            qg, k_cache, v_cache, lengths,
            aq["M_idx"], aq["sh_idx"], _lut_q7(),
            aq["inv_s_logit"], aq["out_scale"])           # (B,kv,g,hd) int8
    else:
        ctx = _gqa_decode_jnp(qg, k_cache, v_cache, lengths, aq)
    ctx = ctx.reshape(b, nh, s, hd)                       # == (B,H,1,hd)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = _lin(ctx, f["wo"], cfg.quant.w_bits)
    return out, {"k": k_cache, "v": v_cache}


def _tp_slice(x, tp_axis, nloc, axis):
    """This rank's contiguous block of ``nloc`` heads along ``axis`` (only
    meaningful inside a shard_map over ``tp_axis``).  Q heads slice in the
    same contiguous blocks as KV heads, so GQA group structure — q head h
    reads kv head h // group — is preserved rank-locally."""
    r = jax.lax.axis_index(tp_axis)
    return jax.lax.dynamic_slice_in_dim(x, r * nloc, nloc, axis)


def _is_kv4(cslot) -> bool:
    """Packed int4 pool slots carry per-page scale leaves ('ks'/'vs')."""
    return isinstance(cslot, dict) and "ks" in cslot


@kernel_boundary(why="gathered-view int4 dequant on the jnp fallback path; "
                     "the Pallas kernels do this per tile in VMEM",
                 static_argnums=(3, 4))
def _dequant_paged_view(pool_u8, scales, block_tables, nkv_loc, hd):
    """Gather a slot-major contiguous KV view out of the PACKED pool and
    dequantize it (jnp fallback path only — the Pallas kernels dequantize
    per tile in VMEM and never build this).  (B, max_blocks*P, Hkv_loc, hd)
    int8.  Registered kernel boundary: the pool-scale float cast inside is
    the audited exemption on the ref backend."""
    from repro.core import packing
    b = block_tables.shape[0]
    pg = jnp.take(pool_u8, block_tables, axis=0)      # (B,nb,P,Hkv,hd/2) u8
    sc = jnp.take(scales, block_tables, axis=0)       # (B,nb)
    c4 = packing.unpack_int4_planar(pg, axis=-1)
    c8 = packing.dequant_int4_codes(c4, sc[:, :, None, None, None])
    return c8.reshape(b, -1, nkv_loc, hd)


def _attn_decode_paged(x_i8, f, cfg, cache, pos_offset, block_tables,
                       tp_axis=None):
    """Paged decode step: x (B,1,d); cache {'k','v'}: (n_pages, P, Hkv, hd)
    int8 global page pool; ``block_tables`` (B, max_blocks) int32 maps each
    slot's logical KV blocks onto pool pages.

    The K/V row for this token is scattered through the slot's block table
    (page = table[b, pos // P], row = pos % P); attention then reads the
    pool indirectly — block-table gather on the jnp path, scalar-prefetch
    page lookup inside the Pallas kernel.  Writes only ever land in pages
    the slot owns exclusively (refcount 1): shared prefix pages end strictly
    before the first written position (scheduler COW discipline).  Inactive
    slots (zeroed table rows) scatter into the reserved trash page 0.

    Under tensor parallelism (``tp_axis`` set, running inside a shard_map
    over that mesh axis) the pool's Hkv axis is the per-rank LOCAL slice;
    the block table stays replicated and page ids are global, so this same
    scatter/gather code addresses the rank's slice of the same pages every
    other rank touches.  Q/K/V are sliced to the rank's contiguous head
    block after the (replicated) projections, attention runs on local heads
    only, and the int8 context is all-gathered back to full heads before
    the output projection — a pure reassembly of independently-computed
    heads, so sharded decode is bit-identical to unsharded decode.
    """
    b, s, d = x_i8.shape
    assert not cfg.sliding_window, \
        "paged cache serves full-attention archs; SWA keeps the ring buffer"
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    psize = cache["k"].shape[1]
    nkv_loc = cache["k"].shape[2]                         # Hkv / tp
    pos_vec = _pos_vector(pos_offset, b)                  # (B,) int32
    qc, kc, vc = _decode_qkv(x_i8, f, cfg, pos_vec)
    aq = f["attn_q"]
    assert s == 1
    group = nh // nkv
    kv4 = _is_kv4(cache)
    kc_full, vc_full = kc, vc                             # pre-TP-slice
    if tp_axis is not None:
        nh_loc = group * nkv_loc
        qc = _tp_slice(qc, tp_axis, nh_loc, 2)
        kc = _tp_slice(kc, tp_axis, nkv_loc, 2)
        vc = _tp_slice(vc, tp_axis, nkv_loc, 2)
    else:
        assert nkv_loc == nkv, (nkv_loc, nkv)
    # write-through-table: one (Hkv_loc, hd) row per slot into its own page
    pg = jnp.take_along_axis(block_tables, (pos_vec // psize)[:, None],
                             axis=1)[:, 0]                # (B,) page ids
    row = pos_vec % psize
    if kv4:
        from repro.core import packing
        # page scale from the FULL-head codes (rank-identical under TP — a
        # sliced amax would let ranks disagree on the shared scale): the
        # row opening a page (row == 0) sets a fresh scale from its own
        # codes, later rows reuse the page's existing scale so previously
        # written rows keep dequantizing to the same values
        ks_fresh = jax.vmap(packing.kv_page_scale)(kc_full[:, 0])   # (B,)
        vs_fresh = jax.vmap(packing.kv_page_scale)(vc_full[:, 0])
        ks_pg = jnp.where(row == 0, ks_fresh, cache["ks"][pg])
        vs_pg = jnp.where(row == 0, vs_fresh, cache["vs"][pg])
        kq = packing.quantize_kv_page(kc[:, 0], ks_pg[:, None, None])
        vq = packing.quantize_kv_page(vc[:, 0], vs_pg[:, None, None])
        k_pool = cache["k"].at[pg, row].set(kq)
        v_pool = cache["v"].at[pg, row].set(vq)
        npool = {"k": k_pool, "v": v_pool,
                 "ks": cache["ks"].at[pg].set(ks_pg),
                 "vs": cache["vs"].at[pg].set(vs_pg)}
    else:
        k_pool = cache["k"].at[pg, row].set(kc[:, 0])
        v_pool = cache["v"].at[pg, row].set(vc[:, 0])
        npool = {"k": k_pool, "v": v_pool}
    lengths = pos_vec + 1
    qg = qc.reshape(b, nkv_loc, group, hd)                # (B,kv,g,hd) int8
    if ops.backend() == "pallas":
        if kv4:
            from repro.kernels.decode_attention import \
                paged_decode_qattention_q4
            ctx = paged_decode_qattention_q4(
                qg, k_pool, v_pool, npool["ks"], npool["vs"], block_tables,
                lengths, aq["M_idx"], aq["sh_idx"], _lut_q7(),
                aq["inv_s_logit"], aq["out_scale"])       # (B,kv,g,hd) int8
        else:
            from repro.kernels.decode_attention import paged_decode_qattention
            ctx = paged_decode_qattention(
                qg, k_pool, v_pool, block_tables, lengths,
                aq["M_idx"], aq["sh_idx"], _lut_q7(),
                aq["inv_s_logit"], aq["out_scale"])       # (B,kv,g,hd) int8
    else:
        # gathered per-slot view (B, max_blocks*P, Hkv_loc, hd); masking
        # makes the result bit-identical to the contiguous layout (int8)
        # resp. to the kernel's fused per-tile dequant (kv4)
        if kv4:
            k_view = _dequant_paged_view(k_pool, npool["ks"], block_tables,
                                         nkv_loc, hd)
            v_view = _dequant_paged_view(v_pool, npool["vs"], block_tables,
                                         nkv_loc, hd)
        else:
            kv_shape = (b, -1, nkv_loc, hd)
            k_view = jnp.take(k_pool, block_tables, axis=0).reshape(kv_shape)
            v_view = jnp.take(v_pool, block_tables, axis=0).reshape(kv_shape)
        ctx = _gqa_decode_jnp(qg, k_view, v_view, lengths, aq)
    if tp_axis is not None:
        # reassemble full heads (rank order == head order): int8 values
        # move, nothing is recomputed or re-rounded
        ctx = jax.lax.all_gather(ctx, tp_axis, axis=1, tiled=True)
    ctx = ctx.reshape(b, nh, s, hd)                       # == (B,H,1,hd)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = _lin(ctx, f["wo"], cfg.quant.w_bits)
    return out, npool


def _attn_prefill_paged(x_i8, f, cfg, cache, pos, block_tables, pos0,
                        row_exact, tp_axis=None):
    """Chunk prefill through the block table: queries at absolute positions
    [pos0, pos0+S) write their K/V rows into the slot's pages and attend
    over the slot's WHOLE mapped chain — shared prefix pages and earlier
    chunks (already resident in the pool) plus the rows written here.
    ``pos0`` is a page-aligned traced scalar, so one compiled shape per
    chunk size serves every chunk position: pos0 == 0 starts a prompt, a
    nonzero pos0 continues one (prefix-cache suffix or the next
    token-budget chunk).  Row-exact (q8) rows are bit-identical to decode
    steps at the same positions, so chunked prefill reproduces the one-shot
    and lockstep engines token for token on the ref/interpret backends; the
    pallas backend dispatches to the block-table-walking
    ``paged_prefill_qattention`` kernel, which streams prior-chunk KV
    straight from the page pool instead of gathering a contiguous view
    (self-consistent q7 family, like _attn_prefill).  Pad rows and
    trash-page rows sit at kpos > every real query and are causally
    masked.

    Under tensor parallelism (``tp_axis`` set) the chunk is the cross-rank
    work-division unit: every rank runs the SAME chunk on its own head
    slice of the pool (Hkv axis local, page ids global, block table
    replicated), then the int8 context all-gathers back to full heads for
    the output projection — same reassembly argument as
    ``_attn_decode_paged``, so sharded chunk prefill is bit-identical to
    unsharded on the row-exact path."""
    b, s, d = x_i8.shape
    wb = cfg.quant.w_bits
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    psize = cache["k"].shape[1]
    nkv_loc = cache["k"].shape[2]                         # Hkv / tp
    qc, kc, vc = _qkv_rope(x_i8, f, cfg, pos)
    aq = f["attn_q"]
    kv4 = _is_kv4(cache)
    kc_full, vc_full = kc, vc                             # pre-TP-slice
    if tp_axis is not None:
        nh_loc = (nh // nkv) * nkv_loc
        qc = _tp_slice(qc, tp_axis, nh_loc, 2)
        kc = _tp_slice(kc, tp_axis, nkv_loc, 2)
        vc = _tp_slice(vc, tp_axis, nkv_loc, 2)
    else:
        assert nkv_loc == nkv, (nkv_loc, nkv)
    nb_s = s // psize
    btab_slice = jax.lax.dynamic_slice_in_dim(block_tables, pos0 // psize,
                                              nb_s, axis=1)
    ncache = _paged_prefill_write(cache, kc, vc, btab_slice,
                                  kc_full=kc_full, vc_full=vc_full)
    # kv4 drops the row-exact q8 identity claim by construction (a
    # decode-written page's scale comes from its first row, a prefill-
    # written page's from the whole page), so it always takes the q7
    # paged family — the quality-A/B contract, not the identity one
    if row_exact and not kv4:
        kv_shape = (b, -1, nkv_loc, hd)
        k_view = jnp.take(ncache["k"], block_tables, axis=0).reshape(kv_shape)
        v_view = jnp.take(ncache["v"], block_tables, axis=0).reshape(kv_shape)
        rows = k_view.shape[1]
        qpos = pos0 + jnp.arange(s, dtype=jnp.int32)[:, None]
        kpos = jnp.arange(rows, dtype=jnp.int32)[None, :]
        ctx = _attn_rows_q8(qc, k_view, v_view, aq, cfg, kpos <= qpos)
    elif kv4:
        pos0_vec = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32).reshape(-1),
                                    (b,))
        ctx = ops.paged_prefill_attention_q4(
            qc.transpose(0, 2, 1, 3), ncache["k"], ncache["v"],
            ncache["ks"], ncache["vs"], block_tables, pos0_vec,
            aq["M_idx"], aq["sh_idx"], _lut_q7(),
            aq["inv_s_logit"], aq["out_scale"])           # (B,H,S,hd) int8
    else:
        pos0_vec = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32).reshape(-1),
                                    (b,))
        ctx = ops.paged_prefill_attention_q(
            qc.transpose(0, 2, 1, 3), ncache["k"], ncache["v"],
            block_tables, pos0_vec, aq["M_idx"], aq["sh_idx"], _lut_q7(),
            aq["inv_s_logit"], aq["out_scale"])           # (B,H,S,hd) int8
    if tp_axis is not None:
        ctx = jax.lax.all_gather(ctx, tp_axis, axis=1, tiled=True)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = _lin(ctx, f["wo"], wb)
    return out, ncache


def _paged_prefill_write(cache, kc, vc, block_tables, kc_full=None,
                         vc_full=None):
    """Scatter a prefill chunk's K/V rows (B, S, Hkv, hd) into the page pool
    through the block table.  S must be a whole number of pages and every
    table entry a page the request owns — pad rows land inside owned pages
    (masked or overwritten by decode, same argument as the contiguous
    bucketed prefill).

    On a packed (kv4) pool each written page is quantized to int4 codes
    under ONE shared scale computed from the page's FULL-head codes
    (``kc_full``/``vc_full``, pre-TP-slice — every rank derives the same
    scale) and nibble-packed along hd; the scale leaves update in the same
    scatter so payload and scale always travel together."""
    psize = cache["k"].shape[1]
    b, s = kc.shape[0], kc.shape[1]
    nb = s // psize
    assert nb * psize == s and block_tables.shape[1] == nb, \
        (s, psize, block_tables.shape)
    kr = kc.reshape(b, nb, psize, *kc.shape[2:])
    vr = vc.reshape(b, nb, psize, *vc.shape[2:])
    if _is_kv4(cache):
        from repro.core import packing
        kfr = (kc if kc_full is None else kc_full).reshape(
            b, nb, psize, *((kc if kc_full is None else kc_full).shape[2:]))
        vfr = (vc if vc_full is None else vc_full).reshape(
            b, nb, psize, *((vc if vc_full is None else vc_full).shape[2:]))
        ks = jax.vmap(jax.vmap(packing.kv_page_scale))(kfr)       # (b, nb)
        vs = jax.vmap(jax.vmap(packing.kv_page_scale))(vfr)
        kq = packing.quantize_kv_page(kr, ks[:, :, None, None, None])
        vq = packing.quantize_kv_page(vr, vs[:, :, None, None, None])
        return {"k": cache["k"].at[block_tables].set(kq),
                "v": cache["v"].at[block_tables].set(vq),
                "ks": cache["ks"].at[block_tables].set(ks),
                "vs": cache["vs"].at[block_tables].set(vs)}
    return {"k": cache["k"].at[block_tables].set(kr),
            "v": cache["v"].at[block_tables].set(vr)}


def _attn_verify_paged(x_i8, f, cfg, cache, pos_vec, block_tables, n_rows,
                       row_exact, tp_axis=None):
    """Speculative verify step: score S = k+1 candidate rows per slot in ONE
    forward — row 0 is the slot's committed last token, rows 1..k its draft
    proposals.  ``pos_vec`` (B,) is each slot's decode cursor (the absolute
    position of row 0); ``n_rows`` (B,) is each slot's REAL row count (1 +
    its ragged proposal length) — columns at or past it are padding.

    This is the chunk-prefill datapath driven decode-style: K/V rows
    scatter per (page, row) through the block table exactly like
    ``_attn_decode_paged`` (positions here are NOT page-aligned, so the
    whole-page prefill scatter does not apply), and attention reads the
    slot's whole mapped chain with per-slot causal frontiers.  Row i is
    bit-identical to a plain decode step at position ``pos_vec[b] + i``
    over the same KV prefix (row-exact backends), which is the property
    the greedy acceptance rule leans on: accepted tokens are exactly the
    tokens plain decode would have produced.

    Padding columns redirect their scatter to trash page 0; real columns
    past a slot's eventual accepted prefix leave garbage K/V rows ABOVE
    the slot's rolled-back cursor — positions the causal length masks hide
    until the cursor re-crosses them, at which point the owner rewrites
    them (same argument as chunk-prefill pad rows).  The allocator is
    untouched: pages were grown through ``Scheduler.grow`` before the
    forward and stay owned through rollback.

    Under tensor parallelism the same head-slice / all-gather scheme as
    ``_attn_decode_paged`` applies (replicated block tables and positions,
    rank-local Hkv slice, contexts reassembled before the output
    projection), so sharded verify stays bit-identical to unsharded."""
    b, s, d = x_i8.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    psize = cache["k"].shape[1]
    nkv_loc = cache["k"].shape[2]                         # Hkv / tp
    assert not _is_kv4(cache), \
        "speculative verify serves the int8 pool (spec x kv4: ROADMAP)"
    assert cfg.mrope_sections is None, \
        "speculative verify does not serve mrope archs yet"
    positions = pos_vec[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    qc, kc, vc = _qkv_rope(x_i8, f, cfg, positions)
    aq = f["attn_q"]
    if tp_axis is not None:
        nh_loc = (nh // nkv) * nkv_loc
        qc = _tp_slice(qc, tp_axis, nh_loc, 2)
        kc = _tp_slice(kc, tp_axis, nkv_loc, 2)
        vc = _tp_slice(vc, tp_axis, nkv_loc, 2)
    else:
        assert nkv_loc == nkv, (nkv_loc, nkv)
    # decode-style per-row scatter, vectorized over the S columns; padding
    # columns land in the trash page (the block table would already map
    # beyond-chain positions there, but padding must not touch the last
    # real page's rows either)
    valid = jnp.arange(s, dtype=jnp.int32)[None, :] < n_rows[:, None]
    pg = jnp.take_along_axis(block_tables, positions // psize, axis=1)
    pg = jnp.where(valid, pg, 0)
    row = jnp.where(valid, positions % psize, 0)
    ncache = {"k": cache["k"].at[pg, row].set(kc),
              "v": cache["v"].at[pg, row].set(vc)}
    if row_exact:
        # gathered chain view + per-slot causal frontier: row i of slot b
        # attends rows [0, pos_vec[b] + i] — bit-identical to the decode
        # step at that position (see _attn_rows_q8)
        kv_shape = (b, -1, nkv_loc, hd)
        k_view = jnp.take(ncache["k"], block_tables, axis=0).reshape(kv_shape)
        v_view = jnp.take(ncache["v"], block_tables, axis=0).reshape(kv_shape)
        rows = k_view.shape[1]
        kpos = jnp.arange(rows, dtype=jnp.int32)[None, None, :]
        ctx = _attn_rows_q8(qc, k_view, v_view, aq, cfg,
                            kpos <= positions[:, :, None])
    else:
        # the paged prefill kernel IS the verifier: per-slot pos0 rides the
        # scalar-prefetch argument (its frontier math never needed a
        # page-aligned start), blocks past a chain are causally dead
        ctx = ops.paged_prefill_attention_q(
            qc.transpose(0, 2, 1, 3), ncache["k"], ncache["v"],
            block_tables, pos_vec, aq["M_idx"], aq["sh_idx"], _lut_q7(),
            aq["inv_s_logit"], aq["out_scale"])           # (B,H,S,hd) int8
    if tp_axis is not None:
        ctx = jax.lax.all_gather(ctx, tp_axis, axis=1, tiled=True)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = _lin(ctx, f["wo"], cfg.quant.w_bits)
    return out, ncache


# --- ffn slots ----------------------------------------------------------------

def _mlp_int(x_i8, f, cfg):
    wb = cfg.quant.w_bits
    h = _ln(x_i8, f["ln2"], cfg)
    if cfg.act == "swiglu":
        g = _lin(h, f["wg"], wb)
        u = _lin(h, f["wu"], wb)
        g = _lut8(g, f["silu_lut"])
        prod = g.astype(jnp.int32) * u.astype(jnp.int32)       # int16-range
        hh = jnp.clip(fxp.rescale(prod, f["prod"]["M"], f["prod"]["sh"]),
                      -127, 127).astype(jnp.int8)
        return _lin(hh, f["wd"], wb)
    g = _lin(h, f["w1"], wb)
    g = _lut8(g, f["gelu_lut"])
    g = _rescale_i8(g, f["gelu_rescale"])
    return _lin(g, f["w2"], wb)


def _moe_int(x_i8, f, cfg):
    """Integer experts; fp32 router + combine (documented islands)."""
    from repro.models.moe import capacity, topk_routing, scatter_dispatch, \
        gather_combine
    b, s, d = x_i8.shape
    t = b * s
    h = _ln(x_i8, f["ln2"], cfg).reshape(t, d)
    hf = h.astype(jnp.float32) * f["inv_s_mi"]
    gate_logits = hf @ f["router"]
    cap = capacity(t, cfg.n_experts, cfg.top_k)
    dest, gates, _ = topk_routing(gate_logits, cfg.top_k, cap)
    # integer dispatch: rows are moved, padding is 0 (on-grid), codes exact
    xe = scatter_dispatch(h, dest, cfg.n_experts, cap)
    xe = xe.reshape(cfg.n_experts, cap, d)
    fe = f["experts"]

    wb = cfg.quant.w_bits

    def expert_ffn(xe_i8, grp):
        def one(x1, wg, wu, wd):
            g = _lin(x1, wg, wb)
            u = _lin(x1, wu, wb)
            g = _lut8(g, grp["silu_lut"])
            prod = g.astype(jnp.int32) * u.astype(jnp.int32)
            hh = jnp.clip(fxp.rescale(prod, grp["prod"]["M"], grp["prod"]["sh"]),
                          -127, 127).astype(jnp.int8)
            return _lin(hh, wd, wb)
        return jax.vmap(one)(xe_i8, grp["wg"], grp["wu"], grp["wd"])

    ye = expert_ffn(xe, fe)                                     # (E,C,d) int8
    yf = ye.astype(jnp.float32) * fe["inv_s_out"]
    yt = gather_combine(yf.reshape(cfg.n_experts * cap, d), dest, gates,
                        jnp.float32)
    if "shared" in f:
        sh = f["shared"]
        xs = jnp.broadcast_to(h[None], (cfg.n_shared_experts, t, d))
        ys = expert_ffn(xs, sh)
        yt = yt + jnp.sum(ys.astype(jnp.float32) * sh["inv_s_out"], 0)
    y_i8 = jnp.clip(jnp.round(yt * f["s_rm"]), -127, 127).astype(jnp.int8)
    return y_i8.reshape(b, s, d)


# --- ssm slots (weight-only int4, fp core — DESIGN.md §4) ----------------------

def _mamba_int(x_i8, f, cfg, state):
    b, s, d = x_i8.shape
    h = _ln(x_i8, f["ln1"], cfg)
    hf = h.astype(jnp.float32) * f["inv_s_in"]
    m = f["mx"]
    d_in, dt_rank = Mb.mamba_dims(cfg)
    n = cfg.mamba_d_state
    xz = _lin_wonly(hf, m["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = Mb._causal_conv(xi, m["conv_w"],
                                     None if state is None else state["conv"])
    xc = jax.nn.silu(xc + m["conv_b"])
    prm = _lin_wonly(xc, m["w_x"])
    dt_r, B_, C_ = jnp.split(prm, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ m["w_dt"] + m["dt_bias"])
    A = -jnp.exp(m["A_log"])
    if state is None:
        y = Mb._ssm_chunked(xc, dt, B_, C_, A, m["D"])
        new_state = None
    else:
        a = jnp.exp(dt[:, 0, :, None] * A)
        hstate = a * state["h"] + (dt[:, 0] * xc[:, 0])[..., None] * B_[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", hstate, C_[:, 0])[:, None] + xc * m["D"]
        new_state = {"h": hstate, "conv": conv_state}
    y = y * jax.nn.silu(z)
    out = _lin_wonly(y, m["w_out"])
    out_i8 = jnp.clip(jnp.round(out * f["s_ra"]), -127, 127).astype(jnp.int8)
    return out_i8, new_state


def _xlstm_int(x_i8, f, cfg, state, kind):
    b, s, d = x_i8.shape
    h = _ln(x_i8, f["ln1"], cfg)
    hf = (h.astype(jnp.float32) * f["inv_s_in"]).astype(jnp.float32)
    m = f["mx"]

    def lw(name):
        return lambda xx: _lin_wonly(xx, m[name])

    if kind == "mlstm":
        nh = cfg.n_heads
        qh = Xl._heads(lw("wq")(hf), nh)
        kh = Xl._heads(lw("wk")(hf), nh) / math.sqrt(d // nh)
        vh = Xl._heads(lw("wv")(hf), nh)
        gi = hf @ m["w_ig"] + m["b_ig"]
        gf = hf @ m["w_fg"] + m["b_fg"]
        logf = jax.nn.log_sigmoid(gf)
        if state is None:
            y = Xl.mlstm_parallel(qh, kh, vh, gi, logf)
            new_state = None
        else:
            qt, kt, vt = qh[:, 0], kh[:, 0], vh[:, 0]
            git, logft = gi[:, 0], logf[:, 0]
            m_new = jnp.maximum(logft + state["m"], git)
            fdec = jnp.exp(logft + state["m"] - m_new)[..., None]
            iinc = jnp.exp(git - m_new)[..., None]
            C = fdec[..., None] * state["C"] + iinc[..., None] * (
                kt[..., :, None] * vt[..., None, :])
            nvec = fdec * state["n"] + iinc * kt
            num = jnp.einsum("bhe,bhef->bhf", qt, C)
            den = jnp.maximum(jnp.abs(jnp.sum(nvec * qt, -1)), jnp.exp(-m_new))
            y = (num / den[..., None])[:, None]
            new_state = {"C": C, "n": nvec, "m": m_new}
        y = y.reshape(b, s, d)
        og = jax.nn.sigmoid(hf @ m["w_og"] + m["b_og"])
        y = L.rmsnorm(y, m["ln_y"]) * og
        out = _lin_wonly(y, m["wo"])
    else:  # slstm — reuse the QAT fp implementation on dequantized input
        pol_off = cfg.quant
        params_fp = {k: (v if not (isinstance(v, dict)) else v) for k, v in m.items()}
        # reconstruct float weights from weight-only folds
        from repro.core import packing
        def unw(t):
            return (packing.unpack_int4_planar(t["w"], axis=0).astype(jnp.float32)
                    * t["inv_s_w"]) if isinstance(t, dict) and "w" in t else t
        pf = {k: unw(v) for k, v in m.items()}
        amax_stub = {kk: jnp.float32(0) for kk in Xl.SLSTM_SITES}
        import dataclasses as _dc
        cfg_fp = _dc.replace(cfg, quant=_dc.replace(cfg.quant, quantize_wa=False))
        y, _, new_state = Xl.slstm_qat(hf, pf, amax_stub, cfg_fp.quant, cfg_fp,
                                       state)
        out = y
    out_i8 = jnp.clip(jnp.round(out * f["s_ra"]), -127, 127).astype(jnp.int8)
    return out_i8, new_state


# --- whole-model serving forward -----------------------------------------------

def cache_rows(cfg: ModelConfig, max_len: int) -> int:
    """KV rows allocated per slot (the SWA ring buffer is window-sized).
    Single source of truth shared by init_cache and the serving engine's
    one-shot-prefill eligibility check."""
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Per-slot decode state, stacked (n_reps, ...)."""
    kinds = slot_kinds(cfg)
    smax = cache_rows(cfg, max_len)
    cache = {}
    for i, (mixer, _) in enumerate(kinds):
        if mixer == "attn":
            c = {"k": jnp.zeros((cfg.n_reps, batch, smax, cfg.n_kv_heads, cfg.hd),
                                jnp.int8),
                 "v": jnp.zeros((cfg.n_reps, batch, smax, cfg.n_kv_heads, cfg.hd),
                                jnp.int8)}
        elif mixer == "mamba":
            d_in, _ = Mb.mamba_dims(cfg)
            c = {"h": jnp.zeros((cfg.n_reps, batch, d_in, cfg.mamba_d_state),
                                jnp.float32),
                 "conv": jnp.zeros((cfg.n_reps, batch, cfg.mamba_d_conv - 1, d_in),
                                   jnp.float32)}
        elif mixer == "mlstm":
            dh = cfg.d_model // cfg.n_heads
            c = {"C": jnp.zeros((cfg.n_reps, batch, cfg.n_heads, dh, dh), jnp.float32),
                 "n": jnp.zeros((cfg.n_reps, batch, cfg.n_heads, dh), jnp.float32),
                 "m": jnp.zeros((cfg.n_reps, batch, cfg.n_heads), jnp.float32)}
        else:  # slstm
            dh = cfg.d_model // cfg.n_heads
            z = lambda: jnp.zeros((cfg.n_reps, batch, cfg.n_heads, dh), jnp.float32)
            c = {"c": z(), "n": z(), "h": z(), "m": z()}
        cache[f"slot{i}"] = c
    return cache


def paged_page_nbytes(cfg: ModelConfig, page_size: int,
                      kv_bits: int = 8) -> int:
    """HBM bytes one pool page occupies across every rep/slot leaf of the
    ``init_paged_cache`` pytree (K + V payload, plus the two fp32 page
    scales at ``kv_bits=4``).  The allocator carries this for pool-bytes
    accounting: at 4 bits a fixed byte budget holds ~2x the pages."""
    kinds = slot_kinds(cfg)
    hd = cfg.hd // 2 if kv_bits == 4 else cfg.hd
    per = 2 * page_size * cfg.n_kv_heads * hd       # k + v payload bytes
    if kv_bits == 4:
        per += 2 * 4                                 # ks + vs fp32 scales
    return cfg.n_reps * len(kinds) * per


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     kv_bits: int = 8) -> Dict:
    """Global paged KV pool, stacked (n_reps, n_pages, P, Hkv, hd) per attn
    slot.  Pages are position-agnostic: a slot's (max_blocks,) block-table
    row, not the pool layout, decides which rows belong to which request.
    Only all-attention archs page (SSM/xLSTM state is O(1) per slot and
    SWA already ring-buffers to the window).

    ``kv_bits=4`` switches each slot to the PACKED layout: payload leaves
    become (n_reps, n_pages, P, Hkv, hd//2) uint8 (nibble-planar along hd —
    half the pool bytes) plus per-page fp32 shared-scale leaves 'ks'/'vs'
    of shape (n_reps, n_pages).  The trash-page scale initializes to the
    all-zero page's well-defined scale (1/7) so dead reads stay exact
    zeros."""
    kinds = slot_kinds(cfg)
    assert all(m == "attn" for m, _ in kinds) and not cfg.sliding_window, \
        "paged cache requires an all-attention, non-SWA arch"
    assert kv_bits in (8, 4), kv_bits
    if kv_bits == 4:
        from repro.core import packing
        assert cfg.hd % 2 == 0, cfg.hd
        shape = (cfg.n_reps, n_pages, page_size, cfg.n_kv_heads, cfg.hd // 2)
        sshape = (cfg.n_reps, n_pages)
        # NB: one jnp.full per leaf — sharing a single scale array across
        # leaves would alias buffers and break donate_argnums on the pool
        def s0():
            return jnp.full(sshape, 1.0 / packing.KV4_QMAX, jnp.float32)
        return {f"slot{i}": {"k": jnp.zeros(shape, jnp.uint8),
                             "v": jnp.zeros(shape, jnp.uint8),
                             "ks": s0(), "vs": s0()}
                for i in range(len(kinds))}
    shape = (cfg.n_reps, n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    return {f"slot{i}": {"k": jnp.zeros(shape, jnp.int8),
                         "v": jnp.zeros(shape, jnp.int8)}
            for i in range(len(kinds))}


def _embed_int(cfg, folded, tokens):
    if cfg.frontend == "audio_codebooks":
        acc = sum(jnp.take(folded["embed"]["codebooks_i8"][ci], tokens[:, ci], 0
                           ).astype(jnp.int32) for ci in range(cfg.n_codebooks))
        return jnp.clip(acc, -127, 127).astype(jnp.int8)
    return jnp.take(folded["embed"]["tokens_i8"], tokens, axis=0)


def serve_forward(
    cfg: ModelConfig,
    folded: Dict,
    tokens: jax.Array,
    *,
    cache: Optional[Dict] = None,
    pos_offset: jax.Array | int = 0,
    mode: str = "prefill",            # prefill | decode | verify
    block_tables: Optional[jax.Array] = None,
    verify_rows: Optional[jax.Array] = None,
    extra_embeds_i8: Optional[jax.Array] = None,
    pos3: Optional[jax.Array] = None,
    tp_axis: Optional[str] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Integer forward.

    prefill without cache: tokens (B,S) -> logits (evaluation path, no cache
    update).  prefill WITH cache (attention archs only): additionally writes
    the per-layer K/V rows for positions [pos_offset, pos_offset+S) into the
    cache and returns it — the chunk-forward path of the continuous-batching
    engine (pos_offset == 0 and S == prompt length is the one-shot special
    case), computed through the decode-identical row datapath so a later
    chunk or decode continues bit-exactly.  decode: tokens (B,1) + cache ->
    (logits, new_cache); ``pos_offset`` is a scalar or a per-slot (B,)
    vector.

    verify (paged layouts only): tokens (B, k+1) holds each slot's last
    committed token followed by its draft proposals; ``pos_offset`` is the
    per-slot (B,) decode cursor and ``verify_rows`` (B,) each slot's real
    row count (ragged proposals ride one padded shape).  Every row's logits
    come back (B, k+1, vocab), each bit-identical (row-exact backends) to
    the decode step plain decode would have run at that position — the
    verifier half of speculative decoding (see ``_attn_verify_paged``).

    ``block_tables`` (B, max_blocks) int32 switches the cache layout to the
    paged pool (``init_paged_cache``): both the prefill scatter and the
    decode read/write then indirect through each slot's block-table row
    inside the depth scan instead of addressing a contiguous Smax stripe.

    ``tp_axis`` names the mesh axis of a tensor-parallel shard_map this
    forward is running inside (paged layouts only): the pool's Hkv axis is
    then the per-rank local slice, attention runs on the rank's contiguous
    head block, and contexts all-gather back to full heads before the
    output projection.  Everything outside attention is replicated compute
    on replicated data, so the returned logits are replicated and the whole
    sharded forward stays bit-identical to the unsharded one.
    """
    kinds = slot_kinds(cfg)
    assert tp_axis is None or block_tables is not None, \
        "tensor parallelism serves the paged cache layout only"
    assert mode != "verify" or (cache is not None
                                and block_tables is not None
                                and verify_rows is not None), \
        "verify mode needs a paged cache, block tables, and verify_rows"
    x = _embed_int(cfg, folded, tokens)
    if extra_embeds_i8 is not None:
        x = jnp.concatenate([extra_embeds_i8, x], axis=1)
    b, s = x.shape[0], x.shape[1]
    # prefill at a nonzero pos_offset continues an existing chain (the paged
    # suffix prefill after a prefix-cache hit); pos0 stays a traced scalar
    pos0 = jnp.asarray(pos_offset, jnp.int32).reshape(-1)[0]
    vpos = (_pos_vector(pos_offset, b) if mode == "verify" else None)  # (B,)
    if cfg.learned_pos:
        if mode == "decode":
            posrow = jnp.take(folded["embed"]["pos_i8"],
                              _pos_vector(pos_offset, b), axis=0)[:, None]
        elif mode == "verify":
            grid = vpos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            posrow = jnp.take(folded["embed"]["pos_i8"], grid, axis=0)
        else:
            posrow = jax.lax.dynamic_slice_in_dim(
                folded["embed"]["pos_i8"], pos0, s, axis=0)[None]
        x = jnp.clip(x.astype(jnp.int32) + posrow.astype(jnp.int32),
                     -127, 127).astype(jnp.int8)
    if mode in ("decode", "verify"):
        pos = None
    else:
        pos = jnp.broadcast_to(pos0 + jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.mrope_sections is not None:
            pos = pos3 if pos3 is not None else jnp.broadcast_to(
                pos[..., None], (*pos.shape, 3))

    def body(x_i8, f_rep, cache_rep):
        new_cache_rep = {}
        for i, (mixer, ffn) in enumerate(kinds):
            f = f_rep[f"slot{i}"]
            cslot = None if cache_rep is None else cache_rep[f"slot{i}"]
            if mixer == "attn":
                if mode == "decode":
                    out, nc = (
                        _attn_decode_paged(x_i8, f, cfg, cslot, pos_offset,
                                           block_tables, tp_axis=tp_axis)
                        if block_tables is not None
                        else _attn_decode(x_i8, f, cfg, cslot, pos_offset))
                elif mode == "verify":
                    out, nc = _attn_verify_paged(
                        x_i8, f, cfg, cslot, vpos, block_tables, verify_rows,
                        row_exact=ops.backend() != "pallas", tp_axis=tp_axis)
                else:
                    # cached prefill matches the decode datapath per backend:
                    # row-exact q8 softmax mirrors the jnp decode (bit-exact
                    # continuation); on pallas both sides use the q7 flash
                    # family instead (self-consistent, not bit-identical)
                    row_exact = cslot is not None and ops.backend() != "pallas"
                    if cslot is not None and block_tables is not None:
                        # chunk (or one-shot / suffix-only) prefill written
                        # and read through the block table
                        out, nc = _attn_prefill_paged(
                            x_i8, f, cfg, cslot, pos, block_tables, pos0,
                            row_exact, tp_axis=tp_axis)
                    else:
                        out, kc, vc = _attn_prefill(x_i8, f, cfg, pos,
                                                    row_exact=row_exact)
                        # one-shot prefill into the contiguous stripe
                        nc = (None if cslot is None else
                              {"k": jax.lax.dynamic_update_slice(
                                        cslot["k"], kc, (0, 0, 0, 0)),
                               "v": jax.lax.dynamic_update_slice(
                                        cslot["v"], vc, (0, 0, 0, 0))})
            elif mixer == "mamba":
                out, nc = _mamba_int(x_i8, f, cfg,
                                     cslot if mode == "decode" else None)
            else:
                out, nc = _xlstm_int(x_i8, f, cfg,
                                     cslot if mode == "decode" else None, mixer)
            new_cache_rep[f"slot{i}"] = nc if nc is not None else cslot
            x_i8 = _resid_add(x_i8, f["res_a"], out)
            if ffn == "dense":
                out = _mlp_int(x_i8, f, cfg)
                x_i8 = _resid_add(x_i8, f["res_m"], out)
            elif ffn == "moe":
                out = _moe_int(x_i8, f, cfg)
                x_i8 = _resid_add(x_i8, f["res_m"], out)
        x_i8 = _rescale_i8(x_i8, f_rep["block_out_rescale"])
        return x_i8, new_cache_rep

    def scan_body(carry, xs):
        if cache is None:
            f_rep = xs
            y, _ = body(carry, f_rep, None)
            return y, None
        f_rep, cache_rep = xs
        y, nc = body(carry, f_rep, cache_rep)
        return y, nc

    if cache is None:
        x, _ = jax.lax.scan(scan_body, x, folded["blocks"])
        new_cache = None
    else:
        x, new_cache = jax.lax.scan(scan_body, x, (folded["blocks"], cache))

    x = _ln(x, folded["final_norm"], cfg)
    head = folded["lm_head"]

    def head_apply(hw):
        from repro.core import packing
        w = (hw["w"] if cfg.quant.w_bits == 8 else
             packing.unpack_int4_planar(hw["w"], axis=0)).astype(jnp.int8)
        acc = jax.lax.dot_general(x, w, (((2,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * hw["inv_acc"]

    logits = (jnp.stack([head_apply(jax.tree.map(lambda t: t[i], head))
                         for i in range(cfg.n_lm_heads)], axis=1)
              if cfg.n_lm_heads > 1 and not cfg.tied_embeddings
              else head_apply(head))
    return logits, new_cache
