"""Jamba-1.5-Large 398B  [arXiv:2403.19887] — Mamba:attn 7:1, MoE 16e top-2
every other layer (attn at slot 4 of each 8-layer super-block)."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24_576, vocab_size=65_536,
    n_experts=16, top_k=2, moe_d_ff=24_576, moe_period=2, moe_offset=1,
    block_pattern=("m", "m", "m", "m", "a", "m", "m", "m"),
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    param_dtype="bfloat16",
))
