"""Continuous-batching engine: scheduler mechanics, token-for-token
equivalence with the lockstep baseline, and mid-flight admission."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import Engine, LockstepEngine, Request, make_engine
from repro.serve.scheduler import Scheduler

KEY = jax.random.PRNGKey(0)


# --- scheduler unit tests -----------------------------------------------------

def test_scheduler_fifo_admission_and_eviction():
    s = Scheduler(2)
    rids = [s.submit(f"req{i}") for i in range(4)]
    assert rids == [0, 1, 2, 3]
    placed = s.admit()
    assert [(b, st.rid) for b, st in placed] == [(0, 0), (1, 1)]
    assert s.n_free == 0 and len(s.waiting) == 2
    assert s.admit() == []                     # table full -> no-op
    s.evict(0)
    placed = s.admit()                         # freed slot takes next FIFO
    assert [(b, st.rid) for b, st in placed] == [(0, 2)]
    assert s.active == [0, 1]
    s.evict(0)
    s.evict(1)
    placed = s.admit()
    assert [(b, st.rid) for b, st in placed] == [(0, 3)]
    s.evict(0)
    assert not s.has_work


def test_scheduler_evict_empty_slot_asserts():
    s = Scheduler(1)
    with pytest.raises(AssertionError):
        s.evict(0)


# --- engine equivalence -------------------------------------------------------

def _folded(cfg):
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return F.fold_params(cfg, params, obs)


def _mixed_requests(cfg, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, (ln,)
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for ln, mn in zip(lens, max_news)]


@pytest.mark.parametrize("layout,kw", [
    ("contiguous", {}),
    ("paged", dict(page_size=8)),
    ("paged", dict(page_size=4, n_pages=9)),   # tight pool: admission stalls
])
def test_continuous_matches_lockstep_token_for_token(layout, kw):
    """Greedy continuous batching (one-shot prefill, per-slot positions,
    mid-flight admission) must reproduce, per request, exactly what the
    lockstep engine produces for that request alone — in BOTH cache
    layouts: the contiguous slot stripes and the paged block-table pool
    (including with a pool small enough to force out-of-pages waits)."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    lens = [3, 11, 6, 17, 5]
    max_news = [4, 6, 5, 3, 6]

    lock = LockstepEngine(cfg, folded, batch_slots=1, max_len=64)
    truth = []
    for r in _mixed_requests(cfg, lens, max_news):
        lock.reset()
        truth.append(lock.generate([r])[0].out.tolist())

    eng = Engine(cfg, folded, batch_slots=2, max_len=64, prefill_bucket=4,
                 cache_layout=layout, **kw)
    assert eng.layout == layout
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    got = [r.out.tolist() for r in out]
    assert got == truth
    # more requests than slots -> the scheduler really streamed them
    assert eng.stats["completed"] == len(lens)
    assert eng.stats["oneshot_prefills"] == len(lens)
    assert eng.stats["loop_prefill_steps"] == 0
    if layout == "paged":
        # reservation-based pool: peak pages reflect actual, not worst-case,
        # sequence memory — strictly under the contiguous footprint
        assert 0 < eng.stats["cache_pages_peak"] <= eng.alloc.capacity
        assert eng.alloc.live == 0                # all pages came back


def test_engine_streaming_admission_and_determinism():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=2, max_len=64)

    def run():
        eng.reset()
        reqs = _mixed_requests(cfg, [4, 9, 6, 5], [5, 5, 5, 5], seed=3)
        return [r.out.tolist() for r in eng.generate(reqs)]

    a, b = run(), run()
    assert a == b                       # greedy decode is deterministic
    assert all(len(o) == 5 for o in a)


def test_engine_eos_eviction_frees_slot():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=1, max_len=64)
    # discover the greedy continuation, then rerun with it as the EOS token
    probe = _mixed_requests(cfg, [5, 7], [6, 6], seed=1)
    out = eng.generate(probe)
    eos = int(out[0].out[2])            # third emitted token of request 0
    eng.reset()
    reqs = _mixed_requests(cfg, [5, 7], [6, 6], seed=1)
    reqs[0].eos_token = eos
    out2 = eng.generate(reqs)
    assert out2[0].out.tolist() == out[0].out.tolist()[:3]  # stopped at EOS
    assert out2[1].out.tolist() == out[1].out.tolist()      # unaffected
    assert eng.stats["completed"] == 2


def test_engine_rejects_overlong_request():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(12, np.int32), max_new_tokens=8))


def test_paged_rejects_request_larger_than_pool():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=2, max_len=64, cache_layout="paged",
                 page_size=4, n_pages=3)         # 2 allocatable pages
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(10, np.int32), max_new_tokens=4))


def test_paged_prefix_reuse_skips_prefill_and_pages():
    """Requests repeating one system prompt must map its cached pages
    (refcounted sharing), run only the unseen suffix, produce tokens
    identical to the contiguous engine, and use fewer peak pages than
    exclusive stripes would."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(0, cfg.vocab_size, (24,)).astype(np.int32)

    def requests(seed):
        r = np.random.default_rng(seed)
        return [Request(prompt=np.concatenate(
                    [sys_prompt,
                     r.integers(0, cfg.vocab_size, (3 + i,)).astype(np.int32)]),
                    max_new_tokens=4)
                for i in range(5)]

    cont = Engine(cfg, folded, batch_slots=2, max_len=64,
                  cache_layout="contiguous")
    truth = [r.out.tolist() for r in cont.generate(requests(7))]

    eng = Engine(cfg, folded, batch_slots=2, max_len=64, cache_layout="paged",
                 page_size=8)
    out = eng.generate(requests(7))
    assert [r.out.tolist() for r in out] == truth
    # first request prefills one-shot; the other four share its prefix pages
    assert eng.stats["oneshot_prefills"] == 1
    assert eng.stats["prefix_hits"] == 4
    assert eng.stats["shared_rows"] == 4 * 24     # 3 pages x 8 rows each
    # paged peak well under the contiguous footprint (2 slots x smax rows)
    assert eng.stats["cache_pages_peak"] < eng.batch * eng.max_blocks
    # prefix pages stay cached (LRU) after every sharer finished
    assert eng.alloc.live == 0 and eng.alloc.cached_pages > 0


def test_paged_prefix_cache_survives_eviction():
    """The prefix registry keeps refcount-0 pages cached: a request arriving
    AFTER every earlier sharer completed still hits."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (17,)).astype(np.int32)
    eng = Engine(cfg, folded, batch_slots=1, max_len=64, cache_layout="paged",
                 page_size=8)
    first = eng.generate([Request(prompt=prompt.copy(), max_new_tokens=4)])
    assert eng.stats["prefix_hits"] == 0
    second = eng.generate([Request(prompt=prompt.copy(), max_new_tokens=4)])
    assert eng.stats["prefix_hits"] == 1
    assert second[0].out.tolist() == first[0].out.tolist()


def test_make_engine_warns_on_dropped_kwargs():
    """make_engine must not silently pop continuous-only kwargs for
    lockstep archs (musicgen: audio codebooks)."""
    cfg = smoke_config("musicgen-medium", n_layers=1)
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, cfg.n_codebooks, 8), 0,
                               cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    folded = F.fold_params(cfg, params, obs)
    with pytest.warns(UserWarning, match="prefill_bucket"):
        eng = make_engine(cfg, folded, batch_slots=2, max_len=32,
                          prefill_bucket=8)
    assert isinstance(eng, LockstepEngine)
    with pytest.warns(UserWarning, match="cache_layout"):
        make_engine(cfg, folded, batch_slots=2, max_len=32,
                    cache_layout="paged", page_size=8)


def test_make_engine_passes_kwargs_to_continuous():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = make_engine(cfg, folded, batch_slots=2, max_len=64,
                      prefill_bucket=4, cache_layout="paged", page_size=8)
    assert isinstance(eng, Engine)
    assert eng.layout == "paged" and eng.page_size == 8
    assert eng.prefill_bucket == 4


@pytest.mark.slow
def test_continuous_matches_lockstep_hybrid_arch():
    """Hybrid (attention+mamba) archs take the batch-1 decode-loop prefill
    path; outputs must still match the lockstep engine per request."""
    cfg = smoke_config("jamba-1.5-large-398b")
    folded = _folded(cfg)
    lens = [3, 7]
    max_news = [4, 4]

    lock = LockstepEngine(cfg, folded, batch_slots=1, max_len=32)
    truth = []
    for r in _mixed_requests(cfg, lens, max_news):
        lock.reset()
        truth.append(lock.generate([r])[0].out.tolist())

    eng = Engine(cfg, folded, batch_slots=2, max_len=32)
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    assert eng.stats["oneshot_prefills"] == 0
    assert eng.stats["loop_prefill_steps"] == sum(lens)
