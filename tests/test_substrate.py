"""Substrate tests: checkpointing (atomicity, resume, elastic reshard, crc),
data pipeline determinism, optimizer (incl. quantized moments), grad
compression, sharding rules."""
import dataclasses
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.train import checkpoint as ck
from repro.train import steps as St

KEY = jax.random.PRNGKey(0)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int8)}}
    ck.save(tmp_path, 7, tree, meta={"data_step": 7})
    assert ck.latest_step(tmp_path) == 7
    shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got, meta = ck.restore(tmp_path, 7, shape)
    assert meta["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_crash_leaves_no_partial(tmp_path):
    tree = {"a": jnp.ones((4,))}
    ck.save(tmp_path, 1, tree)
    # simulate a crashed writer: a .tmp dir that never got renamed
    (tmp_path / "step_000002.tmp-dead").mkdir()
    assert ck.latest_step(tmp_path) == 1           # tmp ignored
    ck.gc_old(tmp_path, keep=3)
    assert not list(tmp_path.glob("*.tmp-*"))      # litter collected


def test_checkpoint_crc_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(100.0)}
    d = ck.save(tmp_path, 3, tree)
    # flip bytes in the shard
    f = d / "shard_00000.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    with pytest.raises(Exception):
        ck.restore(tmp_path, 3, shape)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore onto a 2x2 mesh with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ck.save(tmp_path, 5, tree)
    n = len(jax.devices())
    if n < 2:
        pytest.skip("single device")
    mesh = jax.make_mesh((n,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    shape = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    got, _ = ck.restore(tmp_path, 5, shape, sh)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert got["w"].sharding.spec == P("data", None)


def test_data_pipeline_deterministic_and_restartable():
    src = SyntheticLM(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    b1 = src.batch_at(10)
    b2 = src.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(11)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host slicing partitions the global batch deterministically
    h0 = src.batch_at(10, host_id=0, n_hosts=2)
    assert h0["tokens"].shape[0] == 4
    # learnable structure: odd positions are a function of even ones
    t = b1["tokens"]
    np.testing.assert_array_equal(t[:, 1::2], (t[:, 0::2] * 7 + 3) % 512)


def test_adamw_quantized_moments_track_fp32():
    cfg_f = adamw.AdamWConfig(lr=1e-2, quantize_moments=False)
    cfg_q = adamw.AdamWConfig(lr=1e-2, quantize_moments=True)
    params = {"w": jnp.ones((16, 16)) * 0.5}
    sf = adamw.init_state(params, cfg_f)
    sq = adamw.init_state(params, cfg_q)
    pf, pq = params, params
    rng = np.random.default_rng(0)
    for i in range(5):
        g = {"w": jnp.asarray(rng.normal(0, 0.1, (16, 16)), jnp.float32)}
        pf, sf = adamw.apply_updates(pf, g, sf, cfg_f)
        sf.pop("grad_norm")
        pq, sq = adamw.apply_updates(pq, g, sq, cfg_q)
        sq.pop("grad_norm")
    diff = float(jnp.max(jnp.abs(pf["w"] - pq["w"])))
    assert diff < 5e-3          # int8 moments stay close to fp32 moments


def test_grad_compression_preserves_direction():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 64)),
                          jnp.float32)}
    gq = St._compress_grads(g, 8)
    cos = float(jnp.sum(g["w"] * gq["w"]) /
                (jnp.linalg.norm(g["w"]) * jnp.linalg.norm(gq["w"])))
    assert cos > 0.9999


def test_partition_rules_fit_and_cover():
    from jax.sharding import PartitionSpec as P
    from repro.models import transformer as T
    from repro.sharding import partition as Pt

    cfg = smoke_config("jamba-1.5-large-398b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ps = jax.eval_shape(lambda k: T.init_params(cfg, k), KEY)
    sh = Pt.make_param_shardings(mesh, ps, fsdp=True)
    # every leaf got a sharding; specs never violate divisibility
    for (path, leaf), (_, s) in zip(
            Pt._tree_paths_specs(ps), Pt._tree_paths_specs(sh)):
        fitted = Pt._fit_spec(s.spec, leaf.shape, mesh)
        assert tuple(fitted) == tuple(s.spec), path


def test_fit_spec_drops_nondivisible():
    from jax.sharding import PartitionSpec as P
    from repro.sharding import partition as Pt

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 16-way axis via direct call semantics: use shape not divisible
    out = Pt._fit_spec(P("data", "model"), (3, 5), mesh)  # 1x1 divides all
    assert tuple(out) == ("data", "model")


def test_trainer_resume_exact(tmp_path):
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainerConfig, train

    cfg = smoke_config("yi-6b", n_layers=2, d_model=64, vocab_size=128)
    shape = ShapeConfig("t", 32, 4, "train")
    mesh = make_host_mesh()
    opt = AdamWConfig(lr=1e-3)
    t1 = TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                       log_every=100)
    state_a, hist_a = train(cfg, shape, mesh, opt, t1, fsdp=False)
    # "crash" after step 6, resume to 9
    t2 = TrainerConfig(steps=9, ckpt_every=3, ckpt_dir=str(tmp_path),
                       log_every=100)
    state_b, hist_b = train(cfg, shape, mesh, opt, t2, fsdp=False)
    assert int(state_b.step) == 9
    assert len(hist_b) == 3     # only steps 6..8 re-run (exactly-once data)
