"""Static checks over the Pallas attention kernels' BlockSpecs.

The kernels' correctness story leans on three structural claims that
nothing machine-checked until now:

``IDXMAP-RANGE`` / ``IDXMAP-CLAMP``
    The KV BlockSpec index maps clamp dead blocks in-range: for EVERY grid
    point, over a battery of edge-case lengths / chunk origins / block
    tables, the returned block coordinates must address inside the backing
    array, and every *dead* step must re-address exactly the last live
    block's page (that identity is why the pipeliner skips the DMA — an
    out-of-range or merely-different address silently streams garbage or
    wastes bandwidth).  The maps are module-level factories
    (``decode_kv_index_map`` / ``paged_kv_index_map`` /
    ``prefill_kv_index_map``) precisely so this lint can evaluate them.

``VMEM-BUDGET``
    The per-grid-step VMEM working set implied by the BlockSpec geometry
    (double-buffered KV tiles + q/out tiles + LUT + scratch) must fit the
    shared ``kernels/hw_constants`` budget at the tile sizes
    ``kernels/autotune`` actually picks — the tuner's quick filter only
    models the KV tiles, so this is the check that scratch growth can't
    sneak past it.

``SCALAR-PREFETCH``
    ``PrefetchScalarGridSpec(num_scalar_prefetch=N)`` makes the FIRST N
    positional operands of the pallas_call the scalar args, in order; the
    index maps then receive them in that same order.  A swapped pair
    (lengths vs block_tables) type-checks and runs — reading garbage.
    Checked via AST against the per-kernel expected name order.

``SHARED-BODY``
    The int8 and int4-packed wrappers claim byte-identical datapaths: both
    must reach the shared ``_decode_body`` / ``_prefill_body`` through the
    AST call graph, and both paged wrappers must build their KV index map
    from the one shared factory rather than a local re-derivation.
"""
from __future__ import annotations

import ast
import dataclasses
import inspect
import itertools
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.analysis.jaxpr_audit import Violation
from repro.kernels import autotune
from repro.kernels import decode_attention as DA
from repro.kernels import prefill_attention as PA
from repro.kernels.hw_constants import VMEM_BUDGET, VMEM_FILL

LUT_BYTES = 512 * 4          # exp LUT tile (LUT_SIZE int32 in VMEM)


@dataclasses.dataclass
class Check:
    check: str
    kernel: str
    ok: bool
    detail: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _violation(rule: str, kernel: str, detail: str) -> Violation:
    return Violation(rule=rule, graph=f"pallas:{kernel}", scope="", detail=detail)


# --- index-map bounds ----------------------------------------------------

def check_decode_kv_map(map_factory: Callable = DA.decode_kv_index_map,
                        *, b: int = 3, hkv: int = 2, smax: int = 64,
                        bkv: int = 16,
                        kernel: str = "decode_qattention") -> List[Violation]:
    """Contiguous decode map: (bb, blk, h, 0) must stay inside
    (B, Smax//bkv, Hkv) for every grid point and every length in
    [0, smax], and dead steps must re-address the last live block."""
    out: List[Violation] = []
    nblk = smax // bkv
    kv_map = map_factory(bkv)
    for lens_val in (0, 1, bkv - 1, bkv, bkv + 1, smax - 1, smax):
        lens = np.full((b,), lens_val, np.int32)
        last_live = max((lens_val - 1) // bkv, 0)
        for bb, h, k in itertools.product(range(b), range(hkv), range(nblk)):
            bb_o, blk, h_o, r = (int(x) for x in kv_map(bb, h, k, lens))
            if not (bb_o == bb and 0 <= blk < nblk and h_o == h and r == 0):
                out.append(_violation(
                    "IDXMAP-RANGE", kernel,
                    f"len={lens_val} grid=({bb},{h},{k}) -> "
                    f"({bb_o},{blk},{h_o},{r}) outside (B,{nblk},Hkv)"))
            elif k * bkv >= lens_val and blk != last_live:
                out.append(_violation(
                    "IDXMAP-CLAMP", kernel,
                    f"dead step k={k} (len={lens_val}) addresses block "
                    f"{blk}, not the last live block {last_live}"))
    return out


def _example_btab(b: int, nb: int, n_pages: int,
                  live_blocks: int) -> np.ndarray:
    """A representative allocator state: each slot owns ``live_blocks``
    distinct non-trash pages (strided so slots interleave), zeros (the
    trash page) beyond its chain — exactly what the engine hands the
    kernels."""
    btab = np.zeros((b, nb), np.int32)
    nxt = 1
    for bb in range(b):
        for k in range(live_blocks):
            btab[bb, k] = 1 + (nxt % (n_pages - 1))
            nxt += 3
    return btab


def check_paged_decode_kv_map(map_factory: Callable = DA.paged_kv_index_map,
                              *, b: int = 3, hkv: int = 2, nb: int = 4,
                              psize: int = 16, n_pages: int = 13,
                              kernel: str = "paged_decode_qattention",
                              ) -> List[Violation]:
    """Paged decode map: the returned page must be a page of the slot's
    own table row (in particular < n_pages), and dead logical blocks must
    re-address the last live page."""
    out: List[Violation] = []
    kv_map = map_factory(psize)
    smax = nb * psize
    for lens_val in (0, 1, psize - 1, psize, psize + 1, smax - 1, smax):
        live_blocks = max(-(-lens_val // psize), 1)
        btab = _example_btab(b, nb, n_pages, live_blocks)
        lens = np.full((b,), lens_val, np.int32)
        last_live = max((lens_val - 1) // psize, 0)
        for bb, h, k in itertools.product(range(b), range(hkv), range(nb)):
            pg, r0, h_o, r1 = (int(x) for x in kv_map(bb, h, k, lens, btab))
            if not (0 <= pg < n_pages and r0 == 0 and h_o == h and r1 == 0):
                out.append(_violation(
                    "IDXMAP-RANGE", kernel,
                    f"len={lens_val} grid=({bb},{h},{k}) -> page {pg} "
                    f"outside pool of {n_pages}"))
            elif pg != int(btab[bb, min(k, last_live)]):
                out.append(_violation(
                    "IDXMAP-RANGE", kernel,
                    f"len={lens_val} grid=({bb},{h},{k}) -> page {pg} is "
                    f"not the slot's own page "
                    f"{int(btab[bb, min(k, last_live)])}"))
            elif k * psize >= lens_val and pg != int(btab[bb, last_live]):
                out.append(_violation(
                    "IDXMAP-CLAMP", kernel,
                    f"dead step k={k} (len={lens_val}) addresses page "
                    f"{pg}, not the last live page "
                    f"{int(btab[bb, last_live])}"))
    return out


def check_prefill_kv_map(map_factory: Callable = PA.prefill_kv_index_map,
                         *, b: int = 2, h: int = 4, group: int = 2,
                         nb: int = 4, psize: int = 16, bq: int = 8,
                         sq: int = 16, n_pages: int = 13,
                         kernel: str = "paged_prefill_qattention",
                         ) -> List[Violation]:
    """Paged prefill map under the kernel contract ``pos0 + sq <= nb *
    psize`` (page-aligned chunks): page in-pool, kv head = q head // group,
    and blocks past the q-block's causal frontier re-address the frontier
    page."""
    out: List[Violation] = []
    kv_map = map_factory(bq, psize, group)
    nq = sq // bq
    hkv = h // group
    for pos0_val in (0, psize, nb * psize - sq):
        live_blocks = max(-(-(pos0_val + sq) // psize), 1)
        btab = _example_btab(b, nb, n_pages, live_blocks)
        pos0 = np.full((b,), pos0_val, np.int32)
        for bb, hh, qi, ki in itertools.product(
                range(b), range(h), range(nq), range(nb)):
            frontier = (pos0_val + (qi + 1) * bq - 1) // psize
            pg, r0, h_o, r1 = (int(x)
                               for x in kv_map(bb, hh, qi, ki, pos0, btab))
            if not (0 <= pg < n_pages and r0 == 0 and r1 == 0
                    and 0 <= h_o < hkv):
                out.append(_violation(
                    "IDXMAP-RANGE", kernel,
                    f"pos0={pos0_val} grid=({bb},{hh},{qi},{ki}) -> "
                    f"(page {pg}, head {h_o}) outside "
                    f"(pool {n_pages}, Hkv {hkv})"))
            elif h_o != hh // group:
                out.append(_violation(
                    "IDXMAP-RANGE", kernel,
                    f"q head {hh} mapped to kv head {h_o}, "
                    f"expected {hh // group}"))
            elif pg != int(btab[bb, min(ki, frontier)]):
                out.append(_violation(
                    "IDXMAP-CLAMP", kernel,
                    f"pos0={pos0_val} grid=({bb},{hh},{qi},{ki}) -> page "
                    f"{pg}, expected frontier-clamped "
                    f"{int(btab[bb, min(ki, frontier)])}"))
    return out


# --- VMEM tile budgets ---------------------------------------------------

def _decode_tile_bytes(g: int, d: int, kv_tile_rows: int,
                       kv_bits: int) -> int:
    """VMEM working set of one decode grid step from the BlockSpec
    geometry: double-buffered K+V tiles, q + out tiles, LUT, and the three
    scratch buffers ((g,128) i32 + (g,128) f32 + (g,d) f32)."""
    kv_row = d // 2 if kv_bits == 4 else d
    kv = 2 * 2 * kv_tile_rows * kv_row            # K+V, double-buffered
    q_out = 2 * g * d
    scratch = g * 128 * 4 + g * 128 * 4 + g * d * 4
    return kv + q_out + LUT_BYTES + scratch


def _prefill_tile_bytes(bq: int, d: int, psize: int, kv_bits: int) -> int:
    kv_row = d // 2 if kv_bits == 4 else d
    kv = 2 * 2 * psize * kv_row
    q_out = 2 * bq * d
    scratch = bq * 128 * 4 + bq * 128 * 4 + bq * d * 4
    return kv + q_out + LUT_BYTES + scratch


# (name, geometry) battery: the audit presets' smoke shape plus a
# deployment-scale shape, both bit widths
_DECODE_SHAPES = (
    ("smoke", dict(smax=64, batch_slots=4, hkv=4, hd=32, kv_bits=8)),
    ("large", dict(smax=4096, batch_slots=64, hkv=8, hd=128, kv_bits=8)),
    ("large_kv4", dict(smax=4096, batch_slots=64, hkv=8, hd=128, kv_bits=4)),
)
_PREFILL_SHAPES = (
    ("smoke", dict(sq=32, batch_slots=4, page_size=16, hkv=4, hd=32,
                   kv_bits=8, n_blocks=4, n_heads=4)),
    ("large", dict(sq=512, batch_slots=16, page_size=64, hkv=8, hd=128,
                   kv_bits=8, n_blocks=64, n_heads=32)),
    ("large_kv4", dict(sq=512, batch_slots=16, page_size=64, hkv=8, hd=128,
                       kv_bits=4, n_blocks=64, n_heads=32)),
)


def check_vmem_budgets() -> List[Violation]:
    """At the tile sizes autotune actually picks for a battery of shapes,
    the full BlockSpec working set (not just the tuner's KV-tile filter)
    must fit the shared VMEM budget."""
    out: List[Violation] = []
    for tag, kw in _DECODE_SHAPES:
        bkv = autotune.decode_bkv(kw["smax"], batch_slots=kw["batch_slots"],
                                  hkv=kw["hkv"], hd=kw["hd"],
                                  kv_bits=kw["kv_bits"])
        g = 8    # worst-case GQA group sharing one kv head's tile
        used = _decode_tile_bytes(g, kw["hd"], bkv, kw["kv_bits"])
        if used > VMEM_BUDGET * VMEM_FILL:
            out.append(_violation(
                "VMEM-BUDGET", f"decode[{tag}]",
                f"bkv={bkv} working set {used}B exceeds "
                f"{int(VMEM_BUDGET * VMEM_FILL)}B "
                f"(VMEM_BUDGET*VMEM_FILL) at {kw}"))
    for tag, kw in _PREFILL_SHAPES:
        bq = autotune.prefill_bq(kw["sq"], batch_slots=kw["batch_slots"],
                                 page_size=kw["page_size"], hkv=kw["hkv"],
                                 hd=kw["hd"], kv_bits=kw["kv_bits"],
                                 n_blocks=kw["n_blocks"],
                                 n_heads=kw["n_heads"])
        used = _prefill_tile_bytes(bq, kw["hd"], kw["page_size"],
                                   kw["kv_bits"])
        if used > VMEM_BUDGET * VMEM_FILL:
            out.append(_violation(
                "VMEM-BUDGET", f"prefill[{tag}]",
                f"bq={bq} working set {used}B exceeds "
                f"{int(VMEM_BUDGET * VMEM_FILL)}B "
                f"(VMEM_BUDGET*VMEM_FILL) at {kw}"))
    return out


# --- AST checks: scalar-prefetch ordering + shared-body diff gate --------

# kernel -> (module, expected scalar operand names, in pallas_call order)
SCALAR_PREFETCH_ORDER = {
    "decode_qattention": (DA, ("lengths",)),
    "paged_decode_qattention": (DA, ("lengths", "block_tables")),
    "paged_decode_qattention_q4": (DA, ("lengths", "block_tables")),
    "paged_prefill_qattention": (PA, ("pos0", "block_tables")),
    "paged_prefill_qattention_q4": (PA, ("pos0", "block_tables")),
}

# wrapper kernel fn -> (module, shared body it must reach transitively)
SHARED_BODY = {
    "_decode_kernel": (DA, "_decode_body"),
    "_paged_decode_kernel": (DA, "_decode_body"),
    "_paged_decode_q4_kernel": (DA, "_decode_body"),
    "_paged_prefill_kernel": (PA, "_prefill_body"),
    "_paged_prefill_q4_kernel": (PA, "_prefill_body"),
}

# public wrapper -> (module, the index-map factory it must use)
INDEX_MAP_FACTORY = {
    "decode_qattention": (DA, "decode_kv_index_map"),
    "paged_decode_qattention": (DA, "paged_kv_index_map"),
    "paged_decode_qattention_q4": (DA, "paged_kv_index_map"),
    "paged_prefill_qattention": (PA, "prefill_kv_index_map"),
    "paged_prefill_qattention_q4": (PA, "prefill_kv_index_map"),
}

_mod_ast_cache: Dict[str, ast.Module] = {}
_mod_src_cache: Dict[str, str] = {}


def _module_ast(mod) -> ast.Module:
    if mod.__name__ not in _mod_ast_cache:
        src = inspect.getsource(mod)
        _mod_src_cache[mod.__name__] = src
        _mod_ast_cache[mod.__name__] = ast.parse(src)
    return _mod_ast_cache[mod.__name__]


def _find_funcdef(mod, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(_module_ast(mod)):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def check_scalar_prefetch() -> List[Violation]:
    """The first ``num_scalar_prefetch`` positional operands of each
    kernel's pallas_call must name the expected scalars in order."""
    out: List[Violation] = []
    for kernel, (mod, expected) in SCALAR_PREFETCH_ORDER.items():
        fd = _find_funcdef(mod, kernel)
        if fd is None:
            out.append(_violation("SCALAR-PREFETCH", kernel,
                                  "kernel function not found"))
            continue
        src = _mod_src_cache[mod.__name__]
        nsp = None
        operands: Optional[Sequence[ast.expr]] = None
        for node in ast.walk(fd):
            if isinstance(node, ast.Call) \
                    and _call_name(node) == "PrefetchScalarGridSpec":
                for kwarg in node.keywords:
                    if kwarg.arg == "num_scalar_prefetch" \
                            and isinstance(kwarg.value, ast.Constant):
                        nsp = kwarg.value.value
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Call) \
                    and _call_name(node.func) == "pallas_call":
                operands = node.args
        if nsp is None or operands is None:
            out.append(_violation(
                "SCALAR-PREFETCH", kernel,
                "could not locate PrefetchScalarGridSpec"
                f"(num_scalar_prefetch=...) + pallas_call(...)(operands) "
                f"in {kernel}"))
            continue
        if nsp != len(expected):
            out.append(_violation(
                "SCALAR-PREFETCH", kernel,
                f"num_scalar_prefetch={nsp} but {len(expected)} scalar "
                f"operands expected ({', '.join(expected)})"))
            continue
        for i, want in enumerate(expected):
            seg = ast.get_source_segment(src, operands[i]) or ""
            if want not in seg:
                out.append(_violation(
                    "SCALAR-PREFETCH", kernel,
                    f"scalar operand {i} is `{seg.strip()}`, expected it "
                    f"to carry `{want}` (order: {', '.join(expected)})"))
    return out


def _reaches(mod, fn_name: str, target: str,
             seen: Optional[set] = None) -> bool:
    if fn_name == target:
        return True
    seen = seen or set()
    if fn_name in seen:
        return False
    seen.add(fn_name)
    fd = _find_funcdef(mod, fn_name)
    if fd is None:
        return False
    callees = set()
    for node in ast.walk(fd):
        if isinstance(node, ast.Call):
            callees.add(_call_name(node))
        elif isinstance(node, ast.Name):
            # functools.partial(_decode_kernel, ...) and bare references
            callees.add(node.id)
    return any(_reaches(mod, c, target, seen)
               for c in callees if c != fn_name)


def check_shared_body() -> List[Violation]:
    """Every kernel wrapper must reach the shared audited body; every
    public wrapper must build its KV map from the shared factory."""
    out: List[Violation] = []
    for fn_name, (mod, body) in SHARED_BODY.items():
        if not _reaches(mod, fn_name, body):
            out.append(_violation(
                "SHARED-BODY", fn_name,
                f"does not dispatch into the shared `{body}` — the "
                "int8/int4 byte-identity claim no longer holds"))
    for fn_name, (mod, factory) in INDEX_MAP_FACTORY.items():
        fd = _find_funcdef(mod, fn_name)
        used = fd is not None and any(
            isinstance(n, ast.Call) and _call_name(n) == factory
            for n in ast.walk(fd))
        local = fd is not None and any(
            isinstance(n, ast.FunctionDef) and n.name == "kv_map"
            for n in ast.walk(fd))
        if not used or local:
            out.append(_violation(
                "SHARED-BODY", fn_name,
                f"KV index map must come from the shared `{factory}` "
                "factory (no local kv_map re-derivations)"))
    return out


def run_all() -> Dict:
    """Every pallas lint; returns {"checks": [...], "violations": [...]}."""
    groups = {
        "idxmap_decode": check_decode_kv_map(),
        "idxmap_paged_decode": check_paged_decode_kv_map(),
        "idxmap_prefill": check_prefill_kv_map(),
        "vmem_budget": check_vmem_budgets(),
        "scalar_prefetch": check_scalar_prefetch(),
        "shared_body": check_shared_body(),
    }
    checks = [Check(check=name, kernel="*", ok=not viols,
                    detail=f"{len(viols)} violation(s)").to_dict()
              for name, viols in groups.items()]
    return {"checks": checks,
            "violations": [v.to_dict() for vs in groups.values()
                           for v in vs]}
