"""Config-driven model stack: embeddings/frontend -> scanned super-blocks ->
final norm -> LM head(s).  Covers all ten assigned architectures + BERT.

Depth is handled by ``lax.scan`` over the repeating super-block (pattern), so
HLO size is O(1) in n_layers — a 126-layer 405B model lowers as fast as a
2-layer smoke model.  Params and amax-EMA state are stacked (n_reps, ...) on
the leading axis; per-rep quantization observations come back as scan ys.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as Mb
from repro.models import moe as Moe
from repro.models import xlstm as Xl

# ---------------------------------------------------------------------------
# slot descriptors
# ---------------------------------------------------------------------------

ATTN_SITES = ("attn_in", "q_pre", "k_pre", "q", "k", "v", "attn_out_in",
              "resid_a")
MLP_SITES_SWIGLU = ("mlp_in", "g_pre", "g_out", "u_out", "h_in", "resid_m")
MLP_SITES_GELU = ("mlp_in", "h_pre", "g_out", "h_in", "resid_m")


def slot_kinds(cfg: ModelConfig):
    """[(mixer, ffn)] per slot in the super-block pattern."""
    out = []
    for i, blk in enumerate(cfg.pattern):
        mixer = {"a": "attn", "m": "mamba", "s": "slstm", "x": "mlstm"}[blk]
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.n_experts and i % cfg.moe_period == cfg.moe_offset:
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        out.append((mixer, ffn))
    return out


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def _norm_params(cfg, dim=None):
    d = dim or cfg.d_model
    p = {"gamma": jnp.ones((d,), cfg.dtype)}
    if cfg.norm_type == "layernorm":
        p["beta"] = jnp.zeros((d,), cfg.dtype)
    return p


def _dense(key, din, dout, cfg, scale=0.02):
    return (jax.random.normal(key, (din, dout)) * scale).astype(cfg.dtype)


def init_slot_params(cfg: ModelConfig, mixer: str, ffn: str, key) -> Dict:
    ks = iter(jax.random.split(key, 24))
    d, hd = cfg.d_model, cfg.hd
    p: Dict = {"norm1": _norm_params(cfg)}
    if mixer == "attn":
        p["attn"] = {
            "wq": _dense(next(ks), d, cfg.n_heads * hd, cfg),
            "wk": _dense(next(ks), d, cfg.n_kv_heads * hd, cfg),
            "wv": _dense(next(ks), d, cfg.n_kv_heads * hd, cfg),
            "wo": _dense(next(ks), cfg.n_heads * hd, d, cfg),
        }
        if cfg.learned_pos:  # BERT uses biases everywhere
            p["attn"].update(
                bq=jnp.zeros((cfg.n_heads * hd,), cfg.dtype),
                bk=jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype),
                bv=jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype),
                bo=jnp.zeros((d,), cfg.dtype))
        if cfg.qk_norm:
            p["attn"]["qn"] = jnp.ones((hd,), cfg.dtype)
            p["attn"]["kn"] = jnp.ones((hd,), cfg.dtype)
    elif mixer == "mamba":
        d_in, dt_rank = Mb.mamba_dims(cfg)
        n = cfg.mamba_d_state
        p["mixer"] = {
            "w_in": _dense(next(ks), d, 2 * d_in, cfg),
            "conv_w": (jax.random.normal(next(ks), (cfg.mamba_d_conv, d_in))
                       * 0.1).astype(cfg.dtype),
            "conv_b": jnp.zeros((d_in,), cfg.dtype),
            "w_x": _dense(next(ks), d_in, dt_rank + 2 * n, cfg),
            "w_dt": _dense(next(ks), dt_rank, d_in, cfg, scale=dt_rank**-0.5),
            "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))),
            "D": jnp.ones((d_in,), jnp.float32),
            "w_out": _dense(next(ks), d_in, d, cfg),
        }
    elif mixer == "mlstm":
        p["mixer"] = {
            "wq": _dense(next(ks), d, d, cfg),
            "wk": _dense(next(ks), d, d, cfg),
            "wv": _dense(next(ks), d, d, cfg),
            "wo": _dense(next(ks), d, d, cfg),
            "w_ig": _dense(next(ks), d, cfg.n_heads, cfg),
            "b_ig": jnp.zeros((cfg.n_heads,), jnp.float32),
            "w_fg": _dense(next(ks), d, cfg.n_heads, cfg),
            "b_fg": jnp.full((cfg.n_heads,), 3.0, jnp.float32),
            "w_og": _dense(next(ks), d, d, cfg),
            "b_og": jnp.zeros((d,), cfg.dtype),
            "ln_y": jnp.ones((d,), cfg.dtype),
        }
    elif mixer == "slstm":
        dh = d // cfg.n_heads
        p["mixer"] = {
            "w_z": _dense(next(ks), d, d, cfg), "b_z": jnp.zeros((d,), cfg.dtype),
            "w_i": _dense(next(ks), d, d, cfg), "b_i": jnp.zeros((d,), cfg.dtype),
            "w_f": _dense(next(ks), d, d, cfg), "b_f": jnp.full((d,), 3.0, cfg.dtype),
            "w_o": _dense(next(ks), d, d, cfg), "b_o": jnp.zeros((d,), cfg.dtype),
            "r": (jax.random.normal(next(ks), (cfg.n_heads, dh, 4 * dh))
                  * dh**-0.5).astype(cfg.dtype),
            "w_out": _dense(next(ks), d, d, cfg),
        }
    if ffn != "none":
        p["norm2"] = _norm_params(cfg)
    if ffn == "dense":
        if cfg.act == "swiglu":  # noqa: SIM108 - parallel dict literals
            p["mlp"] = {
                "wg": _dense(next(ks), d, cfg.d_ff, cfg),
                "wu": _dense(next(ks), d, cfg.d_ff, cfg),
                "wd": _dense(next(ks), cfg.d_ff, d, cfg),
            }
        else:
            p["mlp"] = {
                "w1": _dense(next(ks), d, cfg.d_ff, cfg),
                "b1": jnp.zeros((cfg.d_ff,), cfg.dtype),
                "w2": _dense(next(ks), cfg.d_ff, d, cfg),
                "b2": jnp.zeros((d,), cfg.dtype),
            }
    elif ffn == "moe":
        fe = cfg.moe_d_ff or cfg.d_ff
        p["moe"] = {
            "router": _dense(next(ks), d, cfg.n_experts, cfg),
            "experts": {
                "wg": _dense(next(ks), cfg.n_experts * d, fe, cfg).reshape(
                    cfg.n_experts, d, fe),
                "wu": _dense(next(ks), cfg.n_experts * d, fe, cfg).reshape(
                    cfg.n_experts, d, fe),
                "wd": _dense(next(ks), cfg.n_experts * fe, d, cfg).reshape(
                    cfg.n_experts, fe, d),
            },
        }
        if cfg.n_shared_experts:
            p["moe"]["shared"] = {
                "wg": _dense(next(ks), cfg.n_shared_experts * d, fe, cfg
                             ).reshape(cfg.n_shared_experts, d, fe),
                "wu": _dense(next(ks), cfg.n_shared_experts * d, fe, cfg
                             ).reshape(cfg.n_shared_experts, d, fe),
                "wd": _dense(next(ks), cfg.n_shared_experts * fe, d, cfg
                             ).reshape(cfg.n_shared_experts, fe, d),
            }
    return p


def slot_sites(cfg: ModelConfig, mixer: str, ffn: str):
    sites = []
    if mixer == "attn":
        sites += list(ATTN_SITES)
    elif mixer == "mamba":
        sites += list(Mb.MAMBA_SITES) + ["resid_a"]
    elif mixer == "mlstm":
        sites += list(Xl.MLSTM_SITES) + ["resid_a"]
    elif mixer == "slstm":
        sites += list(Xl.SLSTM_SITES) + ["resid_a"]
    if ffn == "dense":
        sites += list(MLP_SITES_SWIGLU if cfg.act == "swiglu" else MLP_SITES_GELU)
    elif ffn == "moe":
        sites += list(Moe.MOE_SITES) + ["resid_m"]
        if cfg.n_shared_experts:
            sites += list(Moe.MOE_SHARED_SITES)
    return sites


def init_params(cfg: ModelConfig, key) -> Dict:
    kinds = slot_kinds(cfg)
    keys = jax.random.split(key, len(kinds) * cfg.n_reps + 4)
    blocks = {}
    for i, (mixer, ffn) in enumerate(kinds):
        reps = [init_slot_params(cfg, mixer, ffn, keys[i * cfg.n_reps + r])
                for r in range(cfg.n_reps)]
        blocks[f"slot{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    p = {
        "embed": {"tokens": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model))
                             * 0.02).astype(cfg.dtype)},
        "blocks": blocks,
        "final_norm": _norm_params(cfg),
    }
    if cfg.frontend == "audio_codebooks":
        p["embed"]["codebooks"] = (jax.random.normal(
            keys[-2], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(cfg.dtype)
    if cfg.learned_pos:
        p["embed"]["pos"] = (jax.random.normal(
            keys[-3], (cfg.max_position, cfg.d_model)) * 0.02).astype(cfg.dtype)
    if not cfg.tied_embeddings:
        heads = cfg.n_lm_heads
        shape = (heads, cfg.d_model, cfg.vocab_size) if heads > 1 else (
            cfg.d_model, cfg.vocab_size)
        p["lm_head"] = (jax.random.normal(keys[-4], shape) * 0.02).astype(cfg.dtype)
    return p


def init_amax(cfg: ModelConfig) -> Dict:
    kinds = slot_kinds(cfg)
    blocks = {}
    for i, (mixer, ffn) in enumerate(kinds):
        blocks[f"slot{i}"] = {s: jnp.zeros((cfg.n_reps,), jnp.float32)
                              for s in slot_sites(cfg, mixer, ffn)}
    return {"blocks": blocks,
            "embed_out": jnp.zeros((), jnp.float32),
            "head_in": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

CHUNK_TOKENS = 4096  # token-chunk size for the (token-parallel) dense MLP


def _chunked_mlp(x, p, amax, policy, act):
    """Token-chunked QAT MLP: rows are independent, so scanning token chunks
    caps the live (tokens, d_ff) fake-quant chain at CHUNK_TOKENS rows —
    this is what keeps the train_4k backward inside HBM."""
    b, s, d = x.shape
    c = 512  # seq-chunk per batch element: keeps the dp sharding of B intact
    if b * s <= 2 * CHUNK_TOKENS or s % c != 0 or s <= c:
        return L.mlp_qat(x, p, amax, policy, act)
    xt = x.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)   # (nc, B, c, d)

    def body(_, xc):
        y, o = L.mlp_qat(xc, p, amax, policy, act)
        return None, (y, o)

    body = jax.checkpoint(body)
    _, (ys, obs_c) = jax.lax.scan(body, None, xt)
    obs = jax.tree.map(lambda t: jnp.max(t, axis=0), obs_c)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, d), obs


def _apply_slot(cfg, mixer, ffn, x, p, amax, pos, mask):
    policy = cfg.quant
    obs: Dict = {}
    h = L.qnorm(x, p["norm1"], policy, cfg.norm_type)
    if mixer == "attn":
        out, o = L.attention_qat(h, p["attn"], amax, policy, cfg, pos, mask)
    elif mixer == "mamba":
        out, o, _ = Mb.mamba_qat(h, p["mixer"], amax, policy, cfg)
    elif mixer == "mlstm":
        out, o, _ = Xl.mlstm_qat(h, p["mixer"], amax, policy, cfg)
    else:
        out, o, _ = Xl.slstm_qat(h, p["mixer"], amax, policy, cfg)
    obs.update(o)
    x, obs["resid_a"] = L.residual_add(x, out, amax["resid_a"], policy)
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = L.qnorm(x, p["norm2"], policy, cfg.norm_type)
        if ffn == "dense":
            out, o = _chunked_mlp(h, p["mlp"], amax, policy, cfg.act)
        else:
            out, o, aux = Moe.moe_qat(h, p["moe"], amax, policy, cfg)
        obs.update(o)
        x, obs["resid_m"] = L.residual_add(x, out, amax["resid_m"], policy)
    return x, obs, aux


def forward(
    cfg: ModelConfig,
    params: Dict,
    amax: Dict,
    tokens: jax.Array,                   # (B, S) int32, or (B, K, S) audio
    *,
    mask: Optional[jax.Array] = None,    # (B, 1, S, S) bool; None -> causal
    pos: Optional[jax.Array] = None,
    extra_embeds: Optional[jax.Array] = None,  # vlm stub: (B, S_img, d)
    pos3: Optional[jax.Array] = None,          # vlm: (B, S, 3) M-RoPE ids
) -> Tuple[jax.Array, Dict, jax.Array]:
    """QAT forward.  Returns (logits, obs-tree matching init_amax, aux_loss)."""
    policy = cfg.quant
    # --- embed / frontend ---
    if cfg.frontend == "audio_codebooks":
        b, k, s = tokens.shape
        x = jnp.zeros((b, s, cfg.d_model), cfg.dtype)
        for ci in range(cfg.n_codebooks):
            x = x + jnp.take(params["embed"]["codebooks"][ci], tokens[:, ci], 0)
    else:
        x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    if cfg.learned_pos:
        x = x + params["embed"]["pos"][None, :s]
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections is not None:
        if pos3 is None:
            pos3 = jnp.broadcast_to(pos[..., None], (*pos.shape, 3))
        pos = pos3
    x, obs_embed = L.fake_quant_act(x, amax["embed_out"], policy.a_bits,
                                    policy.quantize_wa)
    from repro.sharding import partition as Pt
    dp = Pt.dp_axes_or_none()
    if dp:
        x = Pt.constrain(x, dp, None, None)
    if mask is None and not cfg.causal:
        mask = jnp.ones((b, 1, s, s), bool)

    # --- scanned super-blocks ---
    kinds = slot_kinds(cfg)

    def body(carry, xs):
        xc, aux_sum = carry
        p_rep, a_rep = xs
        obs_rep = {}
        for i, (mixer, ffn) in enumerate(kinds):
            xc, o, aux = _apply_slot(cfg, mixer, ffn, xc,
                                     p_rep[f"slot{i}"], a_rep[f"slot{i}"],
                                     pos, mask)
            obs_rep[f"slot{i}"] = o
            aux_sum = aux_sum + aux
        return (xc, aux_sum), obs_rep

    if cfg.remat:
        body = jax.checkpoint(body)
    carry0 = (x, jnp.zeros((), jnp.float32))
    g = cfg.remat_groups
    if g > 1 and cfg.n_reps % g == 0:
        # two-level (sqrt-L) checkpointing: residuals live only at group
        # boundaries; backward recomputes one group at a time.
        per = cfg.n_reps // g

        def regroup(t):
            return t.reshape(g, per, *t.shape[1:])

        xs_g = jax.tree.map(regroup, (params["blocks"], amax["blocks"]))

        def group_body(carry, xs):
            c, obs_g = jax.lax.scan(body, carry, xs)
            return c, obs_g

        group_body = jax.checkpoint(group_body)
        (x, aux_total), obs_nested = jax.lax.scan(group_body, carry0, xs_g)
        obs_blocks = jax.tree.map(
            lambda t: t.reshape(cfg.n_reps, *t.shape[2:]), obs_nested)
    else:
        (x, aux_total), obs_blocks = jax.lax.scan(
            body, carry0, (params["blocks"], amax["blocks"]))

    # --- head ---
    x = L.qnorm(x, params["final_norm"], policy, cfg.norm_type)
    x, obs_head = L.fake_quant_act(x, amax["head_in"], policy.a_bits,
                                   policy.quantize_wa)
    if cfg.tied_embeddings:
        w = params["embed"]["tokens"].T
        logits = x @ w
    else:
        w = params["lm_head"]
        logits = (jnp.einsum("bsd,kdv->bksv", x, w)
                  if cfg.n_lm_heads > 1 else x @ w)
    obs = {"blocks": obs_blocks, "embed_out": obs_embed, "head_in": obs_head}
    return logits.astype(jnp.float32), obs, aux_total
