"""Integration tests: the dry-run launch path on a tiny host mesh, the
serving engine end-to-end, SWA ring-buffer decode, and the HLO cost parser.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.models import fold as F
from repro.models import serve_int as S
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _mesh22():
    n = len(jax.devices())
    if n < 4:
        pytest.skip("needs >=4 host devices (run under the dryrun env)")
    return jax.make_mesh((2, 2), ("data", "model"))


def test_lower_train_smoke_mesh():
    """The real dryrun lower_train path on a 2x2 mesh with a smoke config:
    proves the sharding rules + step builder compile end-to-end in-test."""
    from repro.launch.dryrun import lower_train
    from repro.sharding import partition as Pt

    mesh = _mesh22()
    cfg = smoke_config("yi-6b", param_dtype="bfloat16")
    shape = ShapeConfig("t", 64, 4, "train")
    Pt.set_mesh_ctx(mesh)
    try:
        lowered = lower_train(cfg, shape, mesh, fsdp=True, accum_steps=2)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
    finally:
        Pt.set_mesh_ctx(None)


def test_lower_serve_decode_smoke_mesh():
    from repro.launch.dryrun import lower_serve
    from repro.sharding import partition as Pt

    mesh = _mesh22()
    cfg = smoke_config("yi-6b")
    shape = ShapeConfig("d", 64, 4, "decode")
    Pt.set_mesh_ctx(mesh)
    try:
        compiled = lower_serve(cfg, shape, mesh).compile()
        assert "while" in compiled.as_text()
    finally:
        Pt.set_mesh_ctx(None)


def test_engine_generates_and_is_deterministic():
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    folded = F.fold_params(cfg, params, obs)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(2)]

    def run():
        eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64))
        reqs = [Request(prompt=p.copy(), max_new_tokens=5) for p in prompts]
        return [r.out.tolist() for r in eng.generate(reqs)]

    a, b = run(), run()
    assert a == b                       # greedy decode is deterministic
    assert all(len(o) == 5 for o in a)


@pytest.mark.slow
def test_swa_ring_buffer_decode_matches_prefill_tail():
    """Mixtral-style SWA: decode past the window via the ring buffer must
    agree with a windowed prefill on the same tokens."""
    cfg = smoke_config("mixtral-8x22b", sliding_window=8, n_layers=1)
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, toks)
    folded = F.fold_params(cfg, params, obs)
    cache = S.init_cache(cfg, 1, 64)    # ring size = window = 8
    assert cache["slot0"]["k"].shape[2] == 8
    outs = []
    for t in range(16):                 # decode 2x past the window
        lg, cache = S.serve_forward(cfg, folded, toks[:, t:t + 1], cache=cache,
                                    pos_offset=jnp.int32(t), mode="decode")
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    pre, _ = S.serve_forward(cfg, folded, toks, mode="prefill")
    # compare the final position (full window context in both paths)
    pd = jax.nn.log_softmax(dec[:, -1], -1)
    pp = jax.nn.log_softmax(pre[:, -1], -1)
    p = jax.nn.softmax(pre[:, -1], -1)
    kl = float(jnp.sum(p * (pp - pd), -1).mean())
    assert np.isfinite(kl) and kl < 0.02


def test_hlo_cost_parser_scales_loops():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import hlo_cost

    hlo = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), to_apply=%add.red
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

%add.red (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (w: f32[8,8]) -> (s32[], f32[8,8]) {
  %w = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tu = (s32[], f32[8,8]) tuple(%z, %w)
  ROOT %wh = (s32[], f32[8,8]) while(%tu), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
}
"""
    out = hlo_cost.analyze(hlo)
    # dot flops: 2*8*8*8 = 1024 per trip x 10 trips
    assert out["dot_flops"] == 1024 * 10
    assert out["collectives"]["all-reduce"]["count"] == 10
    assert out["collectives"]["all-reduce"]["bytes"] == 8 * 8 * 4 * 10


def test_audio_engine_shapes():
    """musicgen serve path end-to-end at smoke scale (4 codebooks)."""
    cfg = smoke_config("musicgen-medium", n_layers=1)
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    toks = jax.random.randint(KEY, (2, 4, 8), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, toks)
    folded = F.fold_params(cfg, params, obs)
    cache = S.init_cache(cfg, 2, 16)
    lg, cache = S.serve_forward(cfg, folded, toks[:, :, :1], cache=cache,
                                pos_offset=jnp.int32(0), mode="decode")
    assert lg.shape == (2, cfg.n_codebooks, 1, cfg.vocab_size)


def test_vlm_loss_masks_image_positions():
    from repro.optim.adamw import AdamWConfig
    from repro.train import steps as St

    cfg = smoke_config("qwen2-vl-2b")
    opt = AdamWConfig(lr=1e-3)
    state = St.init_train_state(cfg, KEY, opt)
    b, n_img, s_txt = 2, 4, 12
    batch = {
        "tokens": jax.random.randint(KEY, (b, s_txt), 0, cfg.vocab_size),
        "extra_embeds": jax.random.normal(KEY, (b, n_img, cfg.d_model)),
        "pos3": jnp.broadcast_to(
            jnp.arange(n_img + s_txt, dtype=jnp.int32)[None, :, None],
            (b, n_img + s_txt, 3)),
    }
    step = jax.jit(St.make_train_step(cfg, opt))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
