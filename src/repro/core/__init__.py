"""FQ-BERT core: the paper's fully-quantized datapath as reusable JAX modules."""
from repro.core.policy import (  # noqa: F401
    QuantPolicy,
    POLICY_FP32,
    POLICY_WA,
    POLICY_WA_SCALE,
    POLICY_WA_SCALE_SM,
    POLICY_FQ,
    POLICY_W8A8,
    TABLE2_ROWS,
)
from repro.core import quant, packing, fixedpoint, qsoftmax, qlayernorm, qlinear  # noqa: F401
