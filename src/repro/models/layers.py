"""QAT building blocks shared by every architecture in the zoo.

Conventions
-----------
* Pure functions; params are nested dicts of arrays; no framework.
* Every activation that the integer path quantizes has a **site**: a scalar
  EMA of max|activation| (paper Eq. 3) threaded in via ``amax[site]`` and an
  observation returned via ``obs[site]`` so the trainer can update the EMA.
* ``qdense`` fake-quantizes its input activation (8-bit) and weight (4-bit,
  STE) — paper Eq. 1/2 — so the QAT graph numerically mirrors the integer
  serving graph.
* The quantized-softmax and quantized-LayerNorm *simulators* here reproduce
  the integer pipeline's rounding through straight-through estimators, which
  is exactly how the paper fine-tunes ("fine-tune the model with quantization
  function").
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as q
from repro.core.policy import QuantPolicy
from repro.core.qsoftmax import LUT_DELTA
from repro.core.quant import _ste_round as ste_round

Obs = Dict[str, jax.Array]


def _amax_or_obs(amax: jax.Array, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    ob = q.per_tensor_max(jax.lax.stop_gradient(x)).astype(jnp.float32)
    return jnp.where(amax > 0, amax, ob), ob


def fake_quant_act(x, amax, bits, enabled: bool):
    a, ob = _amax_or_obs(amax, x)
    if not enabled:
        return x, ob
    return q.fake_quant(x, a.astype(x.dtype), bits), ob


def qdense(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    amax_in: jax.Array,
    policy: QuantPolicy,
) -> Tuple[jax.Array, jax.Array]:
    """Quantization-aware linear; returns (y, observed max|x|)."""
    x_q, ob = fake_quant_act(x, amax_in, policy.a_bits, policy.quantize_wa)
    if policy.quantize_wa:
        w_m = jax.lax.stop_gradient(
            q.per_channel_max(w, axis=-1) if policy.per_channel_w
            else q.per_tensor_max(w))
        w = q.fake_quant(w, w_m.astype(w.dtype), policy.w_bits)
    y = x_q @ w
    if b is not None:
        y = y + b
    return y, ob


# --- norms -------------------------------------------------------------------

def rmsnorm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    n = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (n * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, gamma, beta, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    c = x32 - mu
    n = c * jax.lax.rsqrt(jnp.mean(c * c, -1, keepdims=True) + eps)
    return (n * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def qnorm(x, p, policy: QuantPolicy, norm_type: str):
    """Norm with (optionally) fake-quantized 8-bit gamma/beta — the QAT mirror
    of the integer LN core."""
    gamma = p["gamma"]
    beta = p.get("beta")
    if policy.quantize_layernorm:
        gm = jax.lax.stop_gradient(q.per_tensor_max(gamma))
        gamma = q.fake_quant(gamma, gm.astype(gamma.dtype), 8)
        if beta is not None:
            bm = jax.lax.stop_gradient(q.per_tensor_max(beta))
            beta = q.fake_quant(beta, jnp.maximum(bm, 1e-8).astype(beta.dtype), 8)
    if norm_type == "layernorm":
        return layernorm(x, gamma, beta if beta is not None else jnp.zeros_like(gamma))
    return rmsnorm(x, gamma)


# --- rotary embeddings ---------------------------------------------------------

def rope_freqs(hd_rot: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def apply_rope(x, pos, theta, partial: float = 1.0):
    """x: (B, S, H, D); pos: (B, S) int32.  Split-half (llama) convention."""
    d = x.shape[-1]
    d_rot = int(d * partial)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_freqs(d_rot, theta)                       # (d_rot/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs       # (B, S, d_rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(xr, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return jnp.concatenate([out, xp], -1)


def apply_mrope(x, pos3, theta, sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE: pos3 (B, S, 3) = (t, h, w) indices; frequency bands are
    split into |sections| groups, each rotated by its own position stream."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                           # (half,)
    sec = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
    band = jnp.searchsorted(sec[1:], jnp.arange(half), side="right")  # 0/1/2
    # pick the position stream per frequency band
    p = jnp.take_along_axis(
        pos3.astype(jnp.float32),                          # (B, S, 3)
        jnp.broadcast_to(band[None, None, :], (*pos3.shape[:2], half)),
        axis=-1,
    )                                                      # (B, S, half)
    ang = p * freqs
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# --- quantized-softmax QAT simulator -----------------------------------------

def lut_softmax_qat(logits, s_logit, enabled: bool):
    """STE simulation of the 256-entry LUT softmax (paper §III-B).

    logits: real-valued, mask already applied as -inf/-1e9.
    s_logit: codes-per-real-unit of the integer logit grid (sqrt(d)*s_q*s_k).
    The index grid, 8-bit LUT values and Q1.7 output rounding all match the
    integer pipeline; gradients flow via STE.
    """
    if not enabled:
        return jax.nn.softmax(logits, axis=-1)
    lf = logits.astype(jnp.float32)
    # quantize logits onto the integer grid first (they arrive via int8 QK^T)
    lq = ste_round(lf * s_logit) / s_logit
    m = jax.lax.stop_gradient(jnp.max(lq, -1, keepdims=True))
    dgap = m - lq                                      # >= 0 real units
    idx = jnp.clip(ste_round(dgap / LUT_DELTA), 0, 255)
    num = ste_round(jnp.exp(-idx * LUT_DELTA) * 255.0) / 255.0
    num = jnp.where(idx >= 255, 0.0, num)              # LUT[255] == 0
    den = jnp.maximum(jnp.sum(num, -1, keepdims=True), 1e-9)
    p = num / den
    p = ste_round(p * 128.0) / 128.0                   # Q1.7 output codes
    return p.astype(logits.dtype)


# --- attention (QAT path, materialized scores) --------------------------------

def attention_qat(
    x: jax.Array,                  # (B, S, d)
    p: Dict,                       # {'wq','wk','wv','wo', optional 'qn','kn'}
    amax: Dict[str, jax.Array],
    policy: QuantPolicy,
    cfg,
    pos: jax.Array,                # (B, S) or (B, S, 3) for mrope
    mask: Optional[jax.Array] = None,   # (B, 1, Sq, Skv) bool, True = attend
) -> Tuple[jax.Array, Obs]:
    b, s, d = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    obs: Obs = {}
    qp, obs["attn_in"] = qdense(x, p["wq"], p.get("bq"), amax["attn_in"], policy)
    kp, _ = qdense(x, p["wk"], p.get("bk"), amax["attn_in"], policy)
    vp, _ = qdense(x, p["wv"], p.get("bv"), amax["attn_in"], policy)
    qh = qp.reshape(b, s, nh, hd)
    kh = kp.reshape(b, s, nkv, hd)
    vh = vp.reshape(b, s, nkv, hd)
    # pre-rope 8-bit grid (the linear's own output is an int8 intermediate;
    # RoPE is a dequant->rotate->requant island between two grids)
    qh, obs["q_pre"] = fake_quant_act(qh, amax["q_pre"], policy.a_bits,
                                      policy.quantize_wa)
    kh, obs["k_pre"] = fake_quant_act(kh, amax["k_pre"], policy.a_bits,
                                      policy.quantize_wa)
    if cfg.qk_norm:
        qh = rmsnorm(qh, p["qn"])
        kh = rmsnorm(kh, p["kn"])
    if cfg.mrope_sections is not None:
        qh = apply_mrope(qh, pos, cfg.rope_theta, cfg.mrope_sections)
        kh = apply_mrope(kh, pos, cfg.rope_theta, cfg.mrope_sections)
    elif not cfg.learned_pos:
        qh = apply_rope(qh, pos, cfg.rope_theta, cfg.partial_rotary)
        kh = apply_rope(kh, pos, cfg.rope_theta, cfg.partial_rotary)
    # 8-bit fake-quant of q, k, v — these ARE the integer path's q/k/v codes
    # (and the quantized KV cache at serving time)
    qh, obs["q"] = fake_quant_act(qh, amax["q"], policy.a_bits, policy.quantize_wa)
    kh, obs["k"] = fake_quant_act(kh, amax["k"], policy.a_bits, policy.quantize_wa)
    vh, obs["v"] = fake_quant_act(vh, amax["v"], policy.a_bits, policy.quantize_wa)
    from repro.sharding import partition as Pt
    dp = Pt.dp_axes_or_none()
    msize = Pt.model_axis_size()
    group = nh // nkv
    kg = jnp.repeat(kh, group, axis=2)
    vg = jnp.repeat(vh, group, axis=2)
    # integer logit grid: s_logit_codes = sqrt(hd) * s_q * s_k
    a_q, _ = _amax_or_obs(amax["q"], qh)
    a_k, _ = _amax_or_obs(amax["k"], kh)
    s_logit = jax.lax.stop_gradient(
        math.sqrt(hd) * (127.0 / a_q) * (127.0 / a_k))

    def rows(q_rows, row0, row_mask):
        """Full-row LUT softmax for a block of query rows — the paper's
        Softmax Core granularity.  (B, Cq, H, hd) -> (B, Cq, H*hd)."""
        cq = q_rows.shape[1]
        lg = jnp.einsum("bqhd,bkhd->bhqk", q_rows, kg) / math.sqrt(hd)
        if msize:
            if nh % msize == 0:
                lg = Pt.constrain(lg, dp, "model", None, None)
            elif cq % msize == 0:
                lg = Pt.constrain(lg, dp, None, "model", None)
        if row_mask is None and cfg.causal:
            qpos = row0 + jnp.arange(cq)[:, None]
            kpos = jnp.arange(s)[None, :]
            m2 = kpos <= qpos
            if cfg.sliding_window:
                m2 &= kpos > (qpos - cfg.sliding_window)
            row_mask = m2[None, None]
        if row_mask is not None:
            lg = jnp.where(row_mask, lg, -1e9)
        pr = lut_softmax_qat(lg, s_logit, policy.quantize_softmax)
        return jnp.einsum("bhqk,bkhd->bqhd", pr, vg).reshape(b, cq, nh * hd)

    # Row-chunked evaluation: softmax rows are independent (full Skv per row)
    # so semantics are exactly the row oracle; memory per layer drops from
    # O(S^2) to O(chunk*S), which is what makes train_4k/backward fit HBM.
    chunk = 512
    if s > chunk and s % chunk == 0 and mask is None:
        qc = qh.reshape(b, s // chunk, chunk, nh, hd).transpose(1, 0, 2, 3, 4)

        def body(_, inp):
            i, qq = inp
            return None, rows(qq, i * chunk, None)

        body = jax.checkpoint(body)
        _, ctxs = jax.lax.scan(body, None, (jnp.arange(s // chunk), qc))
        ctx = ctxs.transpose(1, 0, 2, 3).reshape(b, s, nh * hd)
    else:
        ctx = rows(qh, 0, mask)
    if msize and (nh * hd) % msize == 0:
        ctx = Pt.constrain(ctx, dp, None, "model")
    out, obs["attn_out_in"] = qdense(ctx, p["wo"], p.get("bo"),
                                     amax["attn_out_in"], policy)
    return out, obs


# --- MLPs ---------------------------------------------------------------------

def mlp_qat(x, p, amax, policy, act: str) -> Tuple[jax.Array, Obs]:
    obs: Obs = {}
    if act == "swiglu":
        g, obs["mlp_in"] = qdense(x, p["wg"], None, amax["mlp_in"], policy)
        u, _ = qdense(x, p["wu"], None, amax["mlp_in"], policy)
        # integer path: linear out is an int8 intermediate (g_pre grid), SiLU is
        # an int8->int8 256-entry LUT onto the g_out grid, the gate product is
        # an int8 x int8 multiply requantized to the h_in grid.
        g, obs["g_pre"] = fake_quant_act(g, amax["g_pre"],
                                         policy.a_bits, policy.quantize_wa)
        g, obs["g_out"] = fake_quant_act(jax.nn.silu(g), amax["g_out"],
                                         policy.a_bits, policy.quantize_wa)
        u, obs["u_out"] = fake_quant_act(u, amax["u_out"],
                                         policy.a_bits, policy.quantize_wa)
        h = g * u
        y, obs["h_in"] = qdense(h, p["wd"], None, amax["h_in"], policy)
    else:  # gelu
        h, obs["mlp_in"] = qdense(x, p["w1"], p.get("b1"), amax["mlp_in"], policy)
        h, obs["h_pre"] = fake_quant_act(h, amax["h_pre"],
                                         policy.a_bits, policy.quantize_wa)
        h, obs["g_out"] = fake_quant_act(jax.nn.gelu(h), amax["g_out"],
                                         policy.a_bits, policy.quantize_wa)
        y, obs["h_in"] = qdense(h, p["w2"], p.get("b2"), amax["h_in"], policy)
    return y, obs


def residual_add(x, delta, amax, policy) -> Tuple[jax.Array, jax.Array]:
    """int8 residual stream: both operands live on the residual grid."""
    y = x + delta
    y, ob = fake_quant_act(y, amax, policy.a_bits, policy.quantize_wa)
    return y, ob
