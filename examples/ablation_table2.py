"""Paper Table II ablation: progressively quantize w/a -> scales -> softmax
-> layernorm and measure the output divergence at each step.

    PYTHONPATH=src python examples/ablation_table2.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.policy import TABLE2_ROWS
from repro.models import transformer as T

base = smoke_config("bert-base")
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, base.vocab_size)
ref = None
print(f"{'config':<24} {'logit KL vs fp32':>18}")
for name, pol in TABLE2_ROWS:
    cfg = dataclasses.replace(base, quant=pol)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    amax = T.init_amax(cfg)
    _, obs, _ = T.forward(cfg, params, amax, toks)      # calibrate
    lg, _, _ = T.forward(cfg, params, obs, toks)
    if ref is None:
        ref = lg
        print(f"{name:<24} {'(reference)':>18}")
        continue
    p = jax.nn.softmax(ref, -1)
    kl = float(jnp.mean(jnp.sum(p * (jax.nn.log_softmax(ref, -1)
                                     - jax.nn.log_softmax(lg, -1)), -1)))
    print(f"{name:<24} {kl:>18.6f}")
