"""Online (flash) fully-quantized attention Pallas kernel.

The paper's Softmax Core assumes a whole row of QK^T in SRAM (seq 128).  At
32k-500k context that row no longer fits, so the LUT softmax is composed with
online softmax: per KV block the datapath is exactly the paper's —

    int8 QK^T -> int32 scores -> (max - s) -> fixed-point LUT index ->
    Q0.7 exp numerators -> int8 P @ int8 V on the MXU -> int32 partial

— and only the cross-block carried state (running max rescale factor,
denominator, output accumulator) is fp32, the same compromise FP8 flash
attention makes on GPUs (DESIGN.md §2).  With a single KV block the kernel
degenerates to the paper's row-wise softmax and is bit-exact vs. the oracle.

GQA is handled by the index_map (kv head = q head // group): no KV duplication
ever materializes in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

from repro.core import fixedpoint as fxp
from repro.core.qsoftmax import LUT_SIZE, MASK_OFFSET
from repro.kernels.quant_softmax import lut_lookup

NEG_INIT = -(1 << 30)


def _flash_kernel(bq, bkv, q_offset,
                  q_ref, k_ref, v_ref, lut_ref, mi_ref, si_ref, inv_ref,
                  osc_ref, o_ref, m_scr, den_scr, acc_scr):
    q_i = pl.program_id(1)
    k_i = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        den_scr[...] = jnp.zeros_like(den_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal skip: block contributes only if its first key pos <= last q pos
    live = (k_i * bkv) <= (q_i * bq + bq - 1 + q_offset)

    @pl.when(live)
    def _block():
        q = q_ref[0]                      # (bq, D) int8
        k = k_ref[0]                      # (bkv, D) int8
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.int32)
        qpos = q_offset + q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(kpos <= qpos, s, s - MASK_OFFSET)
        lm = jnp.max(s, axis=-1, keepdims=True)           # (bq, 1)
        m_old = m_scr[:, :1]
        m_new = jnp.maximum(m_old, lm)
        d = m_new - s
        idx = jnp.clip(fxp.rescale(d, mi_ref[0], si_ref[0], out_bits=9),
                       0, LUT_SIZE - 1)
        num = lut_lookup(idx, lut_ref[...].astype(jnp.int32))  # (bq,bkv) Q0.7
        den_b = jnp.sum(num, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(num.astype(jnp.int8), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        # fp32 cross-block carry
        f = jnp.exp((m_old - m_new).astype(jnp.float32) * inv_ref[0])
        f = jnp.where(m_old == NEG_INIT, 0.0, f)
        den_scr[...] = den_scr[...] * f + den_b.astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * f + pv.astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(k_i == nk - 1)
    def _epilogue():
        den = jnp.maximum(den_scr[:, :1], 1.0)
        o = acc_scr[...] / den * osc_ref[0]
        o_ref[0] = jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


def _decode_kernel(g, bkv, q_ref, k_ref, v_ref, lut_ref, mi_ref, si_ref,
                   inv_ref, osc_ref, len_ref, o_ref, m_scr, den_scr, acc_scr):
    k_i = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        den_scr[...] = jnp.zeros_like(den_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                  # (G, D) int8 — whole group
    k = k_ref[0]                                  # (bkv, D) int8
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.int32)   # (G, bkv)
    kpos = k_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (g, bkv), 1)
    s = jnp.where(kpos < len_ref[0], s, s - MASK_OFFSET)
    lm = jnp.max(s, axis=-1, keepdims=True)
    m_old = m_scr[:, :1]
    m_new = jnp.maximum(m_old, lm)
    idx = jnp.clip(fxp.rescale(m_new - s, mi_ref[0], si_ref[0], out_bits=9),
                   0, LUT_SIZE - 1)
    num = lut_lookup(idx, lut_ref[...].astype(jnp.int32))
    den_b = jnp.sum(num, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(num.astype(jnp.int8), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)  # (G, D)
    f = jnp.exp((m_old - m_new).astype(jnp.float32) * inv_ref[0])
    f = jnp.where(m_old == NEG_INIT, 0.0, f)
    den_scr[...] = den_scr[...] * f + den_b.astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * f + pv.astype(jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(k_i == nk - 1)
    def _epilogue():
        den = jnp.maximum(den_scr[:, :1], 1.0)
        o = acc_scr[...] / den * osc_ref[0]
        o_ref[0] = jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def flash_qdecode(
    q_i8: jax.Array,      # int8 (Hkv, G, D) — one token, q heads grouped
    k_i8: jax.Array,      # int8 (Hkv, Smax, D) — the int8 KV cache
    v_i8: jax.Array,
    cache_len: jax.Array,  # int32 scalar: number of valid positions
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, bkv: int = 512, interpret: bool = False,
) -> jax.Array:
    """GQA decode kernel: each KV block is streamed from HBM exactly ONCE and
    shared by all `G` grouped query heads (the jnp.repeat / per-q-head
    streaming formulations pay `G`x the KV traffic — EXPERIMENTS.md §Perf C).
    Returns int8 (Hkv, G, D) on the attn_out grid."""
    hkv, g, d = q_i8.shape
    _, smax, _ = k_i8.shape
    bkv = min(bkv, smax)
    assert smax % bkv == 0
    grid = (hkv, smax // bkv)
    kernel = functools.partial(_decode_kernel, g, bkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h, k: (h, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, k: (h, k, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, k: (h, k, 0)),
            pl.BlockSpec((LUT_SIZE,), lambda h, k: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda h, k: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hkv, g, d), jnp.int8),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.int32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_i8, k_i8, v_i8, lut_q7,
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1),
      jnp.asarray(cache_len, jnp.int32).reshape(1))


def flash_qattention_jax(
    q_i8: jax.Array,     # int8 (H, Sq, D)
    k_i8: jax.Array,     # int8 (Hkv, Skv, D)
    v_i8: jax.Array,     # int8 (Hkv, Skv, D)
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, q_offset=0, bkv: int = 512, window: int | None = None,
) -> jax.Array:
    """Pure-JAX mirror of the Pallas kernel (lax.scan over KV blocks; same
    integer per-block datapath, same fp32 carry).  This is what the dry-run
    lowers on the CPU backend so cost_analysis reflects the blocked algorithm,
    and what long-context serving uses off-TPU.  ``q_offset`` may be traced.
    ``window``: sliding-window attention size (mixtral)."""
    h, sq, d = q_i8.shape
    hkv, skv, _ = k_i8.shape
    group = h // hkv
    bkv = min(bkv, skv)
    assert skv % bkv == 0
    nkv = skv // bkv
    kb = k_i8.reshape(hkv, nkv, bkv, d).transpose(1, 0, 2, 3)
    vb = v_i8.reshape(hkv, nkv, bkv, d).transpose(1, 0, 2, 3)
    qpos = q_offset + jnp.arange(sq)[:, None]           # (Sq, 1)

    def step(carry, inp):
        m_old, den, acc = carry
        k_i, kblk, vblk = inp                           # (), (hkv,bkv,d) x2
        kg = jnp.repeat(kblk, group, axis=0)            # (h, bkv, d)
        vg = jnp.repeat(vblk, group, axis=0)
        s = jax.lax.dot_general(q_i8, kg, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.int32)
        kpos = k_i * bkv + jnp.arange(bkv)[None, :]     # (1, bkv)
        live = kpos <= qpos
        if window is not None:
            live &= kpos > (qpos - window)
        s = jnp.where(live[None], s, s - MASK_OFFSET)
        lm = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_old, lm)
        idx = jnp.clip(fxp.rescale(m_new - s, M_idx, shift_idx, out_bits=9),
                       0, LUT_SIZE - 1)
        num = jnp.take(lut_q7.astype(jnp.int32), idx)
        den_b = jnp.sum(num, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(num.astype(jnp.int8), vg,
                                 (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.int32)
        f = jnp.exp((m_old - m_new).astype(jnp.float32) * inv_s_logit)
        f = jnp.where(m_old == NEG_INIT, 0.0, f)
        den = den * f + den_b.astype(jnp.float32)
        acc = acc * f + pv.astype(jnp.float32)
        return (m_new, den, acc), None

    m0 = jnp.full((h, sq, 1), NEG_INIT, jnp.int32)
    den0 = jnp.zeros((h, sq, 1), jnp.float32)
    acc0 = jnp.zeros((h, sq, d), jnp.float32)
    (m, den, acc), _ = jax.lax.scan(
        step, (m0, den0, acc0), (jnp.arange(nkv), kb, vb))
    o = acc / jnp.maximum(den, 1.0) * out_scale
    return jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "q_offset",
                                              "interpret"))
def flash_qattention(
    q_i8: jax.Array,     # int8 (H, Sq, D)
    k_i8: jax.Array,     # int8 (Hkv, Skv, D)
    v_i8: jax.Array,     # int8 (Hkv, Skv, D)
    M_idx: jax.Array,
    shift_idx: jax.Array,
    lut_q7: jax.Array,   # (256,) int32 Q0.7 table
    inv_s_logit: jax.Array,  # fp32: 1 / s_x  (real units per logit code)
    out_scale: jax.Array,    # fp32: s_o / s_v
    *,
    q_offset: int = 0,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    h, sq, d = q_i8.shape
    hkv, skv, _ = k_i8.shape
    group = h // hkv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    grid = (h, sq // bq, skv // bkv)
    kernel = functools.partial(_flash_kernel, bq, bkv, q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qi, ki: (hh, qi, 0)),
            pl.BlockSpec((1, bkv, d), lambda hh, qi, ki, g=group: (hh // g, ki, 0)),
            pl.BlockSpec((1, bkv, d), lambda hh, qi, ki, g=group: (hh // g, ki, 0)),
            pl.BlockSpec((LUT_SIZE,), lambda hh, qi, ki: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hh, qi, ki: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), jnp.int8),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.int32),    # running max (col-broadcast)
            pltpu.VMEM((bq, 128), jnp.float32),  # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_i8, k_i8, v_i8, lut_q7,
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1),
      jnp.asarray(inv_s_logit, jnp.float32).reshape(1),
      jnp.asarray(out_scale, jnp.float32).reshape(1))
