"""Serving engines over the folded integer model.

``Engine`` — true continuous batching around a single token-budget step
loop: a fixed slot table shares one compiled decode graph; every slot
carries its own position (per-slot ``pos`` vector into ``serve_forward``),
requests are admitted mid-flight into free slots and evicted on
EOS/max-tokens by the ``Scheduler``.  Prefill is no longer a monolithic
one-shot forward at admission: each tick the scheduler carves waiting and
partially-prefilled prompts into page-aligned chunks under a shared token
budget (``max_batched_tokens`` per tick, ``max_prefill_chunk`` per slot)
and interleaves them with the decode batch, so a very long prompt can no
longer stall every decoding slot for the duration of its prefill.  A slot
keeps a ``prefill_pos`` cursor; its final chunk's last-row logits hand the
request into decode without an extra forward.  With both knobs unset a
prompt still prefills in one chunk — the pre-chunking behavior, now just a
degenerate schedule of the same loop.

Chunk forwards run through the decode-identical row datapath on the
ref/interpret kernel backends (CPU serving and CI), so a request's greedy
tokens are bit-for-bit what the lockstep engine produces for it alone —
and bit-for-bit identical across chunk sizes: chunking changes latency,
not outputs.  On the compiled pallas backend both prefill chunks and
decode dispatch to the q7 flash family instead (chunks go through the
block-table-walking ``paged_prefill_qattention`` kernel; self-consistent
integer datapath, but not bit-identical to the jnp path).  SSM/hybrid
architectures (whose prefill is a recurrence) fall back to a batch-1
decode-loop prefill, run as a single chunk of the same loop.

Cache layouts (``cache_layout=``):

* ``"paged"`` — the int8 KV cache is a global pool of fixed-size pages; each
  slot carries a block-table row instead of an exclusive ``Smax`` stripe.
  By default (``reserve_policy="ondemand"``) admission reserves only the
  PROMPT's pages; decode slots request their next page when the write
  cursor crosses a page boundary, and when the pool runs dry the engine
  preempts a victim — spill registers its finished pages in the prefix
  registry and requeues it at the queue front; restore replays through the
  ordinary chunk-continuation path, hitting the registry for whatever
  survived.  ``reserve_policy="full"`` keeps the PR-2 contract (prompt +
  decode budget reserved up front, decode can never OOM, overload stalls
  admission) for latency-critical serving where recompute is unacceptable.
  Prompt prefixes are shared at page granularity through the allocator's
  refcounted registry: a repeated system prompt maps cached pages and only
  the unseen suffix runs through the model.  Chunked prefill requires this
  layout (chunks are pages).
* ``"contiguous"`` — the original dense ``(B, Smax, Hkv, hd)`` stripe per
  slot (kept for one release as the A/B baseline; SWA ring buffers and
  SSM/hybrid archs always use it).  Prefill is always one whole-prompt
  chunk.
* ``"auto"`` (default) — paged when the arch supports it (all-attention,
  no sliding window), else contiguous.

Tensor parallelism (``tp=N`` or an explicit ``mesh``, paged layout only):
the page pool shards over its KV-head axis — every rank holds its heads'
slice of EVERY page, so page ids are global, block tables replicate, and
the host-side allocator/scheduler stays a single authority whose
admission/grow/preempt/spill decisions bind all ranks at once
(spill/restore never moves data across ranks; registration and replay are
rank-local).  Decode and prefill-chunk forwards run under one shard_map:
heads split per rank, chunks are the cross-rank work-division unit for
prefill, and contexts all-gather before the output projection, so sharded
greedy outputs are bit-identical to the unsharded engine on the
ref/interpret backends.  On CPU, simulate ranks with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the test-tp CI
lane's recipe).

``LockstepEngine`` — the original batch demo (kept as the benchmark baseline
and for SSM/audio archs): lockstep decoding with one shared position scalar,
prefill replayed token-by-token for the whole batch, admission only between
``generate()`` calls.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import serve_int as S
from repro.models.transformer import slot_kinds
from repro.serve.scheduler import (BlockAllocator, Scheduler, SlotState,
                                   pages_needed)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_token: Optional[int] = None
    out: Optional[np.ndarray] = None


def supports_continuous(cfg: ModelConfig) -> bool:
    """Continuous batching serves single-head token-LM archs; codebook/audio
    and multi-head archs go through LockstepEngine (see make_engine)."""
    return cfg.frontend == "none" and cfg.n_lm_heads == 1


_CONTINUOUS_ONLY_KW = ("prefill_bucket", "cache_layout", "page_size",
                       "n_pages", "max_batched_tokens", "max_prefill_chunk",
                       "reserve_policy", "tp", "mesh")


def make_engine(cfg: ModelConfig, folded, **kw):
    """The continuous engine when the arch supports it, else the lockstep
    baseline (same generate() surface).  Continuous-only kwargs passed for a
    lockstep arch are dropped with a warning — not silently."""
    cls = Engine if supports_continuous(cfg) else LockstepEngine
    if cls is LockstepEngine:
        dropped = sorted(k for k in _CONTINUOUS_ONLY_KW if k in kw)
        if dropped:
            warnings.warn(
                f"make_engine: arch {cfg.name!r} takes the LockstepEngine, "
                f"which ignores {', '.join(dropped)}", stacklevel=2)
            for k in dropped:
                kw.pop(k)
    return cls(cfg, folded, **kw)


class Engine:
    """Continuous-batching integer serving engine (token-budget step loop)."""

    def __init__(self, cfg: ModelConfig, folded, *, batch_slots: int = 8,
                 max_len: int = 512, seed: int = 0, prefill_bucket: int = 16,
                 cache_layout: str = "auto", page_size: int = 16,
                 n_pages: Optional[int] = None,
                 max_batched_tokens: Optional[int] = None,
                 max_prefill_chunk: Optional[int] = None,
                 reserve_policy: Optional[str] = None,
                 tp: int = 1, mesh=None):
        assert supports_continuous(cfg), \
            "continuous engine serves token-LM archs; use LockstepEngine"
        self.cfg = cfg
        self.folded = folded
        self.batch = batch_slots
        self.max_len = max_len
        self.smax = S.cache_rows(cfg, max_len)
        self.prefill_bucket = prefill_bucket
        # one-shot prefill needs every mixer to be cache-writing attention
        self._attn_only = cfg.causal and \
            all(m == "attn" for m, _ in slot_kinds(cfg))
        # the paged pool ignores the ACTIVATION-constraint mesh context
        # (that ctx drives the contiguous layout's SPMD constrain guards):
        # under an active ctx auto falls back to contiguous and an explicit
        # "paged" is refused rather than silently slow.  Tensor parallelism
        # for the paged pool goes through the engine-level ``tp``/``mesh``
        # kwargs instead (shard_map over the pool's Hkv axis, below).
        from repro.sharding import partition as Pt
        pageable = self._attn_only and not cfg.sliding_window \
            and Pt.get_mesh_ctx() is None
        if cache_layout == "auto":
            cache_layout = "paged" if pageable else "contiguous"
        assert cache_layout in ("paged", "contiguous"), cache_layout
        assert cache_layout != "paged" or pageable, \
            "paged layout requires an all-attention, non-SWA arch and no " \
            "active device mesh"
        self.layout = cache_layout
        self.page_size = page_size
        if cache_layout != "paged":
            assert max_batched_tokens is None and max_prefill_chunk is None, \
                "chunked prefill (max_batched_tokens / max_prefill_chunk) " \
                "requires the paged cache layout"
        self.max_batched_tokens = max_batched_tokens
        self.max_prefill_chunk = max_prefill_chunk
        # page-reservation policy: on-demand growth + preemption is the
        # default for the paged pool (the memory win paging exists for);
        # "full" restores the reserve-everything-at-admission contract
        if self.layout == "paged":
            self.reserve_policy = reserve_policy or "ondemand"
            assert self.reserve_policy in ("full", "ondemand"), reserve_policy
        else:
            assert reserve_policy in (None, "full"), \
                "on-demand page growth requires the paged cache layout"
            self.reserve_policy = "full"
        if self.layout == "paged":
            self.max_blocks = pages_needed(self.smax, page_size)
            # +1: page 0 is the reserved trash page (inactive-slot writes)
            self.n_pages = n_pages if n_pages is not None else \
                batch_slots * self.max_blocks + 1
            assert self.n_pages >= 2
        # --- tensor parallelism (paged pool sharded over KV heads) -------
        # Every rank holds its heads' slice of EVERY page: page ids stay
        # global, the block tables replicated, and the host-side
        # allocator/scheduler a single authority whose grow/preempt/spill
        # decisions apply to all ranks' slices at once.  tp=1 with an
        # explicit 1-device mesh runs the same shard_map path degenerately
        # (the no-simulation CI fallback).
        if mesh is None and tp != 1:
            from repro.launch.mesh import make_tp_mesh
            mesh = make_tp_mesh(tp)
        self.mesh = mesh
        if mesh is not None:
            assert self.layout == "paged", \
                "tensor parallelism shards the paged KV pool; the " \
                "contiguous layout has no TP path"
            assert "model" in mesh.axis_names, mesh.axis_names
            self.tp = int(mesh.shape["model"])
            assert tp in (1, self.tp), (tp, self.tp)
            assert cfg.n_kv_heads % self.tp == 0, \
                f"TP={self.tp} must divide n_kv_heads={cfg.n_kv_heads} " \
                "(each rank owns a whole slice of KV heads)"
        else:
            self.tp = 1
        self._init_state(seed)

        if self.layout == "paged":
            tp_axis = "model" if self.mesh is not None else None

            def decode_step(folded_, cache, tok, pos, btab):
                return S.serve_forward(cfg, folded_, tok, cache=cache,
                                       pos_offset=pos, mode="decode",
                                       block_tables=btab, tp_axis=tp_axis)

            def prefill(folded_, cache, toks, btab, pos0):
                return S.serve_forward(cfg, folded_, toks, cache=cache,
                                       pos_offset=pos0, mode="prefill",
                                       block_tables=btab, tp_axis=tp_axis)

            if self.mesh is not None:
                # one shard_map around the whole forward: the pool enters
                # as the rank-local Hkv slice; tokens, positions, and the
                # block table replicate; logits come back replicated (the
                # forward all-gathers heads before the output projection)
                from jax.sharding import PartitionSpec as P
                from repro.sharding import partition as Pt
                pool, rep = Pt.kv_pool_pspec(), P()
                decode_step = Pt.shard_map_compat(
                    decode_step, self.mesh,
                    in_specs=(rep, pool, rep, rep, rep),
                    out_specs=(rep, pool))
                prefill = Pt.shard_map_compat(
                    prefill, self.mesh,
                    in_specs=(rep, pool, rep, rep, rep),
                    out_specs=(rep, pool))
            self._decode = jax.jit(decode_step, donate_argnums=(1,))
            # the chunk forward: writes straight through the block table
            # into the (donated) pool at page-aligned ``pos0`` and attends
            # over the slot's whole mapped chain; one compiled shape per
            # chunk size (retraces per distinct padded length)
            self._prefill = jax.jit(prefill, donate_argnums=(1,))
        else:
            def decode_step(folded_, cache, tok, pos):
                return S.serve_forward(cfg, folded_, tok, cache=cache,
                                       pos_offset=pos, mode="decode")

            # one graph for the slot table AND (by retrace) the batch-1
            # prefill loop
            self._decode = jax.jit(decode_step, donate_argnums=(1,))

            def prefill(folded_, toks):
                cache1 = S.init_cache(cfg, 1, max_len)
                return S.serve_forward(cfg, folded_, toks, cache=cache1,
                                       mode="prefill")

            self._prefill = jax.jit(prefill)  # retraces per bucketed length

            def write_slot(cache, cache1, b):
                def put(c, c1):
                    starts = (0, b) + (0,) * (c.ndim - 2)
                    return jax.lax.dynamic_update_slice(c, c1, starts)
                return jax.tree.map(put, cache, cache1)

            self._write_slot = jax.jit(write_slot, donate_argnums=(0,))

    @staticmethod
    def _zero_counters() -> Dict[str, int]:
        return dict(ticks=0, prefill_tokens=0, prefill_chunks=0,
                    oneshot_prefills=0, chunked_prefills=0,
                    loop_prefill_steps=0, decode_steps=0, decode_tokens=0,
                    completed=0, prefix_hits=0, shared_rows=0,
                    suffix_prefills=0, cache_pages_peak=0,
                    # on-demand growth + preemption accounting
                    grown_pages=0,        # decode pages granted on demand
                    preemptions=0,        # victims spilled (pool ran dry)
                    preempted_prefill=0, preempted_decode=0,
                    restores=0,           # preempted requests re-seated
                    spilled_rows=0,       # cache rows held at spill time
                    recomputed_tokens=0,  # replayed rows the registry lost
                    pool_wait_ticks=0)    # ticks a request waited on pages
    #                                       while a slot stood free

    def _init_state(self, seed: int):
        self.requests: Dict[int, Request] = {}
        self.pos = np.zeros(self.batch, np.int32)
        self.rng = np.random.default_rng(seed)
        self.counters = self._zero_counters()
        if self.layout == "paged":
            self.alloc = BlockAllocator(self.n_pages, self.page_size)
            self.sched = Scheduler(self.batch, allocator=self.alloc,
                                   max_batched_tokens=self.max_batched_tokens,
                                   max_prefill_chunk=self.max_prefill_chunk,
                                   reserve=self.reserve_policy)
            self.cache = S.init_paged_cache(self.cfg, self.n_pages,
                                            self.page_size)
            if self.mesh is not None:
                # lay the pool out sharded before the first donated step so
                # every forward reuses the same per-rank Hkv-slice buffers
                from repro.sharding import partition as Pt
                self.cache = jax.device_put(
                    self.cache, Pt.paged_pool_shardings(self.mesh, self.cache))
            self.block_tables = np.zeros((self.batch, self.max_blocks),
                                         np.int32)
        else:
            self.alloc = None
            self.sched = Scheduler(self.batch)
            self.cache = S.init_cache(self.cfg, self.batch, self.max_len)

    def reset(self, seed: int = 0):
        """Clear all serving state; keeps the compiled graphs."""
        self._init_state(seed)

    # --- observability ---------------------------------------------------

    def stats(self, check: bool = False) -> Dict:
        """Instantaneous serving gauges + the cumulative ``counters``.

        Invariants the engine maintains (asserted in the tests, logged per
        tick by serve_bench): occupied slots partition into decode-active +
        prefilling; in the paged layout ``pages_in_use + pages_free +
        pages_cached_lru == pages_capacity`` and every prefilling slot's
        pending rows fit the pages it reserved.  ``check=True`` also sweeps
        ``BlockAllocator.check_invariants()`` — O(n_pages), so the tests'
        per-tick assertions opt in while bench/monitoring reads (which time
        the step loop) stay cheap."""
        pre = [self.sched.slots[b] for b in self.sched.prefilling]
        chunk = self.max_prefill_chunk
        pending = [st.prompt_len - st.prefill_pos for st in pre]
        g = dict(
            waiting=len(self.sched.waiting),
            decode_slots_active=len(self.sched.decoding),
            prefill_slots=len(pre),
            free_slots=self.sched.n_free,
            prefill_tokens_pending=sum(pending),
            prefill_chunks_pending=sum(
                -(-p // chunk) if chunk else 1 for p in pending),
        )
        if self.layout == "paged":
            al = self.alloc
            if check:
                al.check_invariants()
            g.update(pages_in_use=al.live,
                     pages_free=al.free_list_pages,
                     pages_cached_lru=al.lru_pages,
                     pages_capacity=al.capacity,
                     tp=self.tp)
        g["counters"] = dict(self.counters)
        return g

    # --- contiguous-layout helpers ---------------------------------------

    def _bucket_len(self, ln: int) -> int:
        """Padded one-shot prefill length for the contiguous layout: a
        multiple of prefill_bucket so compiled shapes are reused.  (Paged
        chunks pad to whole pages instead — see _run_chunk.)"""
        return min(max(self.prefill_bucket,
                       math.ceil(ln / self.prefill_bucket)
                       * self.prefill_bucket), self.smax)

    def _set_table_row(self, b: int, pages: List[int]):
        self.block_tables[b, :] = 0
        self.block_tables[b, :len(pages)] = pages

    # --- request lifecycle ----------------------------------------------

    def submit(self, request: Request) -> int:
        ln = len(request.prompt)
        # hard validation, not an assert: max_new_tokens >= 1 is what makes
        # the ln + max_new - 1 page reservation always cover the prefill
        # scatter's whole-page padding (pages_needed(ln) rows)
        if ln < 1 or request.max_new_tokens < 1:
            raise ValueError(
                f"request needs a non-empty prompt and max_new_tokens >= 1 "
                f"(got prompt len {ln}, max_new_tokens "
                f"{request.max_new_tokens})")
        if not self.cfg.sliding_window:
            if ln + request.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request needs {ln + request.max_new_tokens} cache rows, "
                    f"engine max_len={self.max_len}")
        if self.layout == "paged":
            worst = pages_needed(ln + request.max_new_tokens - 1,
                                 self.page_size)
            if worst > self.alloc.capacity:
                raise ValueError(
                    f"request needs up to {worst} cache pages, pool has "
                    f"{self.alloc.capacity} (n_pages={self.n_pages})")
        rid = self.sched.submit(request)
        self.requests[rid] = request
        return rid

    def _pick_token(self, logits_row: np.ndarray, req: Request) -> int:
        if req.temperature > 0:
            z = logits_row / max(req.temperature, 1e-4)
            z = z + self.rng.gumbel(size=z.shape)
            return int(np.argmax(z))
        return int(np.argmax(logits_row))

    def _prefill_request(self, req: Request) -> Tuple[np.ndarray, object, int]:
        """Contiguous layout: build the batch-1 cache for a prompt; returns
        (last-position logits (V,), cache1, prompt_len)."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        ln = len(prompt)
        if self._attn_only and ln <= self.smax:
            # one-shot: pad to a bucket so compiled prefill shapes are reused;
            # a pad row at cache index r is overwritten by the decode step at
            # pos == r — the same step whose mask first admits index r — so
            # pad garbage is never attended
            bl = self._bucket_len(ln)
            toks = np.zeros((1, bl), np.int32)
            toks[0, :ln] = prompt
            logits, cache1 = self._prefill(self.folded, jnp.asarray(toks))
            return np.asarray(logits[0, ln - 1]), cache1, ln
        # recurrence (SSM/hybrid) or over-long SWA prompt: batch-1 decode loop
        cache1 = S.init_cache(self.cfg, 1, self.max_len)
        logits = None
        for t in range(ln):
            logits, cache1 = self._decode(
                self.folded, cache1, jnp.asarray(prompt[t].reshape(1, 1)),
                jnp.asarray(np.asarray([t], np.int32)))
            self.counters["loop_prefill_steps"] += 1
        return np.asarray(logits[0, -1]), cache1, ln

    def _run_chunk(self, b: int, st: SlotState, pos0: int, ntok: int
                   ) -> List[Tuple[int, int]]:
        """One prefill chunk for slot ``b``: rows [pos0, pos0+ntok) of the
        prompt through the chunk forward.  On the FINAL chunk the last real
        row's logits hand the request straight into decode (first token
        sampled, no extra forward); mid-prompt chunks emit nothing.

        Paged: the chunk scatters its K/V through a local block-table row
        and attends over the slot's whole mapped chain (prior chunks +
        shared prefix pages read directly from the page pool).  The engine's
        shared ``block_tables`` row stays zeroed (trash page) until handoff,
        so decode ticks running while this slot is mid-prefill cannot
        scribble on its pages.  Contiguous: a single whole-prompt chunk via
        the batch-1 prefill + slot write (chunking needs pages).

        A restored preempted slot runs through this same path — its
        ``prompt_tokens`` replay sequence includes any tokens it emitted
        before the spill.  Each chunk charges ``recomputed_tokens`` for the
        rows it re-runs below the slot's high-water mark (the furthest row
        ever computed, across every spill) — rows the prefix registry gave
        back are skipped by the cursor and never charged."""
        req = st.request
        prompt = np.asarray(st.prompt_tokens(), np.int32).reshape(-1)
        ln = len(prompt)
        if pos0 < st.hwm_rows:
            self.counters["recomputed_tokens"] += \
                min(pos0 + ntok, st.hwm_rows) - pos0
        final = pos0 + ntok >= ln
        loop_prefill = False
        if self.layout == "paged":
            # ragged last chunk pads to whole pages (the scatter writes
            # whole pages); pad rows sit causally after every real query
            # and are overwritten by the decode step at their position
            pad = pages_needed(ntok, self.page_size) * self.page_size
            toks = np.zeros((1, pad), np.int32)
            toks[0, :ntok] = prompt[pos0:pos0 + ntok]
            btab = np.zeros((1, self.max_blocks), np.int32)
            btab[0, :len(st.pages)] = st.pages
            logits, self.cache = self._prefill(
                self.folded, self.cache, jnp.asarray(toks),
                jnp.asarray(btab), jnp.int32(pos0))
            last = np.asarray(logits[0, ntok - 1]) if final else None
        else:
            assert pos0 == 0 and final, \
                "contiguous layout prefills in one whole-prompt chunk"
            loop_prefill = not (self._attn_only and ln <= self.smax)
            last, cache1, _ = self._prefill_request(req)
            self.cache = self._write_slot(self.cache, cache1, jnp.int32(b))
        st.prefill_pos = pos0 + ntok
        st.chunks_done += 1
        self.counters["prefill_tokens"] += ntok
        self.counters["prefill_chunks"] += 1
        if not final:
            return []
        # --- handoff into decode (no extra forward) ---
        if self.layout == "paged":
            self.alloc.register_prefix([int(t) for t in prompt], st.pages)
            self._set_table_row(b, st.pages)
        # the replay snapshot is spent: decode appends to ``emitted`` from
        # here, so keeping it would silently desync prompt_tokens(); the
        # next spill (if any) rebuilds it from prompt + emitted
        st.tokens = None
        if st.shared_rows:
            self.counters["prefix_hits"] += 1
            self.counters["shared_rows"] += st.shared_rows
            if st.chunks_done == 1:
                self.counters["suffix_prefills"] += 1
        elif st.chunks_done == 1 and not loop_prefill:
            self.counters["oneshot_prefills"] += 1
        if st.chunks_done > 1:
            self.counters["chunked_prefills"] += 1
        self.pos[b] = ln
        st.pos = ln
        tok = self._pick_token(last, req)
        st.last_token = tok
        st.emitted.append(tok)
        if self._done(st):
            self._finish(b)
        return [(st.rid, tok)]

    def _finish(self, b: int):
        st = self.sched.evict(b)        # paged: returns the page chain
        req = self.requests.pop(st.rid)
        req.out = np.asarray(st.emitted, np.int32)
        self.pos[b] = 0
        if self.layout == "paged":
            self.block_tables[b, :] = 0
        self.counters["completed"] += 1

    # --- on-demand growth + preemption -----------------------------------

    def _preempt(self, b: int):
        """Spill slot ``b`` (scheduler registers its finished pages and
        requeues it at the queue front) and clear its engine-side rows."""
        st = self.sched.slots[b]
        was_prefilling = st.prefilling
        self.sched.preempt(b)
        self.pos[b] = 0
        self.block_tables[b, :] = 0
        self.counters["preemptions"] += 1
        self.counters["preempted_prefill" if was_prefilling
                      else "preempted_decode"] += 1
        self.counters["spilled_rows"] += st.spilled_rows

    def _grow_decode_pages(self):
        """On-demand mode, run between the tick's prefill chunks and its
        decode forward: make sure every decoding slot owns the page its
        write cursor is about to enter.  Slots grow oldest-first; when the
        pool comes up empty the scheduler names a victim (last-admitted
        prefilling slot, else longest-remaining decoder — never the oldest
        seated request while another candidate exists) which is spilled and
        the allocation retried.  ``submit`` caps every request's worst-case
        pages at pool capacity, so once every other slot is spilled the
        grower's allocation cannot fail — the RuntimeError is a genuine
        invariant breach, not an operating condition."""
        order = sorted(self.sched.decoding,
                       key=lambda b: self.sched.slots[b].rid)
        for b in order:
            st = self.sched.slots[b]
            if st is None:              # preempted by an earlier grower
                continue
            while True:
                got = self.sched.grow(st, st.pos + 1)
                if got is not None:
                    self.counters["grown_pages"] += got
                    break
                v = self.sched.pick_victim(exclude=frozenset({b}))
                if v is None:
                    raise RuntimeError(
                        "page pool exhausted with no preemption victim; "
                        "submit() sizing makes this unreachable")
                self._preempt(v)
            if got:                     # chain unchanged -> row already set
                self._set_table_row(b, st.pages)

    def _done(self, st: SlotState) -> bool:
        req = st.request
        if len(st.emitted) >= req.max_new_tokens:
            return True
        return req.eos_token is not None and st.emitted and \
            st.emitted[-1] == req.eos_token

    # --- the engine loop ------------------------------------------------

    def step(self) -> List[Tuple[int, int]]:
        """One scheduler tick of the token-budget loop:

        1. seat waiting requests into free slots (paged: reserve their page
           budget; prefill does NOT run here),
        2. run prefill chunks for prefilling slots under the tick's token
           budget (``max_batched_tokens`` minus this tick's decode tokens;
           a final chunk also charges the decode token of its handoff),
           replanning after every chunk so a completion's registered prefix
           is visible to the next slot's first chunk,
        3. (on-demand reservation) grow each decoding slot's page chain
           where its write cursor crosses a page boundary, preempting a
           victim when the pool runs dry,
        4. decode one token for every slot whose prompt is fully cached
           (slots that handed off in step 2 join the same tick's batch).

        Returns the (rid, token) pairs emitted this tick."""
        self.counters["ticks"] += 1
        emitted: List[Tuple[int, int]] = []
        placed = self.sched.admit()
        for _b, st in placed:
            if st.preemptions:          # a spilled request re-seated
                self.counters["restores"] += 1
        if self.layout == "paged" and self.sched.waiting \
                and self.sched.n_free > 0:
            # a request is waiting on PAGES, not slots: the stranded-
            # capacity signal the overload bench A/Bs across policies
            self.counters["pool_wait_ticks"] += 1
        n_decode = len(self.sched.decoding)
        used = 0
        chunked: set = set()
        while True:
            plan = self.sched.next_chunk(n_decode, used,
                                         exclude=frozenset(chunked))
            if plan is None:
                break
            b, st, pos0, ntok = plan
            chunked.add(b)
            # a final chunk hands the slot into this tick's decode batch:
            # charge its decode token so the budget stays a real cap
            used += ntok + (pos0 + ntok >= st.prompt_len)
            emitted.extend(self._run_chunk(b, st, pos0, ntok))
        for b in self.sched.prefilling:   # scheduler anti-starvation input
            st = self.sched.slots[b]
            st.starved_ticks = 0 if b in chunked else st.starved_ticks + 1
        if self.layout == "paged" and self.reserve_policy == "ondemand":
            self._grow_decode_pages()     # may preempt victims
        active = self.sched.decoding
        if self.layout == "paged":
            self.counters["cache_pages_peak"] = self.alloc.peak_live
        if not active:
            return emitted
        toks = np.zeros((self.batch, 1), np.int32)
        for b in active:
            toks[b, 0] = self.sched.slots[b].last_token
        if self.layout == "paged":
            logits, self.cache = self._decode(
                self.folded, self.cache, jnp.asarray(toks),
                jnp.asarray(self.pos), jnp.asarray(self.block_tables))
        else:
            logits, self.cache = self._decode(self.folded, self.cache,
                                              jnp.asarray(toks),
                                              jnp.asarray(self.pos))
        rows = np.asarray(logits[:, -1])          # (B, V)
        for b in active:
            st = self.sched.slots[b]
            self.pos[b] += 1
            st.pos += 1
            tok = self._pick_token(rows[b], st.request)
            st.last_token = tok
            st.emitted.append(tok)
            emitted.append((st.rid, tok))
            if self._done(st):
                self._finish(b)
        self.counters["decode_steps"] += 1
        self.counters["decode_tokens"] += len(active)
        return emitted

    def run(self) -> List[Tuple[int, int]]:
        """Drain the queue; returns every (rid, token) emitted."""
        out = []
        while self.sched.has_work:
            out.extend(self.step())
        return out

    def generate(self, requests: List[Request]) -> List[Request]:
        """Batch convenience API: submit everything, drain, return the same
        requests with ``.out`` filled (continuous batching inside)."""
        for r in requests:
            self.submit(r)
        self.run()
        return requests


class LockstepEngine:
    """The original lockstep engine: one shared position scalar, prefill
    replayed through the decode graph for the whole (same-length) batch.
    Kept as the serve_bench baseline and for archs the continuous engine
    doesn't take (audio codebooks)."""

    def __init__(self, cfg: ModelConfig, folded, *, batch_slots: int = 8,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.folded = folded
        self.batch = batch_slots
        self.max_len = max_len
        self.cache = S.init_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.key = jax.random.PRNGKey(seed)

        def decode_step(folded_, cache, tok, pos):
            return S.serve_forward(cfg, folded_, tok, cache=cache,
                                   pos_offset=pos, mode="decode")

        self._decode = jax.jit(decode_step, donate_argnums=(1,))

    def reset(self, seed: int = 0):
        self.cache = S.init_cache(self.cfg, self.batch, self.max_len)
        self.pos = np.zeros(self.batch, np.int32)
        self.key = jax.random.PRNGKey(seed)

    def _step(self, tokens_col: np.ndarray, pos_scalar: int):
        tok = jnp.asarray(tokens_col).reshape(self.batch, 1)
        logits, self.cache = self._decode(self.folded, self.cache, tok,
                                          jnp.int32(pos_scalar))
        return logits[:, -1] if logits.ndim == 3 else logits[:, :, -1]

    def generate(self, requests: List[Request]) -> List[Request]:
        """Lockstep decode for a batch of same-length-padded prompts."""
        assert len(requests) <= self.batch
        max_prompt = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((self.batch, max_prompt), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        outs = [[] for _ in requests]
        # prefill via lockstep decode (works uniformly for attn/ssm/hybrid)
        last_logits = None
        for t in range(max_prompt):
            last_logits = self._step(toks[:, t], t)
        cur = np.asarray(jnp.argmax(last_logits, -1)).astype(np.int32)
        for i in range(len(requests)):
            outs[i].append(int(cur[i]))
        for t in range(max_prompt, max_prompt + max_new - 1):
            logits = self._step(cur, t)
            if any(r.temperature > 0 for r in requests):
                self.key, sub = jax.random.split(self.key)
                samp = jax.random.categorical(sub, logits / max(
                    requests[0].temperature, 1e-4), -1)
                cur = np.asarray(samp).astype(np.int32)
            else:
                cur = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            for i in range(len(requests)):
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(cur[i]))
        for r, o in zip(requests, outs):
            r.out = np.asarray(o, np.int32)
        return requests
