"""Symmetric linear quantization — the FQ-BERT scheme (paper Eq. 1-3).

The paper's quantizer, for k-bit symmetric quantization of a tensor x:

    x_c = clamp(x, MIN, MAX)            MIN = -MAX (symmetric), tuned in QAT
    s   = (2^(k-1) - 1) / MAX           "scale" multiplies REAL -> INT
    x_I = round(x_c * s)                integer code
    x_q = x_I / s                       dequantized (fake-quant) value

Weights use MAX = max|W| (Eq. 2); activations use an EMA of max|A| collected
during training (Eq. 3). Everything here is pure JAX and differentiable via a
straight-through estimator so the same code serves QAT and calibration.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

def qmax(bits: int) -> int:
    """Largest positive code of a symmetric k-bit quantizer: 2^(k-1) - 1."""
    return (1 << (bits - 1)) - 1


def storage_dtype(bits: int):
    """Storage dtype for k-bit codes (4-bit rides sign-extended in int8;
    nibble packing lives in packing.py)."""
    if bits <= 8:
        return jnp.int8
    if bits <= 16:
        return jnp.int16
    return jnp.int32


def compute_scale(max_abs: jax.Array, bits: int) -> jax.Array:
    """Paper Eq. 2/3: s = (2^(k-1)-1) / MAX.  REAL * s -> code."""
    max_abs = jnp.maximum(max_abs, 1e-8)  # guard all-zero tensors
    return qmax(bits) / max_abs


def quantize(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """x -> integer codes (round-to-nearest-even, clamped to symmetric range)."""
    q = jnp.clip(jnp.round(x * scale), -qmax(bits), qmax(bits))
    return q.astype(storage_dtype(bits))


def dequantize(x_int: jax.Array, scale: jax.Array) -> jax.Array:
    return x_int.astype(jnp.float32) / scale


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant(x: jax.Array, max_abs: jax.Array, bits: int) -> jax.Array:
    """Fake quantization with a straight-through estimator (QAT forward).

    Matches the integer path bit-for-bit: fake_quant(x) == dequantize(quantize(x)).
    Gradients flow straight through the round; the clamp DOES gate gradients
    (values outside [MIN, MAX] get zero grad), which is what lets the clip
    thresholds train — the paper notes MIN/MAX "need to be carefully tuned".
    """
    max_abs = jnp.maximum(jnp.asarray(max_abs, x.dtype), 1e-8)
    s = qmax(bits) / max_abs
    x_c = jnp.clip(x, -max_abs, max_abs)
    return _ste_round(x_c * s) / s


def per_tensor_max(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x))


def per_channel_max(x: jax.Array, axis: int = -1) -> jax.Array:
    """Beyond-paper option: per-output-channel MAX (paper is per-tensor)."""
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    return jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)


@dataclasses.dataclass(frozen=True)
class EMACalibrator:
    """Paper Eq. 3 — exponential moving average of max|A| for activation scales.

    Functional: state is a scalar (or per-channel) array threaded by the caller.
    """

    decay: float = 0.99

    def init(self, shape=()) -> jax.Array:
        return jnp.zeros(shape, jnp.float32)

    def update(self, ema: jax.Array, x: jax.Array) -> jax.Array:
        batch_max = per_tensor_max(x).astype(jnp.float32)
        # First observation (ema == 0) adopts the batch statistic directly.
        new = self.decay * ema + (1.0 - self.decay) * batch_max
        return jnp.where(ema == 0.0, batch_max, new)


def quantize_bias(bias: jax.Array, s_a: jax.Array, s_w: jax.Array) -> jax.Array:
    """Paper Eq. 4: bias_I = round(bias * s_bias), s_bias = s_a * s_w -> int32."""
    s_bias = s_a * s_w
    return jnp.round(bias * s_bias).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Activation-statistics pytree helpers.  QAT threads a dict of EMA maxima
# (one scalar per quantized activation site) through the model; these helpers
# keep that bookkeeping in one place.
# ---------------------------------------------------------------------------

def ema_tree_update(ema_tree: dict, obs_tree: dict, decay: float = 0.99) -> dict:
    cal = EMACalibrator(decay)
    return jax.tree.map(lambda e, o: cal.update(e, o), ema_tree, obs_tree)
