"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151_936,
    n_experts=60, top_k=4, n_shared_experts=4, moe_d_ff=1408,
    moe_period=1, moe_offset=0,
    rope_theta=1_000_000.0, param_dtype="bfloat16",
))
