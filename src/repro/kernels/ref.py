"""Pure-jnp oracles for every Pallas kernel.

Integer kernels must match these BIT-EXACTLY in interpret mode (tests assert
array equality, not allclose).  The flash attention kernel is block-online and
carries its cross-block state in fp32 (see DESIGN.md), so it is compared to
``qattention_ref`` with a small LSB tolerance — and bit-exactly when a single
KV block covers the row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fxp
from repro.core import packing
from repro.core import qlayernorm as qln
from repro.core import qsoftmax as qs


def int4_matmul_ref(
    x_i8: jax.Array,      # int8 (M, K)
    w_packed: jax.Array,  # uint8 (K//2, N), K-planar nibble packing
    bias_i32: jax.Array,  # int32 (N,)
    M_q: jax.Array,
    shift_q: jax.Array,
) -> jax.Array:
    """W4A8 integer matmul + bias + fixed-point requantize -> int8 (M, N)."""
    w = packing.unpack_int4_planar(w_packed, axis=0).astype(jnp.int8)
    acc = jax.lax.dot_general(
        x_i8.astype(jnp.int8), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc = acc + bias_i32.astype(jnp.int32)
    return fxp.requantize(acc, M_q, shift_q, bits=8)


def int8_bitsplit_matmul_ref(
    x_i8: jax.Array,   # int8 (M, K)
    w_i8: jax.Array,   # int8 (K, N) full 8-bit codes
    bias_i32: jax.Array,
    M_q: jax.Array,
    shift_q: jax.Array,
) -> jax.Array:
    """8x8 product via two 8x4 passes + shift-add — the BIM Type-A identity.

    w = (w >> 4) * 16 + (w & 15): hi is signed int4, lo unsigned 4-bit.
    Mathematically identical to a direct int8 dot; computed the bit-split way
    so the kernel and oracle share the exact accumulation order budget.
    """
    w32 = w_i8.astype(jnp.int32)
    hi = (w32 >> 4).astype(jnp.int8)          # arithmetic shift: signed high nibble
    lo = (w32 & 15).astype(jnp.int8)          # unsigned low nibble, fits int8
    x = x_i8.astype(jnp.int8)
    dn = (((1,), (0,)), ((), ()))
    acc_hi = jax.lax.dot_general(x, hi, dn, preferred_element_type=jnp.int32)
    acc_lo = jax.lax.dot_general(x, lo, dn, preferred_element_type=jnp.int32)
    acc = (acc_hi << 4) + acc_lo + bias_i32.astype(jnp.int32)
    return fxp.requantize(acc, M_q, shift_q, bits=8)


def quant_softmax_ref(x_int, M_idx, shift_idx, lut, mask=None, axis=-1):
    return qs.quant_softmax(x_int, M_idx, shift_idx, lut, mask=mask, axis=axis)


def quant_layernorm_ref(x_int, p: qln.QLNParams, eps_codes: int = 1):
    return qln.quant_layernorm(x_int, p, eps_codes)


def qattention_ref(
    q_i8: jax.Array,    # int8 (H, Sq, D)
    k_i8: jax.Array,    # int8 (Hkv, Skv, D)
    v_i8: jax.Array,    # int8 (Hkv, Skv, D)
    M_idx: jax.Array,   # LUT index multiplier for (max - s) -> table steps
    shift_idx: jax.Array,
    lut: jax.Array,     # (256,) int32, Q0.7 codes (flash-compatible table)
    out_scale: jax.Array,  # fp32: s_o / s_v  (epilogue projection to out grid)
    causal: bool = True,
    q_offset: int = 0,  # absolute position of q row 0 (decode: cache length)
) -> jax.Array:
    """Row-wise fully-quantized attention oracle (paper-style, non-flash).

    Integer datapath: int8 QK^T -> int32 scores -> LUT numerators (Q0.7) ->
    integer P (codes sum ~127 per row) -> int32 P@V; the final division and
    output projection are the fp32 epilogue shared with the flash kernel.
    """
    h, sq, d = q_i8.shape
    hkv = k_i8.shape[0]
    group = h // hkv
    k_g = jnp.repeat(k_i8, group, axis=0)
    v_g = jnp.repeat(v_i8, group, axis=0)
    dn = (((2,), (2,)), ((0,), (0,)))
    s = jax.lax.dot_general(q_i8, k_g, dn, preferred_element_type=jnp.int32)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k_i8.shape[1])[None, :]
        s = jnp.where((kpos <= qpos)[None], s, s - qs.MASK_OFFSET)
    m = jnp.max(s, axis=-1, keepdims=True)
    dgap = (m - s).astype(jnp.int32)
    idx = jnp.clip(fxp.rescale(dgap, M_idx, shift_idx, out_bits=9), 0, 255)
    num = jnp.take(lut.astype(jnp.int32), idx)           # Q0.7 codes, <= 127
    den = jnp.maximum(jnp.sum(num, axis=-1, keepdims=True), 1)
    pv = jax.lax.dot_general(
        num.astype(jnp.int8), v_g.astype(jnp.int8),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )
    o = pv.astype(jnp.float32) / den.astype(jnp.float32)
    y = jnp.round(o * out_scale)
    return jnp.clip(y, -127, 127).astype(jnp.int8)


def decode_qattention_ref(
    q_i8: jax.Array,      # int8 (B, Hkv, G, D) — one query token per slot
    k_i8: jax.Array,      # int8 (B, Hkv, Smax, D) — int8 KV cache
    v_i8: jax.Array,
    lengths: jax.Array,   # int32 (B,): valid cache prefix per slot
    M_idx: jax.Array,
    shift_idx: jax.Array,
    lut: jax.Array,       # (256,) int32 Q0.7 codes
    out_scale: jax.Array,
) -> jax.Array:
    """Row-wise oracle for the continuous-batching decode kernel: per slot,
    paper-style LUT attention of one query over the first ``lengths[b]``
    cached positions.  int8 (B, Hkv, G, D) on the attn_out grid.

    Realized as ``qattention_ref`` with the query at absolute position
    ``lengths[b] - 1`` — the causal mask then admits exactly the valid
    prefix, so the masking semantics match the kernel bit-for-bit.
    """
    b, hkv, g, d = q_i8.shape

    def one(qb, kb, vb, ln):
        o = qattention_ref(qb.reshape(hkv * g, 1, d), kb, vb,
                           M_idx, shift_idx, lut, out_scale,
                           causal=True, q_offset=ln - 1)
        return o.reshape(hkv, g, d)

    return jax.vmap(one)(q_i8, k_i8, v_i8, lengths)


def paged_decode_qattention_ref(
    q_i8: jax.Array,          # int8 (B, Hkv, G, D) — one query token per slot
    k_pool: jax.Array,        # int8 (n_pages, P, Hkv, D) — global page pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # int32 (B, max_blocks): slot -> pool pages
    lengths: jax.Array,       # int32 (B,): valid rows per slot
    M_idx: jax.Array,
    shift_idx: jax.Array,
    lut: jax.Array,           # (256,) int32 Q0.7 codes
    inv_s_logit: jax.Array,
    out_scale: jax.Array,
) -> jax.Array:
    """Block-online oracle for the PAGED decode kernel: per slot, one page
    per step gathered through the block table, with the kernel's exact
    accumulation order (int32 scores, Q0.7 LUT numerators, fp32 running
    max-rescale / denominator / output carry).  Because every operation and
    its order match ``_decode_kernel``, the Pallas kernel is BIT-EXACT
    against this oracle for any page count — unlike the contiguous kernel,
    whose oracle is the row-wise ``decode_qattention_ref`` (exact only when
    one block covers the row)."""
    from repro.core.qsoftmax import LUT_SIZE

    b, hkv, g, d = q_i8.shape
    psize = k_pool.shape[1]
    nb = block_tables.shape[1]
    neg_init = -(1 << 30)
    m = jnp.full((b, hkv, g, 1), neg_init, jnp.int32)
    den = jnp.zeros((b, hkv, g, 1), jnp.float32)
    acc = jnp.zeros((b, hkv, g, d), jnp.float32)
    lut32 = lut.astype(jnp.int32)
    inv = jnp.asarray(inv_s_logit, jnp.float32)
    for k_i in range(nb):
        pg = block_tables[:, k_i]                          # (B,)
        kb = jnp.take(k_pool, pg, axis=0).transpose(0, 2, 1, 3)  # (B,Hkv,P,D)
        vb = jnp.take(v_pool, pg, axis=0).transpose(0, 2, 1, 3)
        s = jax.lax.dot_general(
            q_i8, kb, (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.int32)              # (B,Hkv,G,P)
        kpos = k_i * psize + jnp.arange(psize, dtype=jnp.int32)
        s = jnp.where(kpos[None, None, None, :] < lengths[:, None, None, None],
                      s, s - qs.MASK_OFFSET)
        lm = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, lm)
        idx = jnp.clip(fxp.rescale(m_new - s, M_idx, shift_idx, out_bits=9),
                       0, LUT_SIZE - 1)
        num = jnp.take(lut32, idx)                         # Q0.7 numerators
        den_b = jnp.sum(num, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            num.astype(jnp.int8), vb, (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.int32)              # (B,Hkv,G,D)
        f = jnp.exp((m - m_new).astype(jnp.float32) * inv)
        f = jnp.where(m == neg_init, 0.0, f)
        live = ((k_i * psize) < lengths)[:, None, None, None]
        den = jnp.where(live, den * f + den_b.astype(jnp.float32), den)
        acc = jnp.where(live, acc * f + pv.astype(jnp.float32), acc)
        m = jnp.where(live, m_new, m)
    den = jnp.maximum(den, 1.0)
    o = acc / den * out_scale
    return jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


def paged_prefill_qattention_ref(
    q_i8: jax.Array,          # int8 (B, H, Sq, D) — chunk queries, ungrouped
    k_pool: jax.Array,        # int8 (n_pages, P, Hkv, D) — global page pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # int32 (B, max_blocks): slot -> pool pages
    pos0: jax.Array,          # int32 (B,): chunk start position per slot
    M_idx: jax.Array,
    shift_idx: jax.Array,
    lut: jax.Array,           # (256,) int32 Q0.7 codes
    inv_s_logit: jax.Array,
    out_scale: jax.Array,
) -> jax.Array:
    """Block-online oracle for the paged chunked-PREFILL kernel: queries at
    absolute positions [pos0[b], pos0[b]+Sq) attend causally over the
    slot's whole block-table chain, one pool page per step, with the
    kernel's exact accumulation order (int32 scores, Q0.7 LUT numerators,
    fp32 running max-rescale / denominator / output carry).

    The kernel additionally SKIPS blocks wholly past a q block's causal
    frontier; the oracle processes every block unconditionally.  These are
    bit-identical: a fully-masked block's scores sit MASK_OFFSET below any
    live score, so its row max never wins (``m_new == m_old`` exactly, and
    block 0 is live for every query, so ``m_old`` is never NEG_INIT after
    it), the rescale factor is ``exp(0) == 1.0`` (fp32-exact multiply), and
    its LUT indices clip to the table's terminal zero code — the update
    adds exact zeros.  That also makes the kernel's result independent of
    its q-block size, so the oracle needs no ``bq`` parameter."""
    from repro.core.qsoftmax import LUT_SIZE

    b, h, sq, d = q_i8.shape
    psize = k_pool.shape[1]
    hkv = k_pool.shape[2]
    group = h // hkv
    nb = block_tables.shape[1]
    neg_init = -(1 << 30)
    m = jnp.full((b, h, sq, 1), neg_init, jnp.int32)
    den = jnp.zeros((b, h, sq, 1), jnp.float32)
    acc = jnp.zeros((b, h, sq, d), jnp.float32)
    lut32 = lut.astype(jnp.int32)
    inv = jnp.asarray(inv_s_logit, jnp.float32)
    qpos = pos0[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]  # (B,Sq)
    for k_i in range(nb):
        pg = block_tables[:, k_i]                          # (B,)
        kb = jnp.take(k_pool, pg, axis=0).transpose(0, 2, 1, 3)  # (B,Hkv,P,D)
        vb = jnp.take(v_pool, pg, axis=0).transpose(0, 2, 1, 3)
        kb = jnp.repeat(kb, group, axis=1)                 # (B,H,P,D)
        vb = jnp.repeat(vb, group, axis=1)
        s = jax.lax.dot_general(
            q_i8, kb, (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.int32)              # (B,H,Sq,P)
        kpos = k_i * psize + jnp.arange(psize, dtype=jnp.int32)
        live = kpos[None, None, None, :] <= qpos[:, None, :, None]
        s = jnp.where(live, s, s - qs.MASK_OFFSET)
        lm = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, lm)
        idx = jnp.clip(fxp.rescale(m_new - s, M_idx, shift_idx, out_bits=9),
                       0, LUT_SIZE - 1)
        num = jnp.take(lut32, idx)                         # Q0.7 numerators
        den_b = jnp.sum(num, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            num.astype(jnp.int8), vb, (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.int32)              # (B,H,Sq,D)
        f = jnp.exp((m - m_new).astype(jnp.float32) * inv)
        f = jnp.where(m == neg_init, 0.0, f)
        den = den * f + den_b.astype(jnp.float32)
        acc = acc * f + pv.astype(jnp.float32)
        m = m_new
    den = jnp.maximum(den, 1.0)
    o = acc / den * out_scale
    return jnp.clip(jnp.round(o), -127, 127).astype(jnp.int8)


def paged_decode_qattention_q4_ref(q_i8, k_pool_u8, v_pool_u8, k_scale,
                                   v_scale, block_tables, lengths, M_idx,
                                   shift_idx, lut, inv_s_logit, out_scale):
    """Oracle for the int4-packed paged decode kernel.

    Dequantizes the whole packed pool with the shared packing helpers
    (``clip(round(c4 * scale), -127, 127)`` — the exact formula the kernel
    fuses per tile) and delegates to the int8 block-online oracle.  This is
    an exact identity with the kernel's in-VMEM dequant: every page the
    kernel touches dequantizes to the same int8 codes this full view holds,
    and pages it never reads (dead blocks re-address already-live pages)
    contribute nothing either way."""
    k_pool = packing.dequantize_kv_pool(k_pool_u8, k_scale)
    v_pool = packing.dequantize_kv_pool(v_pool_u8, v_scale)
    return paged_decode_qattention_ref(q_i8, k_pool, v_pool, block_tables,
                                       lengths, M_idx, shift_idx, lut,
                                       inv_s_logit, out_scale)


def paged_prefill_qattention_q4_ref(q_i8, k_pool_u8, v_pool_u8, k_scale,
                                    v_scale, block_tables, pos0, M_idx,
                                    shift_idx, lut, inv_s_logit, out_scale):
    """Oracle for the int4-packed paged prefill kernel (see the decode q4
    oracle for why whole-pool dequant + int8 oracle is bit-exact vs the
    kernel's fused per-tile dequant)."""
    k_pool = packing.dequantize_kv_pool(k_pool_u8, k_scale)
    v_pool = packing.dequantize_kv_pool(v_pool_u8, v_scale)
    return paged_prefill_qattention_ref(q_i8, k_pool, v_pool, block_tables,
                                        pos0, M_idx, shift_idx, lut,
                                        inv_s_logit, out_scale)


def make_exp_lut_q7():
    """Q0.7 exp table for the attention kernels (max code 127, fits int8)."""
    import numpy as np

    from repro.core.qsoftmax import LUT_DELTA, LUT_SIZE

    i = np.arange(LUT_SIZE, dtype=np.float64)
    vals = np.round(np.exp(-i * LUT_DELTA) * 127.0).astype(np.int32)
    vals[-1] = 0
    return vals
