"""Per-shape tile-size selection for the attention kernels.

``benchmarks/roofline.py`` and ``benchmarks/hlo_cost.py`` can price a kernel
but fed no kernel decisions until now: the decode kernel always ran
``bkv=512`` and the paged prefill kernel ``bq=128`` regardless of batch
size, page size, head geometry, or KV bit width.  This module closes the
loop with a tiny roofline-derived cost table:

* ``decode_bkv(...)``  — KV tile length for the contiguous decode kernel.
* ``prefill_bq(...)``  — q-block length for the paged prefill kernel.

Selections are cached per shape key, overridable by environment
(``REPRO_DECODE_BKV`` / ``REPRO_PREFILL_BQ`` pin a value,
``REPRO_AUTOTUNE=off`` restores the legacy fixed defaults), and — because
the paged kernels' dead-block clamping makes their outputs tile-size
independent (see the kernel docstrings) — NEVER change numerics: autotune
moves DMA/grid overhead around, not bits.

The cost model mirrors ``benchmarks/roofline.py``'s v4-lite ceilings.  A
grid step costs ``max(tile_bytes / HBM_BW, tile_flops / PEAK_INT8)`` plus a
fixed per-step overhead (DMA issue + grid bookkeeping); fewer, larger steps
amortize the overhead until the double-buffered tiles overflow the VMEM
budget.  For prefill, every KV page is streamed once per (head, q-block),
so the KV traffic itself scales with ``ceil(sq / bq)`` — the dominant term
for long chains at big batch.

``REPRO_AUTOTUNE=measure`` is the opt-in measured mode: instead of pricing
candidates with the cost table, ``decode_bkv``/``prefill_bq`` build
synthetic int8 inputs for the exact shape being asked about, race every
candidate tile through the live kernel on the real backend
(``measure_best``), and cache the per-shape winner under the same
key/override discipline.  The env pins still take precedence and the
analytic table remains the default: measured mode pays one compile+run
per candidate per shape at first touch (a deliberate compile storm), which
is right for benchmarks pinning a deployment shape and wrong for cold
serving starts.  Shapes the measured path cannot race (an int4 contiguous
decode has no kernel; synthetic pools over the memory guard) fall back to
the roofline pick.  Either way numerics never move — tile size only
relocates DMA/grid overhead.
"""
from __future__ import annotations

import os

# v4-lite ceilings — shared with benchmarks/roofline.py and the analysis
# lane's VMEM lint via kernels/hw_constants (drift-tested).
from repro.kernels.hw_constants import (  # noqa: F401  (re-exported names)
    HBM_BW,
    PEAK_INT8_FLOPS,
    STEP_OVERHEAD_S,
    VMEM_BUDGET,
    VMEM_FILL,
)

DECODE_BKV_CANDIDATES = (128, 256, 512, 1024)
# 8/16 exist for the small ragged batches the speculative verify forward
# sends through the paged prefill kernel (sq = spec_k+1); divisor-fitting
# collapses them for ordinary chunk sizes, the roofline model prices them
# out for long chains
PREFILL_BQ_CANDIDATES = (8, 16, 32, 64, 128, 256)

DEFAULT_DECODE_BKV = 512     # legacy fixed defaults (REPRO_AUTOTUNE=off)
DEFAULT_PREFILL_BQ = 128

_cache: dict = {}


def clear_cache() -> None:
    _cache.clear()


def _mode() -> str:
    return os.environ.get("REPRO_AUTOTUNE", "roofline")


def _env_int(name: str):
    v = os.environ.get(name)
    return int(v) if v else None


def _fit(c: int, n: int) -> int:
    """Largest divisor of ``n`` that is <= c (mirrors divisor_tile)."""
    c = min(c, n)
    while n % c:
        c -= 1
    return c


def _kv_bytes(hd: int, kv_bits: int) -> float:
    return hd * (0.5 if kv_bits == 4 else 1.0)


def decode_bkv(smax: int, *, batch_slots: int, hkv: int, hd: int,
               kv_bits: int = 8) -> int:
    """KV tile length for the contiguous decode kernel at this shape."""
    env = _env_int("REPRO_DECODE_BKV")
    if env:
        return _fit(env, smax)
    if _mode() == "off":
        return _fit(DEFAULT_DECODE_BKV, smax)
    key = ("decode_bkv", batch_slots, hkv, hd, smax, kv_bits)
    if _mode() == "measure":
        got = _measured_decode_bkv(("measure",) + key, smax,
                                   batch_slots=batch_slots, hkv=hkv, hd=hd,
                                   kv_bits=kv_bits)
        if got is not None:
            return got                    # else: fall back to the model
    got = _cache.get(key)
    if got is None:
        got = _roofline_pick(
            DECODE_BKV_CANDIDATES, smax,
            tile_bytes=lambda bkv: 2 * bkv * _kv_bytes(hd, kv_bits),
            tile_flops=lambda bkv: 2 * 2 * bkv * hd,       # QK^T + P@V
            steps=lambda bkv: batch_slots * hkv * (smax // bkv),
        )
        _cache[key] = got
    return got


def prefill_bq(sq: int, *, batch_slots: int, page_size: int, hkv: int,
               hd: int, kv_bits: int = 8, n_blocks: int = 1,
               n_heads: int | None = None) -> int:
    """q-block length for the paged prefill kernel at this shape.

    Safe to vary freely: block-level causal skipping makes the kernel
    output bq-independent, so two engines tuned differently still agree
    bit-for-bit.
    """
    env = _env_int("REPRO_PREFILL_BQ")
    if env:
        return _fit(env, sq)
    if _mode() == "off":
        return _fit(DEFAULT_PREFILL_BQ, sq)
    h = n_heads or hkv
    key = ("prefill_bq", batch_slots, page_size, hkv, hd, sq, kv_bits,
           n_blocks, h)
    if _mode() == "measure":
        got = _measured_prefill_bq(("measure",) + key, sq,
                                   batch_slots=batch_slots,
                                   page_size=page_size, hkv=hkv, hd=hd,
                                   kv_bits=kv_bits, n_blocks=n_blocks,
                                   n_heads=h)
        if got is not None:
            return got                    # else: fall back to the model
    got = _cache.get(key)
    if got is None:
        kvb = page_size * _kv_bytes(hd, kv_bits)
        got = _roofline_pick(
            PREFILL_BQ_CANDIDATES, sq,
            # each page streams once per (head, q-block): q tile + KV page
            tile_bytes=lambda bq: bq * hd + 2 * kvb,
            tile_flops=lambda bq: 2 * 2 * bq * page_size * hd,
            steps=lambda bq: batch_slots * h * (sq // bq) * n_blocks,
            extra_vmem=lambda bq: 2 * bq * hd * 4,          # fp32 scratch
        )
        _cache[key] = got
    return got


def _roofline_pick(candidates, n, *, tile_bytes, tile_flops, steps,
                   extra_vmem=lambda c: 0) -> int:
    """Pick the candidate minimizing modeled wall time within VMEM budget."""
    best, best_t = None, None
    for raw in candidates:
        c = _fit(raw, n)
        # double-buffered in/out tiles must fit the fill fraction of VMEM
        if 2 * tile_bytes(c) + extra_vmem(c) > VMEM_BUDGET * VMEM_FILL:
            continue
        t = steps(c) * (STEP_OVERHEAD_S +
                        max(tile_bytes(c) / HBM_BW,
                            tile_flops(c) / PEAK_INT8_FLOPS))
        if best_t is None or t < best_t or (t == best_t and c > best):
            best, best_t = c, t
    if best is None:                      # every candidate overflowed VMEM
        best = _fit(candidates[0], n)
    return best


def measure_best(candidates, timer, *, key=None):
    """Measured mode core: time ``timer(candidate)`` (seconds) over the
    candidate set and cache the argmin under ``key``.  Drives the
    ``REPRO_AUTOTUNE=measure`` paths below and is usable directly by
    benchmarks; returns the winning candidate."""
    if key is not None and key in _cache:
        return _cache[key]
    best, best_t = None, None
    for c in candidates:
        t = timer(c)
        if best_t is None or t < best_t:
            best, best_t = c, t
    if key is not None:
        _cache[key] = best
    return best


# --- REPRO_AUTOTUNE=measure: race candidates through the live kernels ----

MEASURE_REPS = 3                 # timed reps per candidate (after 1 warmup)
MEASURE_BYTES_CAP = 2 << 30      # skip measuring shapes needing > 2 GiB


def _timed_call(fn, reps=MEASURE_REPS) -> float:
    """Mean wall seconds per call; one untimed call first eats the
    compile + warmup."""
    import time

    import jax
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def _attn_quant_meta():
    """Plausible softmax requant metadata for synthetic timing inputs (the
    values move bits, never runtime)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fixedpoint as fxp
    from repro.core import qsoftmax as qs
    from repro.kernels import ref
    s_logit = 1.0 / (0.05 * np.sqrt(64))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    return (jnp.int32(M), jnp.int32(sh), jnp.asarray(ref.make_exp_lut_q7()),
            jnp.float32(1.0 / s_logit), jnp.float32(1.0))


def _measured_decode_bkv(key, smax, *, batch_slots, hkv, hd, kv_bits):
    """Race DECODE_BKV_CANDIDATES through the contiguous decode kernel on
    synthetic int8 inputs at this exact shape.  Returns None (-> roofline
    fallback) for shapes with no raceable kernel (int4 contiguous decode
    does not exist) or over the memory guard."""
    if key in _cache:
        return _cache[key]
    if kv_bits != 8 or 2 * batch_slots * smax * hkv * hd > MEASURE_BYTES_CAP:
        return None
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-64, 65, (batch_slots, hkv, 1, hd)),
                    jnp.int8)
    k = jnp.asarray(rng.integers(-64, 65, (batch_slots, smax, hkv, hd)),
                    jnp.int8)
    v = jnp.asarray(rng.integers(-64, 65, (batch_slots, smax, hkv, hd)),
                    jnp.int8)
    lengths = jnp.full((batch_slots,), smax, jnp.int32)
    meta = _attn_quant_meta()
    cands = tuple(dict.fromkeys(_fit(c, smax)
                                for c in DECODE_BKV_CANDIDATES))
    return measure_best(
        cands,
        lambda c: _timed_call(
            lambda: ops.decode_attention_q(q, k, v, lengths, *meta, bkv=c)),
        key=key)


def _measured_prefill_bq(key, sq, *, batch_slots, page_size, hkv, hd,
                         kv_bits, n_blocks, n_heads):
    """Race PREFILL_BQ_CANDIDATES through the paged prefill kernel (int8 or
    int4-packed to match ``kv_bits``) on a synthetic full-chain workload:
    every slot's block table maps ``n_blocks`` distinct pages and the chunk
    sits at the chain's end, so each candidate pays the worst-case KV
    restream the roofline model prices."""
    if key in _cache:
        return _cache[key]
    n_pages = batch_slots * n_blocks + 1
    kvb = int(page_size * _kv_bytes(hd, kv_bits))
    if 2 * n_pages * kvb * hkv > MEASURE_BYTES_CAP:
        return None
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-64, 65, (batch_slots, n_heads, sq, hd)),
                    jnp.int8)
    btab = jnp.asarray(
        1 + np.arange(batch_slots * n_blocks, dtype=np.int32)
        .reshape(batch_slots, n_blocks))
    pos0 = jnp.full((batch_slots,), n_blocks * page_size - sq, jnp.int32)
    meta = _attn_quant_meta()
    if kv_bits == 4:
        pool = lambda: jnp.asarray(
            rng.integers(0, 256, (n_pages, page_size, hkv, hd // 2)),
            jnp.uint8)
        kp, vp = pool(), pool()
        scale = jnp.full((n_pages,), 0.05, jnp.float32)
        run = lambda c: ops.paged_prefill_attention_q4(
            q, kp, vp, scale, scale, btab, pos0, *meta, bq=c)
    else:
        pool = lambda: jnp.asarray(
            rng.integers(-64, 65, (n_pages, page_size, hkv, hd)), jnp.int8)
        kp, vp = pool(), pool()
        run = lambda c: ops.paged_prefill_attention_q(
            q, kp, vp, btab, pos0, *meta, bq=c)
    cands = tuple(dict.fromkeys(_fit(c, sq) for c in PREFILL_BQ_CANDIDATES))
    return measure_best(cands, lambda c: _timed_call(lambda: run(c)),
                        key=key)
