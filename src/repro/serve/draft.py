"""Draft sources for speculative decoding (draft-then-verify).

The continuous engine's decode loop is one token per tick per slot — a
sequential chain of memory-bound GEMVs.  The chunk-prefill forward
already scores many positions in one pass, which is exactly the verifier
a draft-then-verify scheme needs.  A :class:`DraftSource` proposes up to
``k`` candidate next tokens per decode slot per tick; the engine runs ONE
multi-row verify forward over ``[last_token, d_1, ..., d_n]`` per slot and
greedily accepts the longest prefix whose proposals match the argmax
chain.  Because acceptance is exact argmax matching, speculative decoding
is bit-identical to plain greedy decode — the repo's entire identity test
matrix doubles as a speculative correctness oracle.

Sources
-------

* :class:`PromptLookupDraft` — model-free prompt-lookup decoding (n-gram
  continuation): find the longest suffix of the context that reoccurs
  earlier in the context, propose the tokens that followed the earlier
  occurrence.  Free to evaluate, surprisingly effective on repetitive
  text (code, summaries with copied spans, greedy cycles).
* :class:`SequenceDraft` — replay proposals from known full sequences
  (prompt + continuation).  A controllable oracle: tests use it to force
  full acceptance across page boundaries / preemption, and to measure
  verifier mechanics at a pinned acceptance rate.

A smaller folded integer model from the config zoo slots in here later:
it only has to implement ``propose`` (see ROADMAP).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


class DraftSource:
    """Interface: propose up to ``k`` next tokens for one slot's context.

    ``context`` is the slot's full token history (prompt + emitted so
    far) as a 1-D int array; the return value is a list of 0..k proposed
    next tokens.  Returning fewer than ``k`` (including none) is always
    legal — the engine verifies whatever is proposed and falls back to
    plain decode for slots with no proposals.

    ``propose`` must be a pure function of ``context`` — the engine may
    call it speculatively and discard the result (e.g. when a slot is
    preempted before its verify forward runs).
    """

    def propose(self, context: np.ndarray, k: int) -> List[int]:
        raise NotImplementedError


class PromptLookupDraft(DraftSource):
    """Prompt-lookup decoding: n-gram continuation from the context.

    Searches for the longest suffix n-gram of the context (lengths
    ``max_ngram`` down to ``min_ngram``) that also occurs earlier in the
    context; proposes up to ``k`` tokens following the most recent
    earlier occurrence.  No model, no state — pure array search.
    """

    def __init__(self, min_ngram: int = 1, max_ngram: int = 3):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"{min_ngram}..{max_ngram}")
        self.min_ngram = min_ngram
        self.max_ngram = max_ngram

    def propose(self, context: np.ndarray, k: int) -> List[int]:
        ctx = np.asarray(context).ravel()
        n = len(ctx)
        if k <= 0 or n < self.min_ngram + 1:
            return []
        for ng in range(min(self.max_ngram, n - 1), self.min_ngram - 1, -1):
            suffix = ctx[n - ng:]
            # candidate start positions of earlier occurrences (windows
            # strictly before the suffix itself), most recent first
            starts = np.flatnonzero(ctx[:n - ng] == suffix[0])
            for s in starts[::-1]:
                if np.array_equal(ctx[s:s + ng], suffix):
                    cont = ctx[s + ng:s + ng + k]
                    if len(cont):
                        return [int(t) for t in cont]
        return []


class SequenceDraft(DraftSource):
    """Oracle/replay draft: propose the continuation of a known sequence.

    Holds full token sequences (prompt + continuation).  ``propose``
    finds a sequence whose prefix equals the context and returns its next
    ``k`` tokens.  With truth sequences from a plain-decode run this
    yields 100% acceptance — the controlled setting for exercising commit
    paths (page-boundary growth, preemption mid-verify) and for measuring
    verify-forward throughput independent of draft quality.
    """

    def __init__(self, sequences: Sequence[Sequence[int]] = ()):
        self._seqs = [np.asarray(s, dtype=np.int64).ravel()
                      for s in sequences]

    def add(self, sequence: Sequence[int]):
        self._seqs.append(np.asarray(sequence, dtype=np.int64).ravel())

    def propose(self, context: np.ndarray, k: int) -> List[int]:
        ctx = np.asarray(context, dtype=np.int64).ravel()
        n = len(ctx)
        if k <= 0:
            return []
        for seq in self._seqs:
            if len(seq) > n and np.array_equal(seq[:n], ctx):
                return [int(t) for t in seq[n:n + k]]
        return []


_NAMED = {
    "prompt_lookup": PromptLookupDraft,
}


def make_draft_source(spec) -> DraftSource:
    """Resolve an ``EngineConfig.draft`` value: a :class:`DraftSource`
    instance passes through; a registered name ("prompt_lookup")
    constructs the default instance."""
    if isinstance(spec, DraftSource):
        return spec
    if isinstance(spec, str):
        try:
            return _NAMED[spec]()
        except KeyError:
            raise ValueError(
                f"unknown draft source {spec!r}; known: "
                f"{sorted(_NAMED)} or a DraftSource instance") from None
    raise TypeError(
        f"draft must be a DraftSource or one of {sorted(_NAMED)}, "
        f"got {type(spec).__name__}")
