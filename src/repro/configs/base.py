"""Model configuration system.

One ``ModelConfig`` describes any architecture in the zoo; per-arch files in
this package instantiate the exact published dimensions and register them.
``--arch <id>`` anywhere in the launchers resolves through ``get_config``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.policy import QuantPolicy, POLICY_FQ


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|vlm|audio|encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention flavour
    causal: bool = True
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0      # fraction of head_dim rotated (stablelm: 0.25)
    qk_norm: bool = False            # qwen3
    sliding_window: Optional[int] = None  # mixtral SWA
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE (t,h,w)
    learned_pos: bool = False        # BERT
    max_position: int = 1 << 20

    # norm / mlp
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_period: int = 1              # MoE on layers where i % period == offset
    moe_offset: int = 0

    # layer pattern for hybrid/ssm stacks; None -> all-attention
    # e.g. jamba: ('m','m','m','m','a','m','m','m'); xlstm: ('s','m7',...)
    block_pattern: Optional[Tuple[str, ...]] = None
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # modality frontend (stub per task spec)
    frontend: str = "none"           # none | vision_stub | audio_codebooks
    n_codebooks: int = 4             # musicgen
    n_lm_heads: int = 1              # musicgen: one head per codebook

    tied_embeddings: bool = False
    param_dtype: str = "float32"     # float32 | bfloat16
    quant: QuantPolicy = POLICY_FQ
    remat: bool = True               # checkpoint each super-block in training
    remat_groups: int = 0            # >1: two-level (sqrt-L) checkpointing —
                                     # saves residuals only at group
                                     # boundaries; ~(g + L/g)/L of the
                                     # activation memory for ~+1 forward

    # --- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or ("a",)

    @property
    def n_reps(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    def is_moe_layer(self, global_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return global_idx % self.moe_period == self.moe_offset

    @property
    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    @property
    def n_params_estimate(self) -> int:
        """Rough dense-equivalent parameter count (for roofline MODEL_FLOPS)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        mlp_dense = (3 if self.act == "swiglu" else 2) * d * ff
        total = 0
        for i in range(self.n_layers):
            blk = self.pattern[i % len(self.pattern)]
            if blk == "a":
                total += attn
            elif blk == "m":  # mamba block
                d_in = self.mamba_expand * d
                total += 2 * d * d_in + d_in * d + d_in * (2 * self.mamba_d_state + 2)
            elif blk == "x":  # mLSTM block: q,k,v,o + output gate
                total += 5 * d * d
            elif blk == "s":  # sLSTM block: z,i,f,o + recurrent + out
                total += 6 * d * d
            if blk in ("a", "m"):
                if self.is_moe_layer(i):
                    total += 3 * self.n_experts * d * self.moe_d_ff \
                        + 3 * self.n_shared_experts * d * self.moe_d_ff
                elif ff:
                    total += mlp_dense
        total += self.vocab_size * d * (1 if self.tied_embeddings else 2)
        return total

    def active_params_estimate(self) -> int:
        """Active (per-token) params — MoE counts only routed top-k experts."""
        if self.n_experts == 0:
            return self.n_params_estimate
        full = self.n_params_estimate
        moe_layers = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        all_exp = 3 * self.n_experts * self.d_model * self.moe_d_ff * moe_layers
        act_exp = 3 * self.top_k * self.d_model * self.moe_d_ff * moe_layers
        return full - all_exp + act_exp


# --- registry ----------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch registration)

    cfg = _REGISTRY[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_configs():
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


# --- input shapes (the assigned shape set) -----------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
    "paper_128": ShapeConfig("paper_128", 128, 1, "prefill"),  # the paper's op point
}

# archs for which long_500k is runnable (sub-quadratic attention):
# mixtral (SWA ring buffer), jamba (hybrid), xlstm (ssm).  Pure full-attention
# archs skip it — see DESIGN.md §4.
LONG_CONTEXT_OK = {"mixtral-8x22b", "jamba-1.5-large-398b", "xlstm-1.3b"}


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
