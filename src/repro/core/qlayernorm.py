"""Fully-quantized LayerNorm — paper §III-B "LN Core" (3-stage integer SIMD).

Stage 1: int32 row sum            -> mean code (rounded integer divide)
Stage 2: centered sum of squares  -> variance code
Stage 3: integer Newton rsqrt (Q14), multiply by int8 gamma, add aligned
         beta, fixed-point requantize to the 8-bit output grid.

Strictly int32 arithmetic (TPU-native; no 64-bit anywhere):
  x real = x_I / s_x, |x_I| <= 127 -> |c| <= 254, c^2 <= 2^16,
  sum-of-squares <= 2^16 * N  (N <= 16384 => fits int32),
  rstd  = fixed_rsqrt(var) : Q14 code of 1/sqrt(var_codes)  in [64, 2^14]
  n     = c * rstd          : Q14 code of (x-mu)/sigma, <= 254*2^14 = 2^22
  acc   = n * gamma_I + beta_aligned : <= 2^22 * 127 ~ 2^29
  y_I   = requant(acc, M_out, sh_out),  M_out*2^-sh ~ s_y / (2^14 * s_g)

RMSNorm (no mean subtraction, no beta) is the same pipeline with stage 1
skipped — used by the llama-family archs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core import quant as q

FRAC = fxp.RSQRT_FRAC  # Q14 normalized-value domain


@dataclasses.dataclass(frozen=True)
class QLNParams:
    """Folded integer parameters of one quantized LayerNorm."""

    gamma_i: jax.Array       # int8 codes, scale s_g
    beta_aligned: jax.Array  # int32, pre-aligned into the n*gamma accumulator
    M_out: jax.Array         # Q15 fixed-point output multiplier
    shift_out: jax.Array
    subtract_mean: bool = True


def fold_layernorm(
    gamma: np.ndarray, beta: np.ndarray | None, s_y: float, subtract_mean: bool = True
) -> QLNParams:
    """Quantize gamma/beta to 8-bit (paper: 'parameters of layer normalization
    to 8-bit fixed-point values') and fold all scales into integer constants."""
    gamma = np.asarray(gamma, np.float64)
    s_g = float(q.qmax(8)) / max(float(np.max(np.abs(gamma))), 1e-8)
    gamma_i = np.clip(np.round(gamma * s_g), -127, 127).astype(np.int8)
    acc_scale = float(1 << FRAC) * s_g  # accumulator codes per real unit
    if beta is not None:
        # beta is quantized to 8-bit on its own grid, then re-aligned into the
        # accumulator domain (exactly what the FPGA does with a constant add)
        s_b = float(q.qmax(8)) / max(float(np.max(np.abs(beta))), 1e-8)
        beta_i = np.clip(np.round(np.asarray(beta, np.float64) * s_b), -127, 127)
        beta_aligned = np.round(beta_i / s_b * acc_scale).astype(np.int64)
        beta_aligned = np.clip(beta_aligned, -(2**30), 2**30).astype(np.int32)
    else:
        beta_aligned = np.zeros_like(gamma_i, dtype=np.int32)
    M, sh = fxp.quantize_multiplier(s_y / acc_scale)
    return QLNParams(
        gamma_i=jnp.asarray(gamma_i),
        beta_aligned=jnp.asarray(beta_aligned),
        M_out=jnp.asarray(M, jnp.int32),
        shift_out=jnp.asarray(sh, jnp.int32),
        subtract_mean=subtract_mean,
    )


def quant_layernorm(x_int: jax.Array, p: QLNParams, eps_codes: int = 1) -> jax.Array:
    """Reference integer LayerNorm.  x_int: int8 codes (..., N) with scale
    s_x; returns int8 codes on the folded output grid.

    Mirrors the 3-stage hardware pipeline; variance is the biased (1/N)
    estimator like the paper's LN core.  N must be <= 16384 (int32 budget).
    """
    xi = x_int.astype(jnp.int32)
    n = xi.shape[-1]
    assert n <= 16384, "int32 sum-of-squares budget exceeded"
    if p.subtract_mean:
        s = jnp.sum(xi, axis=-1, keepdims=True)
        mean = _rounded_div(s, n)
        c = xi - mean
    else:
        c = xi
    ss = jnp.sum(c * c, axis=-1, keepdims=True)
    var = jnp.maximum(_rounded_div(ss, n), eps_codes)
    # full-precision Q15 mantissa + exponent; shift AFTER the c* multiply so
    # no precision is lost for large-variance rows
    y_m, s_e = fxp.rsqrt_mantexp(var)
    n_q = fxp._rshift_round(c * y_m, s_e + 1)   # Q14 of (x-mu)/sigma
    acc = n_q * p.gamma_i.astype(jnp.int32) + p.beta_aligned
    y = fxp.rescale(acc, p.M_out, p.shift_out)
    return jnp.clip(y, -127, 127).astype(jnp.int8)


def _rounded_div(a: jax.Array, n: int) -> jax.Array:
    half = n // 2
    return jnp.where(a >= 0, (a + half) // n, -((-a + half) // n))
