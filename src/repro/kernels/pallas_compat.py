"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
toolchain pin in CI (and the baked container image) may sit on either side of
the rename.  Kernels import ``CompilerParams`` from here so they compile
against both.

This shim was written against jax 0.4.37 (the ``TPUCompilerParams`` side),
which is the floor requirements-dev.txt pins — move that pin if a future
Pallas rename forces a third branch here.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def divisor_tile(cap: int, n: int) -> int:
    """Largest tile <= ``cap`` that divides ``n`` exactly (Pallas grids
    must tile their axis without remainder).  Shared by the attention
    kernels' block-size fallbacks."""
    cap = min(cap, n)
    while n % cap:
        cap -= 1
    return cap
