"""Asyncio serving frontend over the event-driven engine protocol.

:class:`AsyncServer` wraps any *core* speaking the event protocol — a
single :class:`~repro.serve.engine.Engine` or a
:class:`~repro.serve.router.ReplicaRouter` — and exposes per-request
async streams:

* ``await server.submit(req)`` returns a :class:`StreamHandle`;
  ``async for tok in handle`` yields tokens as the engine emits them.
* Backpressure is two-layered: a semaphore bounds requests in flight
  through the server (``await``-ing submitters is the backpressure), and
  the router's bounded queue underneath turns hard overload into
  :class:`~repro.serve.router.RouterBusy` rejections.
* ``handle.cancel()`` and per-request wall-clock ``timeout`` both route
  through ``core.cancel()`` — the same state machine the engine uses for
  deadline sheds, so a timed-out request frees its pages via the
  ordinary eviction path and its stream ends with a terminal event.

The server never threads or forks: ``serve_forever`` drives
``core.poll()`` inline on the event loop, one tick per iteration, and
fans events out to stream queues.  Because the asyncio layer only decides
*when* to call the same ``submit``/``poll``/``cancel`` the synchronous
bench calls, tokens cannot diverge between the two drivers — scheduling
changes latency, never output (the engine's tick loop is deterministic in
submission order).

No external dependencies: plain ``asyncio`` from the standard library.
"""
from __future__ import annotations

import asyncio
import contextlib
from typing import Dict, List, Optional

import numpy as np

from repro.serve.engine import Request, TokenEvent


class StreamHandle:
    """One request's live output stream.  Async-iterate for tokens; after
    exhaustion ``result()`` / the request's own ``result()`` give the full
    output or raise for cancelled/failed exits."""

    def __init__(self, server: "AsyncServer", rid: int, request: Request):
        self.rid = rid
        self.request = request
        self._server = server
        self._queue: asyncio.Queue = asyncio.Queue()
        self._done = False
        self._timeout_handle: Optional[asyncio.TimerHandle] = None

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        if self._done:
            raise StopAsyncIteration
        ev: TokenEvent = await self._queue.get()
        if ev.final:
            self._done = True
        if ev.token is None:        # token-less terminal (cancel/shed/fail)
            raise StopAsyncIteration
        return ev.token

    async def tokens(self) -> List[int]:
        """Drain the stream to completion and return every token."""
        return [t async for t in self]

    def cancel(self) -> bool:
        """Client-side cancellation; the stream still ends with its
        terminal event (delivered by the poll loop)."""
        return self._server._cancel(self)

    def result(self) -> np.ndarray:
        """Terminal-state accessor (see ``Request.result``)."""
        return self.request.result()


class AsyncServer:
    """Drive an event-protocol core from an asyncio event loop.

    Parameters
    ----------
    core:
        ``Engine`` or ``ReplicaRouter`` (anything with ``submit`` /
        ``cancel`` / ``poll`` / ``has_work``).
    max_inflight:
        Semaphore bound on requests admitted into the core at once;
        further ``submit`` callers await (backpressure).
    idle_sleep:
        Event-loop sleep while the core has no work (seconds).
    """

    def __init__(self, core, *, max_inflight: int = 64,
                 idle_sleep: float = 0.001):
        self.core = core
        self.idle_sleep = idle_sleep
        self._sem = asyncio.Semaphore(max_inflight)
        self._streams: Dict[int, StreamHandle] = {}
        self._stopped = False

    async def submit(self, request: Request,
                     timeout: Optional[float] = None) -> StreamHandle:
        """Admit a request (awaiting the in-flight semaphore) and return
        its stream.  ``timeout`` arms a wall-clock timer that cancels the
        request through the core; tick-based ``deadline_tick`` on the
        request itself additionally bounds time-to-first-schedule
        deterministically.  Raises ``RouterBusy`` (after releasing the
        slot) when the core's bounded queue rejects the submission."""
        await self._sem.acquire()
        try:
            rid = self.core.submit(request)
        except BaseException:
            self._sem.release()
            raise
        handle = StreamHandle(self, rid, request)
        self._streams[rid] = handle
        if timeout is not None:
            loop = asyncio.get_running_loop()
            handle._timeout_handle = loop.call_later(
                timeout, self._cancel, handle)
        return handle

    def _cancel(self, handle: StreamHandle) -> bool:
        if handle.rid not in self._streams:
            return False                   # already terminal
        return self.core.cancel(handle.rid)

    def _settle(self, handle: StreamHandle):
        self._streams.pop(handle.rid, None)
        if handle._timeout_handle is not None:
            handle._timeout_handle.cancel()
            handle._timeout_handle = None
        self._sem.release()

    async def serve_forever(self):
        """Poll loop: one core tick per iteration while there is work,
        yielding to the loop between ticks so submitters and consumers
        interleave; sleeps when idle.  Run as a background task; cancel
        the task (or ``stop()``) to shut down."""
        with contextlib.suppress(asyncio.CancelledError):
            while not self._stopped:
                if self.core.has_work:
                    for ev in self.core.poll():
                        handle = self._streams.get(ev.rid)
                        if handle is None:
                            continue       # not submitted through us
                        handle._queue.put_nowait(ev)
                        if ev.final:
                            self._settle(handle)
                    await asyncio.sleep(0)
                else:
                    await asyncio.sleep(self.idle_sleep)

    def stop(self):
        self._stopped = True

    async def drain(self):
        """Tick until the core has no work left (test/bench helper that
        avoids a background task entirely)."""
        while self.core.has_work:
            for ev in self.core.poll():
                handle = self._streams.get(ev.rid)
                if handle is None:
                    continue
                handle._queue.put_nowait(ev)
                if ev.final:
                    self._settle(handle)
            await asyncio.sleep(0)
