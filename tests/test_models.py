"""Per-architecture smoke tests (reduced configs) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, smoke_config
from repro.models import bert as B
from repro.models import fold as F
from repro.models import serve_int as S
from repro.models import transformer as T
from repro.models import xlstm as Xl

KEY = jax.random.PRNGKey(0)

ALL_ARCHS = ["qwen2-moe-a2.7b", "mixtral-8x22b", "llama3-405b", "qwen3-4b",
             "yi-6b", "stablelm-1.6b", "jamba-1.5-large-398b", "xlstm-1.3b",
             "qwen2-vl-2b", "musicgen-medium"]


def _tokens(cfg, b=2, s=16):
    if cfg.frontend == "audio_codebooks":
        return jax.random.randint(KEY, (b, cfg.n_codebooks, s), 0,
                                  cfg.vocab_size)
    return jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    toks = _tokens(cfg)
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["extra_embeds"] = jax.random.normal(KEY, (2, 4, cfg.d_model))
        kw["pos3"] = jnp.broadcast_to(
            jnp.arange(20, dtype=jnp.int32)[None, :, None], (2, 20, 3))
    logits, obs, aux = T.forward(cfg, params, amax, toks, **kw)
    assert jnp.isfinite(logits).all()
    assert logits.shape[-1] == cfg.vocab_size
    # every amax site observed positive
    assert all(float(jnp.min(v)) > 0 for v in jax.tree.leaves(obs))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_registered_dims(arch):
    cfg = get_config(arch)
    assert cfg.n_layers % len(cfg.pattern) == 0
    assert cfg.d_model > 0 and cfg.vocab_size > 0
    if cfg.n_experts:
        assert cfg.top_k > 0
    # params estimate in a plausible range for the advertised size
    n = cfg.n_params_estimate
    expect = {"llama3-405b": 405e9, "mixtral-8x22b": 141e9,
              "jamba-1.5-large-398b": 398e9, "yi-6b": 6e9,
              "qwen3-4b": 4e9, "stablelm-1.6b": 1.6e9,
              "xlstm-1.3b": 1.3e9, "qwen2-vl-2b": 2e9,
              "qwen2-moe-a2.7b": 14e9, "musicgen-medium": 1.5e9}[arch]
    assert 0.4 * expect < n < 2.2 * expect, (arch, n, expect)


@pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x22b", "musicgen-medium"])
def test_train_step_decreases_loss(arch):
    from repro.optim.adamw import AdamWConfig
    from repro.train import steps as St

    cfg = smoke_config(arch)
    opt_cfg = AdamWConfig(lr=3e-3)
    state = St.init_train_state(cfg, KEY, opt_cfg)
    step = jax.jit(St.make_train_step(cfg, opt_cfg))
    batch = {"tokens": _tokens(cfg, b=4, s=32)}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert int(state.step) == 8


def test_grad_accum_matches_single_batch_direction():
    from repro.optim.adamw import AdamWConfig
    from repro.train import steps as St

    cfg = smoke_config("yi-6b")
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = {"tokens": _tokens(cfg, b=4, s=32)}
    s1 = St.init_train_state(cfg, KEY, opt_cfg)
    s2 = St.init_train_state(cfg, KEY, opt_cfg)
    st1, m1 = jax.jit(St.make_train_step(cfg, opt_cfg))(s1, batch)
    st2, m2 = jax.jit(St.make_train_step(cfg, opt_cfg, accum_steps=2))(s2, batch)
    # same data, same params -> same loss and near-identical update
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        st1.params, st2.params)
    assert max(jax.tree.leaves(d)) < 5e-3


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-6b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b", "mixtral-8x22b"])
def test_integer_serving_decode_matches_prefill(arch):
    cfg = smoke_config(arch, n_layers=len(smoke_config(arch).pattern))
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    toks = _tokens(cfg, b=2, s=8)
    _, obs, _ = T.forward(cfg, params, amax, toks)
    folded = F.fold_params(cfg, params, obs)
    cache = S.init_cache(cfg, 2, 32)
    outs = []
    for t in range(8):
        lg, cache = S.serve_forward(cfg, folded, toks[:, t:t + 1], cache=cache,
                                    pos_offset=jnp.int32(t), mode="decode")
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    pre, _ = S.serve_forward(cfg, folded, toks, mode="prefill")
    p = jax.nn.softmax(pre, -1)
    kl = jnp.mean(jnp.sum(p * (jax.nn.log_softmax(pre, -1)
                               - jax.nn.log_softmax(dec, -1)), -1))
    assert float(kl) < 0.01
    assert jnp.isfinite(dec).all()


def test_qat_vs_integer_serving_agreement():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    toks = _tokens(cfg, b=2, s=16)
    _, obs, _ = T.forward(cfg, params, amax, toks)
    folded = F.fold_params(cfg, params, obs)
    lg_f, _, _ = T.forward(cfg, params, obs, toks)
    lg_i, _ = S.serve_forward(cfg, folded, toks, mode="prefill")
    pf = jax.nn.softmax(lg_f, -1)
    kl = jnp.mean(jnp.sum(pf * (jax.nn.log_softmax(lg_f, -1)
                                - jax.nn.log_softmax(lg_i, -1)), -1))
    assert float(kl) < 0.02   # QAT graph ~= integer graph


def test_mlstm_parallel_equals_recurrent():
    """Dual-form property: the chunk-parallel (training) mLSTM must equal the
    step recurrence used at decode time."""
    cfg = smoke_config("xlstm-1.3b")
    d = cfg.d_model
    k1, k2 = jax.random.split(KEY)
    p = T.init_slot_params(cfg, "mlstm", "none", k1)["mixer"]
    amax = {s: jnp.zeros(()) for s in Xl.MLSTM_SITES}
    pol = dataclasses.replace(cfg.quant, quantize_wa=False)
    x = jax.random.normal(k2, (2, 12, d)) * 0.5
    y_par, _, _ = Xl.mlstm_qat(x, p, amax, pol, cfg, state=None)
    dh = d // cfg.n_heads
    state = {"C": jnp.zeros((2, cfg.n_heads, dh, dh)),
             "n": jnp.zeros((2, cfg.n_heads, dh)),
             "m": jnp.full((2, cfg.n_heads), -1e30)}
    ys = []
    for t in range(12):
        y_t, _, state = Xl.mlstm_qat(x[:, t:t + 1], p, amax, pol, cfg,
                                     state=state)
        ys.append(y_t)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=2e-3, rtol=2e-2)


def test_bert_classify_and_train():
    from repro.optim.adamw import AdamWConfig
    from repro.train import steps as St

    cfg = smoke_config("bert-base")
    params = B.init_bert_params(cfg, KEY)
    amax = B.init_bert_amax(cfg)
    toks = jax.random.randint(KEY, (4, 24), 0, cfg.vocab_size)
    mask = jnp.ones((4, 24), bool).at[:, 20:].set(False)
    logits, obs, aux = B.bert_classify(cfg, params, amax, toks, mask)
    assert logits.shape == (4, 2)
    opt_cfg = AdamWConfig(lr=3e-3)
    state = St.TrainState(params, __import__("repro.optim.adamw",
                          fromlist=["init_state"]).init_state(params, opt_cfg),
                          amax, jnp.zeros((), jnp.int32))
    step = jax.jit(St.make_bert_train_step(cfg, opt_cfg))
    labels = jnp.asarray([0, 1, 0, 1])
    losses = []
    for _ in range(6):
        state, m = step(state, {"tokens": toks, "mask": mask,
                                "labels": labels})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_sliding_window_restricts_attention():
    # NOTE: must run quant-free — per-tensor dynamic calibration (batch-max
    # fallback on step 0) legitimately couples every position through the
    # shared activation scale.
    from repro.core.policy import POLICY_FP32

    cfg = smoke_config("mixtral-8x22b", sliding_window=4, n_layers=1,
                       n_experts=0, top_k=0, d_ff=64, quant=POLICY_FP32)
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    toks = _tokens(cfg, b=1, s=12)
    lg1, _, _ = T.forward(cfg, params, amax, toks)
    # changing a token far outside the window must not affect position -1
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    lg2, _, _ = T.forward(cfg, params, amax, toks2)
    np.testing.assert_allclose(np.asarray(lg1[0, -1]), np.asarray(lg2[0, -1]),
                               atol=1e-5)


def test_w8a8_serving_beats_w4a8_fidelity():
    """Q8BERT comparison point: int8 weights via the BIM bit-split path must
    be closer to fp32 than int4 weights."""
    import dataclasses
    from repro.core.policy import POLICY_W8A8

    cfg4 = smoke_config("yi-6b")
    cfg8 = dataclasses.replace(cfg4, quant=POLICY_W8A8)
    toks = _tokens(cfg4, b=2, s=16)
    kls = {}
    for nm, cfg in (("w4", cfg4), ("w8", cfg8)):
        params = T.init_params(cfg, KEY)
        amax = T.init_amax(cfg)
        _, obs, _ = T.forward(cfg, params, amax, toks)
        folded = F.fold_params(cfg, params, obs)
        li, _ = S.serve_forward(cfg, folded, toks, mode="prefill")
        cfgf = dataclasses.replace(
            cfg, quant=dataclasses.replace(
                cfg.quant, quantize_wa=False, quantize_softmax=False,
                quantize_layernorm=False))
        lf, _, _ = T.forward(cfgf, params, amax, toks)
        p = jax.nn.softmax(lf, -1)
        kls[nm] = float(jnp.mean(jnp.sum(
            p * (jax.nn.log_softmax(lf, -1) - jax.nn.log_softmax(li, -1)),
            -1)))
    assert kls["w8"] < kls["w4"]
    assert kls["w4"] < 0.05
