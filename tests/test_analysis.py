"""Static-analysis subsystem: jaxpr auditor, pallas lint, fixtures,
report schema, and the regression-gate integration.

Two directions, both load-bearing: the CURRENT tree must audit clean
(zero violations across the hot graphs of every preset this host can
build), and every intentionally-broken fixture must be flagged with its
stable rule id — a checker that can't fire is indistinguishable from a
clean tree.
"""
import importlib.util
import json
from pathlib import Path

import jax
import pytest

from repro.analysis import fixtures, jaxpr_audit, pallas_lint, report
from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import Engine, EngineConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def folded_cfg():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def _engine(folded_cfg, **kw):
    cfg, folded = folded_cfg
    return Engine(cfg, folded, EngineConfig(
        batch_slots=4, max_len=64, cache_layout="paged", page_size=8, **kw))


# --- the current tree audits clean --------------------------------------

def test_serve_graphs_audit_clean_kv8_spec3(folded_cfg):
    """decode + prefill chunk + verify of the int8 spec-decode engine:
    zero violations, and the auditor actually walked nontrivial graphs."""
    eng = _engine(folded_cfg, kv_bits=8, spec_k=3)
    results = jaxpr_audit.audit_engine(eng)
    assert set(results) == {"decode", "prefill_chunk", "verify"}
    for name, res in results.items():
        assert res.violations == [], f"{name}: {res.violations}"
        assert res.n_eqns > 100
        # the serve path keeps float work off the MXU: any float output
        # must come from elementwise/softmax-carry islands, never a dot
        assert "dot_general" not in res.float_prims, name


def test_serve_graphs_audit_clean_kv4(folded_cfg):
    eng = _engine(folded_cfg, kv_bits=4)
    results = jaxpr_audit.audit_engine(eng)
    assert set(results) == {"decode", "prefill_chunk"}
    for name, res in results.items():
        assert res.violations == [], f"{name}: {res.violations}"
        assert "dot_general" not in res.float_prims, name


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="tp=4 needs 4 host devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=4); the CI analyze lane covers it")
def test_serve_graphs_audit_clean_tp4(folded_cfg):
    eng = _engine(folded_cfg, kv_bits=8, tp=4, spec_k=3)
    for name, res in jaxpr_audit.audit_engine(eng).items():
        assert res.violations == [], f"{name}: {res.violations}"


def test_hbm_bytes_by_dtype_on_decode(folded_cfg):
    """hlo_cost's per-dtype HBM split on a real compiled decode graph:
    integer pool traffic must dominate float activation traffic."""
    from repro.analysis import hlo_cost
    eng = _engine(folded_cfg, kv_bits=8)
    fn, args = eng.hot_graphs()["decode"]
    rep = hlo_cost.analyze(jaxpr_audit.lowered_hlo(fn, args))
    by_dt = rep["hbm_bytes_by_dtype"]
    assert by_dt and all(isinstance(v, (int, float)) for v in by_dt.values())
    int_bytes = sum(v for k, v in by_dt.items() if k.startswith(("s8", "u8")))
    f32_bytes = by_dt.get("f32", 0)
    assert int_bytes > f32_bytes > 0


def test_pallas_lint_clean_on_tree():
    res = pallas_lint.run_all()
    assert res["violations"] == []
    assert {c["check"] for c in res["checks"]} == {
        "idxmap_decode", "idxmap_paged_decode", "idxmap_prefill",
        "vmem_budget", "scalar_prefetch", "shared_body"}
    assert all(c["ok"] for c in res["checks"])


# --- every broken fixture is flagged with its rule id -------------------

def test_fixtures_flag_expected_rules():
    res = fixtures.run_self_test()
    assert res["ok"], {n: r for n, r in res["fixtures"].items()
                       if not r["ok"]}
    # every jaxpr rule and both index-map rules are exercised by name
    exercised = {r["expected_rule"] for r in res["fixtures"].values()}
    assert exercised >= {"INT-DOT-FLOAT", "INT-DOT-ACC", "POOL-FLOAT-CAST",
                         "DONATION", "DONATION-ALIAS", "IDXMAP-RANGE",
                         "IDXMAP-CLAMP"}
    # violations carry a graph location, not just a rule id
    for name, fr in res["fixtures"].items():
        for v in fr["violations"]:
            assert v["rule"] and v["graph"], (name, v)


def test_boundary_registry_covers_blessed_dequants():
    from repro.analysis import boundary
    assert {"dequantize_kv_pool", "_dequant_paged_view"} <= set(
        boundary.REGISTRY)


# --- report schema + baseline ratchet -----------------------------------

def _tiny_report(float_prims=("exp",), skipped=(), preset="kv8_tp1_spec0"):
    res = jaxpr_audit.AuditResult(graph="decode", n_eqns=3)
    res.float_prims = set(float_prims)
    res.op_histogram = {"float32": {p: 1 for p in float_prims}}
    return report.build_report(
        presets={preset: ({"kv_bits": 8, "tp": 1, "spec_k": 0},
                          {"decode": res}, {})},
        skipped=list(skipped),
        pallas={"checks": [], "violations": []},
        jax_version=jax.__version__)


def test_report_schema_round_trip_and_rejections():
    doc = _tiny_report()
    report.validate_report(doc)
    assert doc["violations_total"] == 0

    stale = dict(doc, schema_version=report.ANALYSIS_SCHEMA_VERSION + 1)
    with pytest.raises(report.AnalysisSchemaError, match="schema_version"):
        report.validate_report(stale)
    with pytest.raises(report.AnalysisSchemaError, match="kind"):
        report.validate_report(dict(doc, kind="bench"))
    missing = {k: v for k, v in doc.items() if k != "pallas_lint"}
    with pytest.raises(report.AnalysisSchemaError, match="missing"):
        report.validate_report(missing)
    with pytest.raises(report.AnalysisSchemaError, match="unknown"):
        report.validate_report(dict(doc, surprise=1))


def test_float_prim_ratchet():
    base = _tiny_report(float_prims=("exp",))
    same = _tiny_report(float_prims=("exp",))
    assert report.compare_to_baseline(same, base) == []
    # dropping a float prim is fine (ratchet is one-way)...
    fewer = _tiny_report(float_prims=())
    assert report.compare_to_baseline(fewer, base) == []
    # ...growing one is the gated regression
    grown = _tiny_report(float_prims=("exp", "dot_general"))
    fails = report.compare_to_baseline(grown, base)
    assert len(fails) == 1 and "dot_general" in fails[0]
    # a vanished preset must be explicitly skipped, never silent
    gone = _tiny_report(preset="other")
    fails = report.compare_to_baseline(gone, base)
    assert fails and "neither audited nor skipped" in fails[0]
    excused = _tiny_report(
        preset="other",
        skipped=[{"preset": "kv8_tp1_spec0", "reason": "1 device"}])
    assert report.compare_to_baseline(excused, base) == []


# --- regression-gate integration ----------------------------------------

def _load_check_regression():
    path = (Path(__file__).resolve().parents[1] / "benchmarks"
            / "check_regression.py")
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_regression_gates_analysis_artifacts(tmp_path):
    cr = _load_check_regression()
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    clean = _tiny_report()
    (baselines / "ANALYSIS.json").write_text(json.dumps(clean))
    cur = tmp_path / "ANALYSIS.json"

    cur.write_text(json.dumps(_tiny_report()))
    assert cr.check_artifact(cur, baselines, 0.25) == []

    # fresh violations fail even though the schema is valid
    bad = _tiny_report()
    g = bad["presets"]["kv8_tp1_spec0"]["graphs"]["decode"]
    g["violations"].append({"rule": "INT-DOT-FLOAT", "graph": "decode",
                            "scope": "", "detail": "seeded"})
    bad["violations_total"] = 1
    cur.write_text(json.dumps(bad))
    fails = cr.check_artifact(cur, baselines, 0.25)
    assert any("violation" in f for f in fails)

    # new float primitive trips the ratchet
    grown = _tiny_report(float_prims=("exp", "dot_general"))
    cur.write_text(json.dumps(grown))
    fails = cr.check_artifact(cur, baselines, 0.25)
    assert any("dot_general" in f for f in fails)

    # schema drift is an error, not a silent pass
    cur.write_text(json.dumps(dict(_tiny_report(), surprise=1)))
    fails = cr.check_artifact(cur, baselines, 0.25)
    assert any("analysis schema" in f for f in fails)

    # a missing committed baseline is an error
    (baselines / "ANALYSIS.json").unlink()
    cur.write_text(json.dumps(_tiny_report()))
    fails = cr.check_artifact(cur, baselines, 0.25)
    assert any("no committed baseline" in f for f in fails)


def test_committed_baseline_is_schema_valid():
    path = (Path(__file__).resolve().parents[1] / "benchmarks"
            / "baselines" / "ANALYSIS.json")
    doc = json.loads(path.read_text())
    report.validate_report(doc)
    assert report.count_violations(doc) == 0
    assert doc["presets"], "baseline must audit at least one preset"
