"""Deterministic, restartable data pipeline.

Two sources:
  * ``SyntheticLM`` — a seeded synthetic token stream with learnable structure
    (Zipf unigrams + a deterministic bigram rule on half the positions) so
    training-loss curves are meaningful offline.
  * ``TokenFileSource`` — memory-mapped .bin of uint16/uint32 token ids.

Restart semantics: the stream is a pure function of (seed, step) — the
checkpoint stores ``step`` and the pipeline resumes exactly-once with no
state files.  Sharding: every host materializes only its slice of the
global batch (``host_slice``), which is how a 1000-node input pipeline
avoids redundant IO.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 0          # musicgen-style (B, K, S) batches
    vlm_patches: int = 0          # qwen2-vl stub: prepended patch embeddings
    d_model: int = 0

    def batch_at(self, step: int, *, host_id: int = 0, n_hosts: int = 1) -> Dict:
        """Deterministic batch for ``step`` (host-sliced)."""
        assert self.global_batch % n_hosts == 0
        b_local = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_id]))
        shape = ((b_local, self.n_codebooks, self.seq_len)
                 if self.n_codebooks else (b_local, self.seq_len))
        # Zipf-ish unigram distribution
        ranks = np.arange(1, self.vocab_size + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(self.vocab_size, size=shape, p=probs)
        # deterministic bigram structure: even positions predict odd ones
        if self.n_codebooks:
            toks[..., 1::2] = (toks[..., 0::2] * 7 + 3) % self.vocab_size
        else:
            toks[:, 1::2] = (toks[:, 0::2] * 7 + 3) % self.vocab_size
        batch = {"tokens": toks.astype(np.int32)}
        if self.vlm_patches:
            batch["extra_embeds"] = rng.normal(
                0, 1, (b_local, self.vlm_patches, self.d_model)
            ).astype(np.float32)
            s_total = self.seq_len + self.vlm_patches
            pos3 = np.broadcast_to(
                np.arange(s_total, dtype=np.int32)[None, :, None],
                (b_local, s_total, 3)).copy()
            batch["pos3"] = pos3
        return batch

    def stream(self, start_step: int = 0, **kw) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.batch_at(step, **kw)
            step += 1


@dataclasses.dataclass
class TokenFileSource:
    """Memory-mapped flat token file; deterministic strided sampling."""

    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")

    def batch_at(self, step: int, *, host_id: int = 0, n_hosts: int = 1) -> Dict:
        b_local = self.global_batch // n_hosts
        n_tok = len(self._data) - self.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_id]))
        starts = rng.integers(0, n_tok, size=(b_local,))
        toks = np.stack([self._data[s:s + self.seq_len] for s in starts])
        return {"tokens": toks.astype(np.int32) % self.vocab_size}


def make_source(cfg, shape, seed: int = 0, path: Optional[str] = None):
    if path:
        return TokenFileSource(path, cfg.vocab_size, shape.seq_len,
                               shape.global_batch, seed=seed)
    return SyntheticLM(
        cfg.vocab_size, shape.seq_len, shape.global_batch, seed=seed,
        n_codebooks=cfg.n_codebooks if cfg.frontend == "audio_codebooks" else 0,
        vlm_patches=256 if cfg.frontend == "vision_stub" else 0,
        d_model=cfg.d_model)
