"""Serving launcher: QAT-calibrate (1 step), fold to integers, run batched
generation through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --prompts 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import EngineConfig, Request, make_engine


def calibrated_folded(cfg, key, calib_tokens):
    params = T.init_params(cfg, key)
    amax = T.init_amax(cfg)
    _, obs, _ = T.forward(cfg, params, amax, calib_tokens)
    return F.fold_params(cfg, params, obs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    folded = calibrated_folded(cfg, key, calib)
    eng = make_engine(cfg, folded, EngineConfig(batch_slots=args.prompts,
                                                max_len=args.max_len))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        (args.prompt_len,)).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.prompts)]
    t0 = time.time()
    out = eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in out)
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s batch={args.prompts})")
    for i, r in enumerate(out[:2]):
        print(f"req{i}: {r.out[:12].tolist()}...")


if __name__ == "__main__":
    main()
