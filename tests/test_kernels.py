"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and bitwidth configurations.  Integer kernels must match
BIT-EXACTLY; the flash kernel matches the row oracle within 2 LSB and
bit-exactly in the single-block case."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixedpoint as fxp
from repro.core import packing as pk
from repro.core import qlayernorm as qln
from repro.core import qsoftmax as qs
from repro.kernels import ref as R
from repro.kernels import ops
from repro.kernels.int4_matmul import int4_matmul, int8_bitsplit_matmul
from repro.kernels.quant_softmax import quant_softmax as sm_kernel
from repro.kernels.quant_layernorm import quant_layernorm as ln_kernel
from repro.kernels.flash_qattention import flash_qattention, flash_qattention_jax

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("m,k,n,bm,bn,bk2", [
    (8, 128, 128, 8, 128, 64),
    (32, 256, 128, 16, 64, 64),
    (64, 512, 384, 32, 128, 128),
    (128, 1024, 256, 128, 128, 256),
])
def test_int4_matmul_shapes(m, k, n, bm, bn, bk2):
    x = RNG.integers(-127, 128, (m, k)).astype(np.int8)
    codes = RNG.integers(-8, 8, (k, n)).astype(np.int8)
    wp = np.asarray(pk.pack_int4_planar(jnp.asarray(codes), axis=0))
    bias = RNG.integers(-5000, 5000, (n,)).astype(np.int32)
    M, sh = fxp.quantize_multiplier(0.00071)
    want = R.int4_matmul_ref(jnp.asarray(x), jnp.asarray(wp),
                             jnp.asarray(bias), jnp.int32(M), jnp.int32(sh))
    got = int4_matmul(jnp.asarray(x), jnp.asarray(wp), jnp.asarray(bias),
                      jnp.int32(M), jnp.int32(sh), bm=bm, bn=bn, bk2=bk2,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (32, 512, 256)])
def test_bitsplit_8x8_equals_direct(m, k, n):
    """BIM Type-A identity: two 8x4 passes + shift-add == direct int8 matmul."""
    x = RNG.integers(-127, 128, (m, k)).astype(np.int8)
    w = RNG.integers(-127, 128, (k, n)).astype(np.int8)
    bias = np.zeros(n, np.int32)
    M, sh = fxp.quantize_multiplier(0.0004)
    got = int8_bitsplit_matmul(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(bias), jnp.int32(M), jnp.int32(sh),
                               bm=16, bn=128, bk=128, interpret=True)
    want = R.int8_bitsplit_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                                      jnp.asarray(bias), jnp.int32(M),
                                      jnp.int32(sh))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    acc = x.astype(np.int32) @ w.astype(np.int32)
    ideal = np.clip(np.round(acc * (M * 2.0 ** -sh)), -127, 127)
    assert np.max(np.abs(np.asarray(got) - ideal)) <= 1


@pytest.mark.parametrize("rows,cols,br", [(8, 64, 8), (32, 384, 8),
                                          (16, 1024, 4)])
def test_softmax_kernel_exact(rows, cols, br):
    lut = jnp.asarray(qs.make_exp_lut())
    s_x = 9.7
    M, sh = qs.index_multiplier(s_x)
    xi = np.round(RNG.normal(0, 3, (rows, cols)) * s_x).astype(np.int32)
    want = qs.quant_softmax(jnp.asarray(xi), jnp.int32(M), jnp.int32(sh), lut)
    got = sm_kernel(jnp.asarray(xi), jnp.int32(M), jnp.int32(sh), lut,
                    block_rows=br, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rows,n,sub", [(8, 128, True), (24, 256, False),
                                        (16, 1024, True)])
def test_layernorm_kernel_exact(rows, n, sub):
    g = (RNG.normal(0, 0.5, n) + 1).astype(np.float32)
    b = RNG.normal(0, 0.1, n).astype(np.float32) if sub else None
    p = qln.fold_layernorm(g, b, 31.0, subtract_mean=sub)
    xi = RNG.integers(-127, 128, (rows, n)).astype(np.int8)
    want = qln.quant_layernorm(jnp.asarray(xi), p)
    got = ln_kernel(jnp.asarray(xi), p.gamma_i, p.beta_aligned, p.M_out,
                    p.shift_out, subtract_mean=sub, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _attn_inputs(h, hkv, s, d, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.integers(-64, 65, (h, s, d)).astype(np.int8)
    k = rng.integers(-64, 65, (hkv, s, d)).astype(np.int8)
    v = rng.integers(-64, 65, (hkv, s, d)).astype(np.int8)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    return q, k, v, M, sh, s_logit


@pytest.mark.parametrize("h,hkv,s,d", [(2, 2, 128, 64), (4, 2, 256, 64),
                                       (4, 1, 128, 128)])
def test_flash_kernel_single_block_bit_exact(h, hkv, s, d):
    q, k, v, M, sh, s_logit = _attn_inputs(h, hkv, s, d)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    want = R.qattention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            jnp.int32(M), jnp.int32(sh), lut7,
                            jnp.float32(1.0), causal=True)
    got = flash_qattention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           jnp.int32(M), jnp.int32(sh), lut7,
                           jnp.float32(1.0 / s_logit), jnp.float32(1.0),
                           bq=s, bkv=s, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bq,bkv", [(64, 64), (128, 32), (32, 128)])
def test_flash_kernel_blocked_2lsb(bq, bkv):
    q, k, v, M, sh, s_logit = _attn_inputs(4, 2, 256, 64, seed=3)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    want = np.asarray(R.qattention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.int32(M),
        jnp.int32(sh), lut7, jnp.float32(1.0), causal=True), np.int32)
    got = np.asarray(flash_qattention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.int32(M),
        jnp.int32(sh), lut7, jnp.float32(1.0 / s_logit), jnp.float32(1.0),
        bq=bq, bkv=bkv, interpret=True), np.int32)
    assert np.max(np.abs(got - want)) <= 2


def test_flash_jax_matches_kernel_semantics():
    q, k, v, M, sh, s_logit = _attn_inputs(4, 2, 256, 64, seed=7)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    a = np.asarray(flash_qattention_jax(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.int32(M),
        jnp.int32(sh), lut7, jnp.float32(1.0 / s_logit), jnp.float32(1.0),
        bkv=64), np.int32)
    b = np.asarray(flash_qattention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.int32(M),
        jnp.int32(sh), lut7, jnp.float32(1.0 / s_logit), jnp.float32(1.0),
        bq=256, bkv=64, interpret=True), np.int32)
    assert np.max(np.abs(a - b)) <= 1


def test_flash_decode_offset():
    q, k, v, M, sh, s_logit = _attn_inputs(4, 2, 128, 64, seed=5)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    qd = q[:, :8]
    want = R.qattention_ref(jnp.asarray(qd), jnp.asarray(k), jnp.asarray(v),
                            jnp.int32(M), jnp.int32(sh), lut7,
                            jnp.float32(1.0), causal=True, q_offset=120)
    got = flash_qattention(jnp.asarray(qd), jnp.asarray(k), jnp.asarray(v),
                           jnp.int32(M), jnp.int32(sh), lut7,
                           jnp.float32(1.0 / s_logit), jnp.float32(1.0),
                           q_offset=120, bq=8, bkv=32, interpret=True)
    assert np.max(np.abs(np.asarray(got, np.int32)
                         - np.asarray(want, np.int32))) <= 1


def test_ops_dispatch_ref_vs_interpret():
    """ops wrappers give identical results through both backends."""
    from repro.core.qlinear import FoldedLinear
    x = RNG.integers(-127, 128, (5, 128)).astype(np.int8)  # odd rows -> pad
    codes = RNG.integers(-8, 8, (128, 64)).astype(np.int8)
    wp = pk.pack_int4_planar(jnp.asarray(codes), axis=0)
    M, sh = fxp.quantize_multiplier(0.001)
    f = FoldedLinear(wp, jnp.zeros(64, jnp.int32), jnp.int32(M), jnp.int32(sh), 4)
    a = ops.linear_w4a8(jnp.asarray(x), f, impl="ref")
    b = ops.linear_w4a8(jnp.asarray(x), f, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("bkv", [128, 32, 64, 48])  # 48: falls back to a
def test_decode_attention_batched_per_slot_lengths(bkv):  # divisor of smax
    """Continuous-batching decode kernel: every slot masked to its own cache
    prefix, bit-exact vs. the row oracle (single block) and within 1 LSB
    when the fp32 carry spans blocks."""
    from repro.kernels.decode_attention import decode_qattention

    b, hkv, g, smax, d = 4, 2, 4, 128, 64
    rng = np.random.default_rng(19)
    q = rng.integers(-64, 65, (b, hkv, g, d)).astype(np.int8)
    # kernel takes the cache-NATIVE layout (B, Smax, Hkv, D)
    k = rng.integers(-64, 65, (b, smax, hkv, d)).astype(np.int8)
    v = rng.integers(-64, 65, (b, smax, hkv, d)).astype(np.int8)
    lengths = np.asarray([1, 37, 64, 128], np.int32)   # mixed-depth slots
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    got = np.asarray(decode_qattention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lengths),
        jnp.int32(M), jnp.int32(sh), lut7, jnp.float32(1.0 / s_logit),
        jnp.float32(1.0), bkv=bkv, interpret=True), np.int32)
    want = np.asarray(R.decode_qattention_ref(
        jnp.asarray(q), jnp.asarray(k.transpose(0, 2, 1, 3)),
        jnp.asarray(v.transpose(0, 2, 1, 3)), jnp.asarray(lengths),
        jnp.int32(M), jnp.int32(sh), lut7, jnp.float32(1.0)), np.int32)
    if bkv >= smax:
        np.testing.assert_array_equal(got, want)
    else:
        assert np.max(np.abs(got - want)) <= 1


def test_decode_attention_ops_dispatch():
    """ops.decode_attention_q: ref and interpret backends agree (single
    block -> bit-exact)."""
    b, hkv, g, smax, d = 2, 1, 2, 64, 32
    rng = np.random.default_rng(23)
    q = jnp.asarray(rng.integers(-64, 65, (b, hkv, g, d)).astype(np.int8))
    k = jnp.asarray(rng.integers(-64, 65, (b, smax, hkv, d)).astype(np.int8))
    v = jnp.asarray(rng.integers(-64, 65, (b, smax, hkv, d)).astype(np.int8))
    lengths = jnp.asarray([5, 64], jnp.int32)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    a = ops.decode_attention_q(q, k, v, lengths, jnp.int32(M), jnp.int32(sh),
                               lut7, jnp.float32(1.0 / s_logit),
                               jnp.float32(1.0), impl="ref")
    c = ops.decode_attention_q(q, k, v, lengths, jnp.int32(M), jnp.int32(sh),
                               lut7, jnp.float32(1.0 / s_logit),
                               jnp.float32(1.0), impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def _paged_inputs(b, hkv, g, d, psize, n_pages, nb, lengths, seed=31):
    """Random pool + per-slot block tables (distinct pages per slot; unused
    table entries alias the trash page 0, like the serving engine's)."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-64, 65, (b, hkv, g, d)).astype(np.int8)
    kp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    vp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    perm = iter(rng.permutation(np.arange(1, n_pages)))
    btab = np.zeros((b, nb), np.int32)
    for bb, ln in enumerate(lengths):
        for i in range(-(-int(ln) // psize)):
            btab[bb, i] = next(perm)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    return q, kp, vp, btab, M, sh, s_logit


@pytest.mark.parametrize("psize,lengths", [
    (64, [1, 37, 64]),          # one page covers every slot
    (16, [1, 23, 48]),          # cross-page fp32 carry
    (8, [5, 17, 40]),
])
def test_paged_decode_attention_bit_exact_vs_oracle(psize, lengths):
    """The paged decode kernel follows per-slot block tables through the
    scalar-prefetch index map and must be BIT-EXACT against the
    block-online oracle (same accumulation order) for any page count."""
    from repro.kernels.decode_attention import paged_decode_qattention

    b, hkv, g, d = 3, 2, 4, 64
    nb = 64 // psize
    n_pages = b * nb + 1
    q, kp, vp, btab, M, sh, s_logit = _paged_inputs(
        b, hkv, g, d, psize, n_pages, nb, lengths)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(btab), jnp.asarray(lengths, jnp.int32),
            jnp.int32(M), jnp.int32(sh), lut7,
            jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    got = np.asarray(paged_decode_qattention(*args, interpret=True), np.int32)
    want = np.asarray(R.paged_decode_qattention_ref(*args), np.int32)
    np.testing.assert_array_equal(got, want)
    # the gathered contiguous view through the row oracle stays within the
    # documented 1-LSB flash tolerance (exact when one page covers a slot)
    kv = np.asarray(jnp.take(jnp.asarray(kp), jnp.asarray(btab), axis=0)
                    ).reshape(b, nb * psize, hkv, d)
    vv = np.asarray(jnp.take(jnp.asarray(vp), jnp.asarray(btab), axis=0)
                    ).reshape(b, nb * psize, hkv, d)
    row = np.asarray(R.decode_qattention_ref(
        jnp.asarray(q), jnp.asarray(kv.transpose(0, 2, 1, 3)),
        jnp.asarray(vv.transpose(0, 2, 1, 3)),
        jnp.asarray(lengths, jnp.int32), jnp.int32(M), jnp.int32(sh), lut7,
        jnp.float32(1.0)), np.int32)
    assert np.max(np.abs(got - row)) <= (0 if psize >= 64 else 1)


def test_paged_decode_attention_ops_dispatch():
    """ops.paged_decode_attention_q: ref (block-online oracle) and
    interpret (Pallas kernel) backends agree bit-for-bit."""
    b, hkv, g, d, psize, nb = 2, 1, 2, 32, 8, 4
    q, kp, vp, btab, M, sh, s_logit = _paged_inputs(
        b, hkv, g, d, psize, b * nb + 1, nb, [9, 32], seed=5)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(btab), jnp.asarray([9, 32], jnp.int32),
            jnp.int32(M), jnp.int32(sh), lut7,
            jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    a = ops.paged_decode_attention_q(*args, impl="ref")
    c = ops.paged_decode_attention_q(*args, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_paged_matches_contiguous_decode_kernel():
    """With an identity block table (page i == rows [i*P, (i+1)*P)), the
    paged kernel must reproduce the contiguous decode kernel bit-for-bit
    when block size == page size (identical DMA schedule)."""
    from repro.kernels.decode_attention import (decode_qattention,
                                                paged_decode_qattention)

    b, hkv, g, d, smax, psize = 2, 2, 4, 64, 64, 16
    rng = np.random.default_rng(13)
    q = rng.integers(-64, 65, (b, hkv, g, d)).astype(np.int8)
    k = rng.integers(-64, 65, (b, smax, hkv, d)).astype(np.int8)
    v = rng.integers(-64, 65, (b, smax, hkv, d)).astype(np.int8)
    lengths = np.asarray([29, 64], np.int32)
    nb = smax // psize
    # pool = per-slot stripes split into pages; table = identity chains
    kp = k.reshape(b * nb, psize, hkv, d)
    vp = v.reshape(b * nb, psize, hkv, d)
    btab = np.arange(b * nb, dtype=np.int32).reshape(b, nb)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    cont = np.asarray(decode_qattention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(lengths), jnp.int32(M), jnp.int32(sh), lut7,
        jnp.float32(1.0 / s_logit), jnp.float32(1.0), bkv=psize,
        interpret=True))
    paged = np.asarray(paged_decode_qattention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(btab),
        jnp.asarray(lengths), jnp.int32(M), jnp.int32(sh), lut7,
        jnp.float32(1.0 / s_logit), jnp.float32(1.0), interpret=True))
    np.testing.assert_array_equal(paged, cont)


@pytest.mark.parametrize("bkv,cache_len", [(128, 128), (32, 100), (64, 37)])
def test_flash_qdecode_matches_row_oracle(bkv, cache_len):
    """GQA decode kernel (KV streamed once per block for the whole q group)
    vs the row oracle evaluated at the cache tip."""
    from repro.kernels.flash_qattention import flash_qdecode

    hkv, g, smax, d = 2, 4, 128, 64
    rng = np.random.default_rng(11)
    q = rng.integers(-64, 65, (hkv, g, d)).astype(np.int8)
    k = rng.integers(-64, 65, (hkv, smax, d)).astype(np.int8)
    v = rng.integers(-64, 65, (hkv, smax, d)).astype(np.int8)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    got = np.asarray(flash_qdecode(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.int32(cache_len), jnp.int32(M), jnp.int32(sh), lut7,
        jnp.float32(1.0 / s_logit), jnp.float32(1.0), bkv=bkv,
        interpret=True), np.int32)
    # oracle: per-q-head attention over the first cache_len positions,
    # realized as causal with q at position cache_len - 1
    # ref expects (H, Sq, D) with kv (Hkv, S, D); group mapping h -> h // g
    q_flat = q.reshape(hkv * g, 1, d)
    want = np.asarray(R.qattention_ref(
        jnp.asarray(q_flat), jnp.asarray(k), jnp.asarray(v),
        jnp.int32(M), jnp.int32(sh), lut7,
        jnp.float32(1.0), causal=True, q_offset=cache_len - 1), np.int32)
    want = want.reshape(hkv, g, d)
    assert np.max(np.abs(got - want)) <= 1


def _paged_prefill_inputs(b, h, hkv, d, psize, nb, sq, pos0, seed=37):
    """Random pool + per-slot chains covering [0, pos0[b]+sq) rows; unused
    table entries alias the trash page 0, like the serving engine's."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-64, 65, (b, h, sq, d)).astype(np.int8)
    n_pages = b * nb + 1
    kp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    vp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    perm = iter(rng.permutation(np.arange(1, n_pages)))
    btab = np.zeros((b, nb), np.int32)
    for bb in range(b):
        for i in range(-(-(int(pos0[bb]) + sq) // psize)):
            btab[bb, i] = next(perm)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    return q, kp, vp, btab, M, sh, s_logit


@pytest.mark.parametrize("psize,sq,pos0,bq", [
    (16, 16, [0, 16], 16),        # single q block, chunk continuation
    (8, 16, [8, 32], 8),          # multi q block, mid-chain chunks
    (8, 24, [0, 16], 4),          # bq < page, ragged grid mix
    (16, 32, [16, 48], 32),       # chunk spanning several pages
])
def test_paged_prefill_kernel_bit_exact_vs_oracle(psize, sq, pos0, bq):
    """The paged chunked-prefill kernel walks per-slot block tables through
    the scalar-prefetch index map (causal-frontier dead-block clamping) and
    must be BIT-EXACT against the block-online oracle for any page count,
    chunk position, and q-block size."""
    from repro.kernels.prefill_attention import paged_prefill_qattention

    b, h, hkv, d = 2, 4, 2, 64
    pos0 = np.asarray(pos0, np.int32)
    nb = -(-(int(pos0.max()) + sq) // psize) + 1     # + one dead tail block
    q, kp, vp, btab, M, sh, s_logit = _paged_prefill_inputs(
        b, h, hkv, d, psize, nb, sq, pos0)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(btab), jnp.asarray(pos0),
            jnp.int32(M), jnp.int32(sh), lut7,
            jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    got = np.asarray(paged_prefill_qattention(*args, bq=bq, interpret=True),
                     np.int32)
    want = np.asarray(R.paged_prefill_qattention_ref(*args), np.int32)
    np.testing.assert_array_equal(got, want)
    # sanity vs the row oracle on the gathered contiguous view: within the
    # documented flash tolerance (fp32 cross-block carry)
    kv = np.asarray(jnp.take(jnp.asarray(kp), jnp.asarray(btab), axis=0)
                    ).reshape(b, nb * psize, hkv, d)
    vv = np.asarray(jnp.take(jnp.asarray(vp), jnp.asarray(btab), axis=0)
                    ).reshape(b, nb * psize, hkv, d)
    for bb in range(b):
        row = np.asarray(R.qattention_ref(
            jnp.asarray(q[bb]),
            jnp.asarray(kv[bb].transpose(1, 0, 2)),
            jnp.asarray(vv[bb].transpose(1, 0, 2)),
            jnp.int32(M), jnp.int32(sh), lut7, jnp.float32(1.0),
            causal=True, q_offset=int(pos0[bb])), np.int32)
        assert np.max(np.abs(got[bb] - row)) <= 2


def test_paged_prefill_ops_dispatch():
    """ops.paged_prefill_attention_q: ref (block-online oracle) and
    interpret (Pallas kernel) backends agree bit-for-bit."""
    b, h, hkv, d, psize, sq = 2, 2, 1, 32, 8, 16
    pos0 = np.asarray([0, 8], np.int32)
    nb = -(-(int(pos0.max()) + sq) // psize)
    q, kp, vp, btab, M, sh, s_logit = _paged_prefill_inputs(
        b, h, hkv, d, psize, nb, sq, pos0, seed=3)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(btab), jnp.asarray(pos0),
            jnp.int32(M), jnp.int32(sh), lut7,
            jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    a = ops.paged_prefill_attention_q(*args, impl="ref")
    c = ops.paged_prefill_attention_q(*args, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
