"""Import shim: the loop-aware HLO cost parser moved to
``repro.analysis.hlo_cost`` (PR 9) so the analysis subsystem can use it
without path games.  Kept so existing ``from benchmarks import hlo_cost``
callers and the CLI keep working.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.hlo_cost import (  # noqa: E402,F401
    PACKED_U8_MARKERS,
    analyze,
    analyze_file,
    parse_computations,
)

if __name__ == "__main__":
    import json

    out = analyze_file(sys.argv[1])
    out.pop("loop_report")
    print(json.dumps(out, indent=1))
