"""Unit + property tests for the FQ-BERT quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency (requirements-dev.txt).  Without it
    # the property tests are skipped but every deterministic test still runs,
    # so the tier-1 suite collects cleanly in minimal environments.
    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install -r "
                   "requirements-dev.txt)")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies`` at decoration time only."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import fixedpoint as fxp
from repro.core import packing as pk
from repro.core import qlayernorm as qln
from repro.core import qsoftmax as qs
from repro.core import quant as q
from repro.core import qlinear as ql
from repro.core.policy import POLICY_FQ, quantize_scale_8bit


# --- symmetric quantizer (paper Eq. 1-3) --------------------------------------

@given(st.integers(2, 8), st.floats(0.01, 100.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_quant_roundtrip_halflsb(bits, scale_mag, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, scale_mag, 256)).astype(np.float32)
    m = q.per_tensor_max(jnp.asarray(x))
    s = q.compute_scale(m, bits)
    xi = q.quantize(jnp.asarray(x), s, bits)
    xd = q.dequantize(xi, s)
    # round-trip error bounded by half an LSB inside the clip range
    assert float(jnp.max(jnp.abs(xd - np.clip(x, -float(m), float(m))))) <= \
        0.5 / float(s) + 1e-6


@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_fake_quant_matches_integer_path(bits, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 3, 128).astype(np.float32)
    m = jnp.asarray(np.abs(x).max())
    fq = q.fake_quant(jnp.asarray(x), m, bits)
    s = q.compute_scale(m, bits)
    ref = q.dequantize(q.quantize(jnp.asarray(x), s, bits), s)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(ref), atol=1e-6)


def test_fake_quant_ste_gradient_gates_clipped():
    x = jnp.asarray([-3.0, -0.5, 0.2, 3.0])
    g = jax.grad(lambda t: jnp.sum(q.fake_quant(t, jnp.float32(1.0), 8)))(x)
    np.testing.assert_allclose(np.asarray(g), [0, 1, 1, 0], atol=1e-6)


def test_ema_calibrator_bootstrap_and_decay():
    cal = q.EMACalibrator(0.9)
    e = cal.init()
    e = cal.update(e, jnp.asarray([1.0, -2.0]))
    assert float(e) == pytest.approx(2.0)        # first obs adopted
    e = cal.update(e, jnp.asarray([4.0]))
    assert float(e) == pytest.approx(0.9 * 2 + 0.1 * 4)


# --- packing ------------------------------------------------------------------

@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_roundtrips(rows2, cols, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, (2 * rows2, cols)).astype(np.int8)
    for pack, unpack in ((pk.pack_int4, pk.unpack_int4),
                         (pk.pack_int4_planar, pk.unpack_int4_planar)):
        p = pack(jnp.asarray(codes), axis=0)
        assert p.shape == (rows2, cols)
        u = np.asarray(unpack(p, axis=0))
        np.testing.assert_array_equal(u, codes)


def test_packed_nbytes():
    assert pk.packed_nbytes((128, 64), axis=0) == 64 * 64


# --- fixed point (paper Eq. 5) --------------------------------------------------

@given(st.floats(1e-7, 0.9999), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_rescale_within_one_lsb(s_f, seed):
    rng = np.random.default_rng(seed)
    M, sh = fxp.quantize_multiplier(s_f)
    acc = rng.integers(-2**30, 2**30, 2000).astype(np.int32)
    got = np.asarray(fxp.rescale(jnp.asarray(acc), jnp.int32(M), jnp.int32(sh)))
    want = np.round(acc.astype(np.float64) * s_f)
    inr = np.abs(want) <= 127
    if inr.any():
        assert np.max(np.abs(got[inr] - want[inr])) <= 1


@given(st.integers(1, 2**16), st.integers(0, 1000))
@settings(max_examples=60, deadline=None)
def test_fixed_rsqrt(x0, jitter):
    x = np.int32(min(x0 + jitter, 2**16))
    y, s = fxp.rsqrt_mantexp(jnp.asarray([x]))
    got = float(y[0]) * 2.0 ** (-15 - int(s[0]))
    assert abs(got - 1 / np.sqrt(x)) * np.sqrt(x) < 3e-3


def test_requantize_saturates():
    y = fxp.requantize(jnp.asarray([2**30, -(2**30)], jnp.int32),
                       *fxp.quantize_multiplier(0.5))
    assert list(np.asarray(y)) == [127, -127]


# --- LUT softmax ----------------------------------------------------------------

def test_lut_properties():
    lut = qs.make_exp_lut()
    assert lut.shape == (256,)
    assert lut[0] == 255 and lut[-1] == 0
    assert np.all(np.diff(lut) <= 0)  # monotone non-increasing


@given(st.floats(2.0, 40.0), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_quant_softmax_close_and_normalized(s_x, seed):
    rng = np.random.default_rng(seed)
    lut = jnp.asarray(qs.make_exp_lut())
    M, sh = qs.index_multiplier(s_x)
    x = rng.normal(0, 3, (8, 64)).astype(np.float32)
    xi = np.round(x * s_x).astype(np.int32)
    p = np.asarray(qs.quant_softmax(jnp.asarray(xi), jnp.int32(M),
                                    jnp.int32(sh), lut))
    ref = np.asarray(jax.nn.softmax(jnp.asarray(xi / s_x), -1))
    assert np.max(np.abs(p / 128.0 - ref)) < 0.04          # ~<=4 LSB
    assert np.all(np.abs(p.sum(-1) - 128) <= 16)           # near-normalized
    assert p.min() >= 0


def test_quant_softmax_mask_exact_zero():
    lut = jnp.asarray(qs.make_exp_lut())
    M, sh = qs.index_multiplier(10.0)
    xi = jnp.asarray(np.random.default_rng(0).integers(-50, 50, (4, 32)),
                     jnp.int32)
    mask = np.ones((4, 32), bool)
    mask[:, 20:] = False
    p = np.asarray(qs.quant_softmax(xi, jnp.int32(M), jnp.int32(sh), lut,
                                    mask=jnp.asarray(mask)))
    assert (p[:, 20:] == 0).all()


def test_quant_softmax_shift_invariance():
    """The paper's max-subtraction trick: softmax(x) == softmax(x + c)."""
    lut = jnp.asarray(qs.make_exp_lut())
    M, sh = qs.index_multiplier(12.0)
    xi = jnp.asarray(np.random.default_rng(1).integers(-100, 100, (4, 16)),
                     jnp.int32)
    p1 = qs.quant_softmax(xi, jnp.int32(M), jnp.int32(sh), lut)
    p2 = qs.quant_softmax(xi + 1000, jnp.int32(M), jnp.int32(sh), lut)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


# --- integer layernorm -----------------------------------------------------------

@given(st.booleans(), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_qln_close_to_float(sub_mean, seed):
    rng = np.random.default_rng(seed)
    n = 256
    g = (rng.normal(0, 0.5, n) + 1).astype(np.float32)
    b = (rng.normal(0, 0.1, n)).astype(np.float32) if sub_mean else None
    xf = rng.normal(0, 2, (16, n)).astype(np.float32)
    s_x = 127.0 / np.abs(xf).max()
    s_y = 127.0 / 4.0
    xi = np.round(xf * s_x).astype(np.int8)
    p = qln.fold_layernorm(g, b, s_y, subtract_mean=sub_mean)
    yi = np.asarray(qln.quant_layernorm(jnp.asarray(xi), p))
    xd = xi / s_x
    if sub_mean:
        ref = ((xd - xd.mean(-1, keepdims=True))
               / np.sqrt(xd.var(-1)[:, None] + 1e-12) * g + b)
    else:
        ref = xd / np.sqrt((xd ** 2).mean(-1)[:, None] + 1e-12) * g
    want = np.clip(np.round(ref * s_y), -127, 127)
    assert np.max(np.abs(yi - want)) <= 3


# --- folded linear (Eq. 4/5 end-to-end) -------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_fold_linear_integer_path(seed):
    rng = np.random.default_rng(seed)
    W = rng.normal(0, 0.2, (64, 32)).astype(np.float32)
    b = rng.normal(0, 0.02, 32).astype(np.float32)
    x = rng.normal(0, 1, (8, 64)).astype(np.float32)
    s_a = 127.0 / np.abs(x).max()
    y_ref = x @ W + b
    s_y = 127.0 / max(np.abs(y_ref).max(), 1e-6)
    f = ql.fold_linear(W, b, float(s_a), float(s_y), POLICY_FQ)
    xi = np.clip(np.round(x * s_a), -127, 127).astype(np.int8)
    yi = np.asarray(ql.integer_linear_ref(jnp.asarray(xi), f))
    # compare against ideal rescale of the same integer accumulator
    wc = np.asarray(pk.unpack_int4_planar(f.w_packed, axis=0), np.int32)
    acc = xi.astype(np.int32) @ wc + np.asarray(f.bias_i)
    ideal = np.clip(np.round(acc * (int(f.M) * 2.0 ** -int(f.shift))),
                    -127, 127)
    assert np.max(np.abs(yi - ideal)) <= 1


def test_scale8_preserves_8bits():
    s = 0.0123456
    s8 = quantize_scale_8bit(s)
    assert abs(s8 - s) / s < 2 ** -7


def test_bias_quantization_eq4():
    b = np.array([0.5, -0.25])
    out = q.quantize_bias(jnp.asarray(b), jnp.float32(10.0), jnp.float32(4.0))
    np.testing.assert_array_equal(np.asarray(out), [20, -10])
