"""Reduced same-family configs for CPU smoke tests."""
import dataclasses

from repro.configs.base import get_config


def smoke_config(name: str, **extra):
    cfg = get_config(name)
    pat = cfg.pattern
    nh = min(cfg.n_heads, 4)
    nkv = max(1, min(cfg.n_kv_heads, nh))
    over = dict(
        n_layers=len(pat) * (2 if len(pat) == 1 else 1),
        d_model=128,
        n_heads=nh,
        n_kv_heads=nkv,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        max_position=512,
        param_dtype="float32",
        remat=False,
    )
    if cfg.n_experts:
        over.update(n_experts=4, top_k=min(cfg.top_k, 2),
                    moe_d_ff=128,
                    n_shared_experts=min(cfg.n_shared_experts, 2))
    if cfg.family == "hybrid":
        over.update(mamba_d_state=8)
    if cfg.family == "ssm":
        over.update(n_heads=2, n_kv_heads=2, head_dim=64)
    if cfg.sliding_window:
        over.update(sliding_window=16)
    over.update(extra)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **over)
