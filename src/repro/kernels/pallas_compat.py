"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
toolchain pin in CI (and the baked container image) may sit on either side of
the rename.  Kernels import ``CompilerParams`` from here so they compile
against both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
