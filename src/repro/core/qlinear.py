"""Quantized linear layer: QAT (fake-quant) and folded-integer representations.

One logical layer, two physical forms:

* **QAT form** (training, paper §IV-A procedure): float master weights W, bias
  b; forward fake-quantizes activations (8-bit, EMA scale) and weights (4-bit,
  max|W| scale) with STE gradients.  This is what ``train_step`` lowers.
* **Folded form** (serving): nibble-packed int4 codes + int32 bias + a 32-bit
  fixed-point requantization multiplier (paper Eq. 4/5).  This is what
  ``serve_step`` lowers, and what the Pallas int4 matmul kernel consumes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core import packing
from repro.core import quant as q
from repro.core.policy import QuantPolicy, quantize_scale_8bit


class FoldedLinear(NamedTuple):
    """Integer serving form of a linear layer y = x @ W + b.

    ``w_packed``: uint8 (K//2, N) — K-axis nibble-planar packed int4 codes
    (rows [0, K/2) in low nibbles, [K/2, K) in high nibbles; Type-A BIM layout).
    For w_bits == 8 the codes are plain int8 (K, N) and ``w_packed`` is int8.
    """

    w_packed: jax.Array
    bias_i: jax.Array      # int32 (N,)
    M: jax.Array           # int32 requant multiplier
    shift: jax.Array       # int32 requant shift
    w_bits: int


def qat_linear(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    a_max: jax.Array,
    policy: QuantPolicy,
) -> jax.Array:
    """QAT forward: fake-quant activations + weights, float matmul.

    ``a_max``: EMA max|activation| for this site (0 on the very first step —
    falls back to the batch statistic so calibration bootstraps itself).
    """
    if policy.quantize_wa:
        a_obs = jax.lax.stop_gradient(q.per_tensor_max(x))
        a_m = jnp.where(a_max > 0, a_max, a_obs)
        x = q.fake_quant(x, a_m, policy.a_bits)
        w_m = jax.lax.stop_gradient(
            q.per_channel_max(w, axis=-1) if policy.per_channel_w
            else q.per_tensor_max(w))
        w = q.fake_quant(w, w_m, policy.w_bits)
    y = x @ w
    if b is not None:
        y = y + b
    return y


def observe(x: jax.Array) -> jax.Array:
    """Batch statistic for the EMA calibrator (Eq. 3)."""
    return jax.lax.stop_gradient(q.per_tensor_max(x)).astype(jnp.float32)


def fold_linear(
    w: np.ndarray,
    b: Optional[np.ndarray],
    s_a: float,
    s_y: float,
    policy: QuantPolicy,
) -> FoldedLinear:
    """Fold a trained float linear layer into the integer serving form.

    s_a: input activation scale (from EMA), s_y: output activation scale.
    """
    w = np.asarray(w, np.float64)
    k_in = w.shape[0]
    s_w = float(q.qmax(policy.w_bits) / max(float(np.max(np.abs(w))), 1e-8))
    if policy.quantize_scale:
        s_w = quantize_scale_8bit(s_w)
        s_a = quantize_scale_8bit(s_a)
        s_y = quantize_scale_8bit(s_y)
    codes = np.clip(np.round(w * s_w), -q.qmax(policy.w_bits), q.qmax(policy.w_bits))
    if policy.w_bits == 4:
        assert k_in % 2 == 0, "int4 packing needs even K"
        w_packed = np.asarray(
            packing.pack_int4_planar(jnp.asarray(codes.astype(np.int8)), axis=0)
        )
    else:
        w_packed = codes.astype(np.int8)
    if b is not None:
        bias_i = np.round(np.asarray(b, np.float64) * (s_a * s_w)).astype(np.int64)
        bias_i = np.clip(bias_i, -(2**31 - 1), 2**31 - 1).astype(np.int32)
    else:
        bias_i = np.zeros(w.shape[1], np.int32)
    s_f = s_y / (s_a * s_w)
    M, shift = fxp.quantize_multiplier(s_f)
    return FoldedLinear(
        w_packed=jnp.asarray(w_packed),
        bias_i=jnp.asarray(bias_i),
        M=jnp.asarray(M, jnp.int32),
        shift=jnp.asarray(shift, jnp.int32),
        w_bits=policy.w_bits,
    )


def integer_linear_ref(x_i: jax.Array, f: FoldedLinear) -> jax.Array:
    """Pure-jnp integer forward (oracle; the Pallas kernel must match exactly).

    x_i: int8 codes (..., K).  Returns int8 codes (..., N) on the s_y grid.
    """
    w_codes = (packing.unpack_int4_planar(f.w_packed, axis=0)  # int8 (K,N)
               if f.w_bits == 4 else f.w_packed)
    acc = jax.lax.dot_general(
        x_i.astype(jnp.int8),
        w_codes.astype(jnp.int8),
        (((x_i.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc = acc + f.bias_i.astype(jnp.int32)
    return fxp.requantize(acc, f.M, f.shift, bits=8)
