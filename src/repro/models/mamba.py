"""Mamba-1 selective-SSM block (for jamba's hybrid stack) with QAT projections.

Projections (in/x/dt/out) are W4A8-quantized like every linear; the selective
scan itself runs fp32 (recurrent 8-bit state diverges — DESIGN.md §4 records
this as the documented partial-applicability case).

Training uses a chunked scan: outer ``lax.scan`` over sequence chunks carries
the (B, d_in, N) state; within a chunk an associative scan runs in parallel.
Decode is the O(1) single-step recurrence on the same state layout.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.models.layers import Obs, qdense, fake_quant_act

import os
CHUNK = int(os.environ.get("REPRO_MAMBA_CHUNK", "128"))


def mamba_dims(cfg):
    d_in = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return d_in, dt_rank


def _ssm_chunked(x, dt, B, C, A, D):
    """x (Bt, S, d_in); dt (Bt, S, d_in); B,C (Bt, S, N); A (d_in, N); D (d_in,)
    -> y (Bt, S, d_in).  h_t = exp(dt*A) h_{t-1} + dt*B_t x_t ; y = C_t.h + D x.
    """
    bt, s, d_in = x.shape
    n = B.shape[-1]
    chunk = min(CHUNK, s)
    pad = (-s) % chunk
    if pad:
        pz = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        x, dt, B, C = pz(x), pz(dt), pz(B), pz(C)
    sp = s + pad
    nchunk = sp // chunk
    xr = x.reshape(bt, nchunk, chunk, d_in)
    dtr = dt.reshape(bt, nchunk, chunk, d_in)
    Br = B.reshape(bt, nchunk, chunk, n)
    Cr = C.reshape(bt, nchunk, chunk, n)

    def chunk_step(h0, inp):
        xc, dtc, bc, cc = inp                       # (Bt, L, ...)
        # decay and input terms, (Bt, L, d_in, N)
        a = jnp.exp(dtc[..., None] * A)             # exp(dt*A)
        u = (dtc * xc)[..., None] * bc[:, :, None, :]

        def op(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_cum, u_cum = jax.lax.associative_scan(op, (a, u), axis=1)
        h = a_cum * h0[:, None] + u_cum             # (Bt, L, d_in, N)
        y = jnp.einsum("bldn,bln->bld", h, cc)
        return h[:, -1], y

    h0 = jnp.zeros((bt, d_in, n), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_step, h0,
        (xr.transpose(1, 0, 2, 3), dtr.transpose(1, 0, 2, 3),
         Br.transpose(1, 0, 2, 3), Cr.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(bt, sp, d_in)[:, :s]
    return y + x[:, :s] * D


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d.  x (B, S, d); w (K, d).  Returns y and the
    last K-1 inputs (decode state)."""
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state)
    xp = jnp.concatenate([pad, x], 1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return y, xp[:, -(k - 1):]


def mamba_qat(
    x: jax.Array,            # (B, S, d)
    p: Dict,
    amax: Dict[str, jax.Array],
    policy: QuantPolicy,
    cfg,
    state: Dict | None = None,   # decode: {'h': (B,d_in,N), 'conv': (B,K-1,d_in)}
) -> Tuple[jax.Array, Obs, Dict | None]:
    b, s, d = x.shape
    d_in, dt_rank = mamba_dims(cfg)
    n = cfg.mamba_d_state
    obs: Obs = {}
    xz, obs["mamba_in"] = qdense(x, p["w_in"], None, amax["mamba_in"], policy)
    xi, z = jnp.split(xz, 2, axis=-1)               # (B, S, d_in) each
    xc, conv_state = _causal_conv(xi, p["conv_w"],
                                  None if state is None else state["conv"])
    xc = jax.nn.silu(xc + p["conv_b"])
    xc, obs["mamba_conv"] = fake_quant_act(xc, amax["mamba_conv"],
                                           policy.a_bits, policy.quantize_wa)
    prm, obs["mamba_x"] = qdense(xc, p["w_x"], None, amax["mamba_x"], policy)
    dt_r, B_, C_ = jnp.split(prm.astype(jnp.float32),
                             [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["w_dt"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))    # (d_in, N), negative
    xf = xc.astype(jnp.float32)
    if state is None:
        y = _ssm_chunked(xf, dt, B_, C_, A, p["D"].astype(jnp.float32))
        new_state = None
    else:
        # single-step decode (s == 1)
        a = jnp.exp(dt[:, 0, :, None] * A)          # (B, d_in, N)
        h = a * state["h"] + (dt[:, 0] * xf[:, 0])[..., None] * B_[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_[:, 0])[:, None] + xf * p["D"]
        new_state = {"h": h, "conv": conv_state}
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out, obs["mamba_out"] = qdense(y, p["w_out"], None, amax["mamba_out"], policy)
    return out, obs, new_state


MAMBA_SITES = ("mamba_in", "mamba_conv", "mamba_x", "mamba_out")
