"""BERT-base (the paper's own model): bidirectional encoder + SST-2-style
classification head, built on the same quantized transformer substrate.

The paper's operating point: seq 128, batch 1, 12 layers, d=768 — config
``bert-base`` with shape ``paper_128``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models import layers as L


def init_bert_params(cfg: ModelConfig, key, n_classes: int = 2) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "backbone": T.init_params(cfg, k1),
        "pooler": {"w": (jax.random.normal(k2, (d, d)) * 0.02).astype(cfg.dtype),
                   "b": jnp.zeros((d,), cfg.dtype)},
        "classifier": {"w": (jax.random.normal(k3, (d, n_classes)) * 0.02
                             ).astype(cfg.dtype),
                       "b": jnp.zeros((n_classes,), cfg.dtype)},
    }


def init_bert_amax(cfg: ModelConfig) -> Dict:
    a = T.init_amax(cfg)
    a["pool_in"] = jnp.zeros((), jnp.float32)
    a["cls_in"] = jnp.zeros((), jnp.float32)
    return a


def bert_forward(
    cfg: ModelConfig,
    params: Dict,
    amax: Dict,
    tokens: jax.Array,                    # (B, S)
    attn_mask: Optional[jax.Array] = None,  # (B, S) bool padding mask
) -> Tuple[jax.Array, jax.Array, Dict, jax.Array]:
    """Returns (cls_logits, mlm_logits, obs, aux)."""
    b, s = tokens.shape
    if attn_mask is None:
        attn_mask = jnp.ones((b, s), bool)
    mask4 = attn_mask[:, None, None, :] & jnp.ones((b, 1, s, 1), bool)
    backbone_amax = {k: amax[k] for k in ("blocks", "embed_out", "head_in")}
    mlm_logits, obs, aux = T.forward(
        cfg, params["backbone"], backbone_amax, tokens, mask=mask4)
    # [CLS] pooling + classifier (quantized linears, paper's task-specific head)
    # NOTE: transformer.forward returns logits; for the pooled path we re-embed
    # the final hidden via the obs-free helper below.
    return mlm_logits, obs, aux


def bert_classify(
    cfg: ModelConfig,
    params: Dict,
    amax: Dict,
    tokens: jax.Array,
    attn_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict, jax.Array]:
    """Classification forward: pooled [CLS] -> tanh -> classifier."""
    b, s = tokens.shape
    if attn_mask is None:
        attn_mask = jnp.ones((b, s), bool)
    mask4 = attn_mask[:, None, None, :] & jnp.ones((b, 1, s, 1), bool)
    backbone_amax = {k: amax[k] for k in ("blocks", "embed_out", "head_in")}
    hidden, obs, aux = forward_hidden(cfg, params["backbone"], backbone_amax,
                                      tokens, mask4)
    policy = cfg.quant
    cls = hidden[:, 0]
    pooled, ob_p = L.qdense(cls, params["pooler"]["w"], params["pooler"]["b"],
                            amax["pool_in"], policy)
    pooled = jnp.tanh(pooled)
    logits, ob_c = L.qdense(pooled, params["classifier"]["w"],
                            params["classifier"]["b"], amax["cls_in"], policy)
    obs = dict(obs)
    obs["pool_in"] = ob_p
    obs["cls_in"] = ob_c
    return logits.astype(jnp.float32), obs, aux


def forward_hidden(cfg, params, amax, tokens, mask):
    """Backbone forward that returns final hidden states (pre-LM-head)."""
    policy = cfg.quant
    b, s = tokens.shape
    x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
    if cfg.learned_pos:
        x = x + params["embed"]["pos"][None, :s]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, obs_embed = L.fake_quant_act(x, amax["embed_out"], policy.a_bits,
                                    policy.quantize_wa)
    kinds = T.slot_kinds(cfg)

    def body(carry, xs):
        xc, aux_sum = carry
        p_rep, a_rep = xs
        obs_rep = {}
        for i, (mixer, ffn) in enumerate(kinds):
            xc, o, aux = T._apply_slot(cfg, mixer, ffn, xc, p_rep[f"slot{i}"],
                                       a_rep[f"slot{i}"], pos, mask)
            obs_rep[f"slot{i}"] = o
            aux_sum = aux_sum + aux
        return (xc, aux_sum), obs_rep

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), obs_blocks = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], amax["blocks"]))
    x = L.qnorm(x, params["final_norm"], policy, cfg.norm_type)
    x, obs_head = L.fake_quant_act(x, amax["head_in"], policy.a_bits,
                                   policy.quantize_wa)
    obs = {"blocks": obs_blocks, "embed_out": obs_embed, "head_in": obs_head}
    return x, obs, aux
