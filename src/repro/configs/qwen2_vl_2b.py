"""Qwen2-VL-2B  [arXiv:2409.12191] — M-RoPE; vision frontend is a stub that
feeds precomputed patch embeddings (per task spec)."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151_936,
    mrope_sections=(16, 24, 24), tied_embeddings=True,
    rope_theta=1_000_000.0, frontend="vision_stub", param_dtype="bfloat16",
))
