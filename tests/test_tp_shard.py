"""TP-sharded paged KV pool: sharded greedy outputs must be bit-identical
to the unsharded engine on every workload shape the bench gates — plain
mixed-length traffic, shared-prefix reuse, chunked long-prompt prefill, and
overload with forced preemption — and the host-side scheduler must remain a
single rank-agnostic authority (identical counters, identical per-tick
stats, allocator invariants clean every tick).

The TP=4 tests need 4 devices; on CPU run them under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the test-tp CI
lane sets this job-wide — the flag must be set before jax initializes, so
it cannot be toggled from inside an already-running suite; without it the
multi-device tests skip).  The tp=1 degenerate test drives the same
shard_map path on a single device and runs everywhere — the no-simulation
fallback that keeps the TP code exercised in the plain CPU lane.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.mesh import make_tp_mesh
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import (Engine, EngineConfig,
                                EngineConfigError, Request)

KEY = jax.random.PRNGKey(0)
NDEV = len(jax.devices())
multi = pytest.mark.skipif(
    NDEV < 4, reason="needs 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4 on CPU)")


@pytest.fixture(scope="module")
def folded_cfg():
    cfg = smoke_config("yi-6b")          # nh=4, nkv=4: TP=4 -> 1 head/rank
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def _requests(cfg, lens, max_news, seed=0, prefix_len=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    reqs = []
    for ln, mn in zip(lens, max_news):
        suffix = rng.integers(0, cfg.vocab_size, (ln,)).astype(np.int32)
        reqs.append(Request(prompt=np.concatenate([prefix, suffix]),
                            max_new_tokens=mn))
    return reqs


def _drive(eng, requests, max_ticks=3000):
    """Submit everything, step to completion, asserting the stats
    invariants + allocator sweep every tick (the per-tick sweep is what
    catches a rank-divergent scheduling decision the moment it happens,
    not after outputs already differ)."""
    for r in requests:
        eng.submit(r)
    ticks = 0
    while eng.sched.has_work:
        assert ticks < max_ticks, "engine livelocked"
        ticks += 1
        eng.step()
        g = eng.stats(check=True)
        assert g["decode_slots_active"] + g["prefill_slots"] \
            + g["free_slots"] == eng.batch
        assert g["pages_in_use"] + g["pages_free"] + g["pages_cached_lru"] \
            == g["pages_capacity"]
    return [r.out.tolist() for r in requests]


def _ab(cfg, folded, mkreqs, *, tp_kw, max_ticks=3000, **kw):
    """Run unsharded vs sharded on the same workload; outputs AND counters
    must match exactly (counters equality is the rank-agnostic-scheduling
    invariant: the sharded engine made the identical decision sequence)."""
    ref = Engine(cfg, folded, EngineConfig(**kw))
    out_ref = _drive(ref, mkreqs(), max_ticks=max_ticks)
    tp = Engine(cfg, folded, EngineConfig(**kw, **tp_kw))
    out_tp = _drive(tp, mkreqs(), max_ticks=max_ticks)
    assert out_tp == out_ref
    assert tp.counters == ref.counters
    return out_ref, ref, tp


@multi
def test_tp4_plain_token_identity(folded_cfg):
    cfg, folded = folded_cfg
    mk = lambda: _requests(cfg, [5, 9, 3, 12], [6, 4, 8, 5])
    _, ref, tp = _ab(cfg, folded, mk, tp_kw=dict(tp=4), batch_slots=3,
                     max_len=64, cache_layout="paged", page_size=4)
    assert tp.stats()["tp"] == 4 and ref.stats()["tp"] == 1


@multi
def test_tp4_pool_is_actually_sharded(folded_cfg):
    """Each rank's shard holds Hkv/tp heads of EVERY page — the memory win
    the tentpole exists for, asserted on device buffers, not specs."""
    cfg, folded = folded_cfg
    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           cache_layout="paged", page_size=4,
                                           tp=4))
    leaf = eng.cache["slot0"]["k"]       # (n_reps, n_pages, P, Hkv, hd)
    shards = leaf.addressable_shards
    assert len(shards) == 4
    for s in shards:
        assert s.data.shape == (cfg.n_reps, eng.n_pages, eng.page_size,
                                cfg.n_kv_heads // 4, cfg.hd)


@multi
def test_tp4_prefix_reuse_token_identity(folded_cfg):
    """Shared system prompt: the replicated block table maps the same
    cached pages on every rank, so prefix hits (and the suffix-only
    prefill) survive sharding bit-exactly."""
    cfg, folded = folded_cfg
    mk = lambda: _requests(cfg, [4, 4, 6], [6, 6, 4], prefix_len=9)
    _, _, tp = _ab(cfg, folded, mk, tp_kw=dict(tp=4), batch_slots=2,
                   max_len=64, cache_layout="paged", page_size=4)
    assert tp.counters["prefix_hits"] >= 1


@multi
def test_tp4_longprompt_chunked_token_identity(folded_cfg):
    """Chunks are the cross-rank work-division unit: every rank runs the
    same page-aligned chunk on its own heads.  The chunked sharded run
    must match both the chunked and the one-shot unsharded runs."""
    cfg, folded = folded_cfg
    mk = lambda: _requests(cfg, [24, 4, 4], [4, 8, 8])
    kw = dict(batch_slots=3, max_len=64, cache_layout="paged", page_size=4)
    out_chunked, _, tp = _ab(cfg, folded, mk, tp_kw=dict(tp=4),
                             max_batched_tokens=16, max_prefill_chunk=8,
                             **kw)
    assert tp.counters["chunked_prefills"] >= 1
    # chunking changes latency, not tokens — sharded chunked == one-shot
    out_oneshot = _drive(Engine(cfg, folded, EngineConfig(**kw)), mk())
    assert out_chunked == out_oneshot


@multi
def test_tp4_overload_preemption_token_identity(folded_cfg):
    """Pool sized to force grow-path preemption: spill/restore decisions
    are made once on the host and apply to every rank's slice — the
    sharded starved run must preempt exactly like the unsharded starved
    run and both must match the unlimited-pool truth."""
    cfg, folded = folded_cfg
    mk = lambda: _requests(cfg, [4, 4], [12, 12])
    kw = dict(batch_slots=2, max_len=64, cache_layout="paged", page_size=4)
    truth = Engine(cfg, folded, EngineConfig(**kw))   # ample default pool
    out_truth = _drive(truth, mk())
    assert truth.counters["preemptions"] == 0
    out_starved, _, tp = _ab(cfg, folded, mk, tp_kw=dict(tp=4), n_pages=6,
                             **kw)
    assert tp.counters["preemptions"] >= 1
    assert tp.counters["restores"] == tp.counters["preemptions"]
    assert out_starved == out_truth


def _cycle_requests(cfg, lens, max_news, seed=7, period=3):
    """Prompt-lookup-friendly prompts (tiled short cycles) so speculative
    runs really exercise multi-token verify forwards."""
    rng = np.random.default_rng(seed)
    reqs = []
    for ln, mn in zip(lens, max_news):
        pat = rng.integers(0, cfg.vocab_size, (period,)).astype(np.int32)
        reqs.append(Request(prompt=np.tile(pat, ln // period + 1)[:ln],
                            max_new_tokens=mn))
    return reqs


@multi
def test_tp4_speculative_token_identity(folded_cfg):
    """Speculative decoding under TP=4: the verify forward shards like
    prefill (rank-local heads, replicated verify_rows), draft/accept
    decisions are host-side and rank-agnostic — sharded spec must match
    unsharded spec counter-for-counter AND both must match plain decode."""
    cfg, folded = folded_cfg
    mk = lambda: _cycle_requests(cfg, [5, 9, 3, 12], [8, 6, 8, 6])
    kw = dict(batch_slots=3, max_len=64, cache_layout="paged", page_size=4)
    out_plain = _drive(Engine(cfg, folded, EngineConfig(**kw)), mk())
    out_spec, _, tp = _ab(cfg, folded, mk, tp_kw=dict(tp=4),
                          spec_k=3, **kw)
    assert out_spec == out_plain
    assert tp.counters["drafted"] > 0


def test_tp1_degenerate_speculative_identity(folded_cfg):
    """Single-device shard_map fallback for the spec verify forward: runs
    in the plain CPU lane, keeps the sharded verify graph tested."""
    cfg, folded = folded_cfg
    mk = lambda: _cycle_requests(cfg, [5, 9, 3], [8, 6, 8])
    kw = dict(batch_slots=2, max_len=64, cache_layout="paged", page_size=4)
    out_plain = _drive(Engine(cfg, folded, EngineConfig(**kw)), mk())
    out_spec, ref, tp = _ab(cfg, folded, mk,
                            tp_kw=dict(mesh=make_tp_mesh(1)),
                            spec_k=3, **kw)
    assert out_spec == out_plain
    assert tp.mesh is not None and ref.mesh is None
    assert tp.counters["drafted"] > 0


@multi
def test_tp_rejects_indivisible_heads(folded_cfg):
    cfg, folded = folded_cfg                 # nkv=4: TP=3 can't slice it
    with pytest.raises(EngineConfigError, match="n_kv_heads"):
        Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                         cache_layout="paged", page_size=4,
                                         tp=3))


def test_tp_requires_paged_layout(folded_cfg):
    cfg, folded = folded_cfg
    with pytest.raises(EngineConfigError, match="paged"):
        Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                         cache_layout="contiguous",
                                         mesh=make_tp_mesh(1)))


def test_tp1_degenerate_shard_map_identity(folded_cfg):
    """tp=1 on an explicit 1-device mesh drives the full shard_map path
    (slice at rank 0, size-1 all_gather) with no simulation flag — the
    fallback that keeps TP code tested in the single-device CI lane."""
    cfg, folded = folded_cfg
    mk = lambda: _requests(cfg, [5, 9, 3], [6, 4, 8], prefix_len=5)
    _, ref, tp = _ab(cfg, folded, mk, tp_kw=dict(mesh=make_tp_mesh(1)),
                     batch_slots=2, max_len=64, cache_layout="paged",
                     page_size=4, max_batched_tokens=16, max_prefill_chunk=8)
    assert tp.mesh is not None and tp.tp == 1
    assert ref.mesh is None              # the A/B really was sharded-vs-not
