"""Batched serving engine over the folded integer model.

Continuous-batching-lite: requests join a fixed-size slot table; each engine
step decodes one token for every active slot (the decode graph is compiled
once for the full batch — idle slots carry a pad token).  Prefill fills the
quantized KV cache slot-by-slot via the decode graph for SSM/hybrid archs or
in one shot for attention archs.  Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import serve_int as S


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out: Optional[np.ndarray] = None


class Engine:
    def __init__(self, cfg: ModelConfig, folded, *, batch_slots: int = 8,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.folded = folded
        self.batch = batch_slots
        self.max_len = max_len
        self.cache = S.init_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.key = jax.random.PRNGKey(seed)

        def decode_step(folded, cache, tok, pos):
            return S.serve_forward(cfg, folded, tok, cache=cache,
                                   pos_offset=pos, mode="decode")

        self._decode = jax.jit(decode_step, donate_argnums=(1,))

    def _step(self, tokens_col: np.ndarray, pos_scalar: int):
        tok = jnp.asarray(tokens_col).reshape(self.batch, 1)
        logits, self.cache = self._decode(self.folded, self.cache, tok,
                                          jnp.int32(pos_scalar))
        return logits[:, -1] if logits.ndim == 3 else logits[:, :, -1]

    def generate(self, requests: List[Request]) -> List[Request]:
        """Lockstep decode for a batch of same-length-padded prompts."""
        assert len(requests) <= self.batch
        max_prompt = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((self.batch, max_prompt), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        outs = [[] for _ in requests]
        # prefill via lockstep decode (works uniformly for attn/ssm/hybrid)
        last_logits = None
        for t in range(max_prompt):
            last_logits = self._step(toks[:, t], t)
        cur = np.asarray(jnp.argmax(last_logits, -1)).astype(np.int32)
        for i in range(len(requests)):
            outs[i].append(int(cur[i]))
        for t in range(max_prompt, max_prompt + max_new - 1):
            logits = self._step(cur, t)
            if any(r.temperature > 0 for r in requests):
                self.key, sub = jax.random.split(self.key)
                samp = jax.random.categorical(sub, logits / max(
                    requests[0].temperature, 1e-4), -1)
                cur = np.asarray(samp).astype(np.int32)
            else:
                cur = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            for i in range(len(requests)):
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(cur[i]))
        for r, o in zip(requests, outs):
            r.out = np.asarray(o, np.int32)
        return requests
