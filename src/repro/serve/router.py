"""SLO-aware data-parallel router over N engine replicas.

The router is a thin, deterministic dispatch layer speaking the same
event-driven protocol as a single :class:`~repro.serve.engine.Engine`
(``submit`` / ``cancel`` / ``poll`` / ``has_work`` / ``stats``), so the
asyncio server and ``serve_bench.py`` drive either interchangeably.  Each
replica is an independent Engine (internally TP-sharded or not); the
router holds a bounded FIFO queue in front of them and makes one
admission decision per queued request per tick:

* **dispatch** when some replica is *admissible* — its ``stats()`` gauges
  show queue depth at or under ``max_replica_waiting``, prefill backlog
  at or under ``max_replica_chunks``, and (paged) at least
  ``min_free_pages`` pages free.  Among admissible replicas the least
  loaded wins, compared lexicographically on
  ``(waiting, prefill_chunks_pending, -pages_free, index)`` — the index
  tiebreak keeps placement deterministic, which is what makes a routed
  run token-identical to a single-engine run on the same trace.
* **queue** when no replica is admissible: the head request waits (FIFO
  is never reordered — later requests do not jump the line).
* **shed** queued requests whose ``deadline_tick`` passes before
  dispatch, through the same CANCELLED/"deadline" exit the engine uses.
* **reject** at ``submit`` when the bounded queue is full —
  :class:`RouterBusy` is the backpressure signal the asyncio frontend
  turns into an HTTP-busy style error instead of letting the tail grow.

Ticks: ``poll()`` polls every replica exactly once, so for replicas
constructed fresh for this router (the supported configuration) replica
tick counters advance in lockstep with the router's own and
``deadline_tick`` means the same thing queued or dispatched.

Token identity holds for greedy requests (``temperature == 0``): a
replica computes the same tokens for a request regardless of which other
requests share its batch.  Sampled requests draw from per-replica PRNG
streams and are excluded from the contract, exactly as they are from the
single-engine identity benches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serve import stats as stats_schema
from repro.serve.engine import Request, RequestStatus, TokenEvent


class RouterBusy(RuntimeError):
    """Submission refused: the router's bounded queue is full."""


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Admission knobs. The defaults dispatch eagerly (a replica with an
    empty queue and any free pages is admissible) and bound only the
    router queue; tighten them to shed earlier under overload."""
    max_queue: int = 64            # router queue bound (submit -> RouterBusy)
    max_replica_waiting: int = 0   # dispatch only if replica waiting <= this
    max_replica_chunks: int = 8    # ... and prefill_chunks_pending <= this
    min_free_pages: int = 1        # ... and pages_free >= this (paged only)

    def validate(self) -> "RouterConfig":
        if self.max_queue < 1:
            raise ValueError("RouterConfig.max_queue must be >= 1")
        if self.max_replica_waiting < 0 or self.max_replica_chunks < 0 \
                or self.min_free_pages < 0:
            raise ValueError("RouterConfig thresholds must be >= 0")
        return self


class ReplicaRouter:
    """Dispatch requests across engine replicas; see the module docstring
    for the admission policy.  Request ids handed out by the router are
    global; per-replica engine rids are internal."""

    def __init__(self, replicas: List, config: Optional[RouterConfig] = None):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        self.replicas = list(replicas)
        self.config = (config or RouterConfig()).validate()
        self.queue: List[tuple] = []       # [(grid, Request)] FIFO
        self.requests: Dict[int, Request] = {}   # live (queued + inflight)
        # per-replica engine-rid -> global-rid translation
        self._rev: List[Dict[int, int]] = [dict() for _ in self.replicas]
        self._next_rid = 0
        self._events: List[TokenEvent] = []
        self.counters = {k: 0 for k in stats_schema.ROUTER_COUNTERS}

    # --- protocol: submit / cancel ---------------------------------------

    def submit(self, request: Request) -> int:
        if len(self.queue) >= self.config.max_queue:
            self.counters["rejected"] += 1
            raise RouterBusy(
                f"router queue full ({self.config.max_queue}); retry later")
        grid = self._next_rid
        self._next_rid += 1
        request.rid = grid
        request.status = RequestStatus.WAITING
        request.finish_reason = None
        request.out = None
        self.queue.append((grid, request))
        self.requests[grid] = request
        self.counters["submitted"] += 1
        return grid

    def cancel(self, grid: int) -> bool:
        """Cancel wherever the request lives.  Queued: terminal here, event
        on the next poll.  Dispatched: forwarded to the owning replica,
        whose terminal event flows back translated."""
        req = self.requests.get(grid)
        if req is None:
            return False
        for i, (g, _r) in enumerate(self.queue):
            if g == grid:
                del self.queue[i]
                self.requests.pop(grid)
                self._terminate(req, RequestStatus.CANCELLED, "cancelled")
                self.counters["cancelled"] += 1
                return True
        for i, rev in enumerate(self._rev):
            for erid, g in rev.items():
                if g == grid:
                    ok = self.replicas[i].cancel(erid)
                    if ok:
                        self.counters["cancelled"] += 1
                    return ok
        raise AssertionError(f"rid {grid} tracked but neither queued "
                             f"nor dispatched")

    def _terminate(self, req: Request, status: RequestStatus, reason: str):
        req.out = np.asarray([], np.int32)
        req.status = status
        req.finish_reason = reason
        self._events.append(TokenEvent(req.rid, None, 0, True, reason))

    # --- admission --------------------------------------------------------

    def _admissible(self, stats: Dict) -> bool:
        c = self.config
        if stats["waiting"] > c.max_replica_waiting:
            return False
        if stats["prefill_chunks_pending"] > c.max_replica_chunks:
            return False
        if "pages_free" in stats and stats["pages_free"] < c.min_free_pages:
            return False
        return True

    def _shed_expired(self):
        t = self.counters["ticks"]
        for grid, req in [q for q in self.queue]:
            if req.deadline_tick is None or t < req.deadline_tick:
                continue
            self.queue.remove((grid, req))
            self.requests.pop(grid)
            self._terminate(req, RequestStatus.CANCELLED, "deadline")
            self.counters["shed_deadline"] += 1

    def _dispatch(self):
        """Place queued requests head-first onto the least-loaded
        admissible replica; stop at the first head that doesn't fit (FIFO:
        nothing jumps the line)."""
        while self.queue:
            snaps = [eng.stats() for eng in self.replicas]
            cands = [(s["waiting"], s["prefill_chunks_pending"],
                      -s.get("pages_free", 0), i)
                     for i, s in enumerate(snaps) if self._admissible(s)]
            if not cands:
                return
            i = min(cands)[3]
            grid, req = self.queue.pop(0)
            try:
                erid = self.replicas[i].submit(req)
            except ValueError as e:
                # the request can never run (too big for any replica built
                # like this one): FAILED, not retried elsewhere
                self.requests.pop(grid)
                req.rid = grid
                req.out = np.asarray([], np.int32)
                req.status = RequestStatus.FAILED
                req.finish_reason = f"error: {e}"
                self._events.append(
                    TokenEvent(grid, None, 0, True, req.finish_reason))
                continue
            req.rid = grid                 # engine stamped its local rid
            self._rev[i][erid] = grid
            self.counters["dispatched"] += 1

    # --- the tick ---------------------------------------------------------

    def poll(self) -> List[TokenEvent]:
        """One router tick: shed expired queued requests, dispatch while
        replicas are admissible, then poll every replica once and return
        the merged, rid-translated event stream."""
        self.counters["ticks"] += 1
        self._shed_expired()
        self._dispatch()
        events = self._events
        self._events = []
        for i, eng in enumerate(self.replicas):
            rev = self._rev[i]
            for e in eng.poll():
                grid = rev.get(e.rid)
                if grid is None:           # replica-local traffic, not ours
                    continue
                if e.final:
                    del rev[e.rid]
                    self.requests.pop(grid, None)
                    if e.finish_reason in ("length", "eos"):
                        self.counters["completed"] += 1
                events.append(dataclasses.replace(e, rid=grid))
        return events

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._events) \
            or any(rev for rev in self._rev) \
            or any(eng.has_work for eng in self.replicas)

    def stats(self) -> Dict:
        """Router gauges + counters wrapping each replica's payload;
        validated against the frozen ``repro.serve.stats`` schema."""
        s = {
            "schema_version": stats_schema.STATS_SCHEMA_VERSION,
            "queued": len(self.queue),
            "inflight": sum(len(rev) for rev in self._rev),
            "n_replicas": len(self.replicas),
            "replicas": [eng.stats() for eng in self.replicas],
            "counters": dict(self.counters),
        }
        return stats_schema.validate_router_stats(s)
