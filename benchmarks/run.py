# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py                   # all tables
#   python benchmarks/run.py --tables table1,table3   # CI smoke subset
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks import tables

    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default=None,
                    help="comma-separated prefixes (table1..table4); "
                         "default: all")
    args = ap.parse_args()

    fns = [tables.table1_compression, tables.table2_ablation,
           tables.table3_kernel_scaling, tables.table4_latency]
    if args.tables:
        keep = tuple(args.tables.split(","))
        fns = [fn for fn in fns if fn.__name__.startswith(keep)]
        if not fns:
            sys.exit(f"--tables {args.tables!r} matched nothing "
                     f"(valid prefixes: table1..table4)")

    all_rows = []
    for fn in fns:
        try:
            all_rows.extend(fn())
        except Exception as e:  # noqa: BLE001
            all_rows.append((f"{fn.__name__}/ERROR", 0.0,
                             f"{type(e).__name__}:{e}"))
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
