"""xLSTM-1.3B  [arXiv:2405.04517] — 7:1 mLSTM:sLSTM, no separate FFN."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    block_pattern=("s", "x", "x", "x", "x", "x", "x", "x"),
    norm_type="layernorm", param_dtype="bfloat16",
))
