"""Production mesh construction.

Single pod: 16 x 16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — 'pod' composes
with 'data' for gradient reduction / batch sharding; XLA emits hierarchical
collectives (reduce-scatter on ICI inside the pod, all-reduce across DCN).

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever-fits mesh for CPU tests: (1, n_devices//model, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
