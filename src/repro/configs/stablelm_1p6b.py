"""StableLM-2 1.6B  [hf:stabilityai/stablelm-2-1_6b] — LayerNorm + partial RoPE."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100_352,
    norm_type="layernorm", partial_rotary=0.25,
    rope_theta=10_000.0, param_dtype="bfloat16",
))
