"""BERT-base — the paper's own model (SST-2/MNLI operating point)."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="bert-base", family="encoder",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=30_522,
    causal=False, learned_pos=True, max_position=512,
    norm_type="layernorm", act="gelu",
))
