"""Quickstart: quantize a model with the FQ pipeline and compare fp32 vs
fully-integer inference in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models import fold as F
from repro.models import serve_int as S

cfg = smoke_config("yi-6b")                       # any --arch id works
key = jax.random.PRNGKey(0)
params = T.init_params(cfg, key)                  # float master weights
amax = T.init_amax(cfg)                           # EMA calibration state

toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)

# 1. QAT/calibration forward: observes activation maxima (paper Eq. 3)
logits_f, obs, _ = T.forward(cfg, params, amax, toks)

# 2. fold to the integer serving form (paper Eq. 1-5): int4 packed weights,
#    int32 biases, fixed-point requant multipliers, LUT tables
folded = F.fold_params(cfg, params, obs)

# 3. fully-integer inference
logits_i, _ = S.serve_forward(cfg, folded, toks, mode="prefill")

pf = jax.nn.softmax(logits_f, -1)
kl = jnp.mean(jnp.sum(pf * (jax.nn.log_softmax(logits_f, -1)
                            - jax.nn.log_softmax(logits_i, -1)), -1))
print(f"fp-vs-integer KL: {float(kl):.5f}")
print(f"argmax agreement: "
      f"{float((logits_f.argmax(-1) == logits_i.argmax(-1)).mean()):.3f}")
