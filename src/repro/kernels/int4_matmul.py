"""W4A8 tiled matmul Pallas kernel — the TPU realization of the paper's PE/BIM.

The accelerator's job split, re-expressed for TPU:

* HBM holds weights **nibble-packed** (two int4 codes per byte, K-planar
  layout = the paper's Type-A BIM data rearrangement): half the weight-stream
  bytes of an int8 model, 1/4 of bf16 — this is where the 7.94x compression
  pays at serving time.
* The Pallas grid pipeline double-buffers packed tiles HBM->VMEM (the paper's
  double-buffered weight buffer overlapping AXI transfers).
* In VMEM each packed tile is sign-extended into two int8 nibble planes and
  fed to the MXU (the BIM's 8x4 multipliers; the MXU consumes int8, so a
  4-bit value rides for free).
* The int32 accumulator lives in a VMEM scratch across the K grid dimension
  (the paper's Psum Buf), and the epilogue on the last K step adds the int32
  bias and applies the 32-bit fixed-point requantizer (paper Eq. 5) — the
  "quantization module" after the accumulator in Fig. 2.

The 8x8 path (``int8_bitsplit``) computes an 8-bit-weight matmul as two
nibble matmuls combined by shift-add — bit-for-bit the BIM Type-A identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

from repro.core import fixedpoint as fxp

# Default MXU-aligned tile sizes (v5e: 128x128 MXU, ~16 MB VMEM/core).
BM, BN, BK2 = 128, 128, 256  # BK2 = packed K rows per step = BK // 2


def _sign_extend(nib: jax.Array) -> jax.Array:
    """uint4-in-int32 [0,15] -> signed [-8,7] (branch-free)."""
    return ((nib ^ 8) - 8).astype(jnp.int8)


def _int4_matmul_kernel(x_lo_ref, x_hi_ref, w_ref, b_ref, m_ref, s_ref,
                        o_ref, acc_ref):
    k_i = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w32 = w_ref[...].astype(jnp.int32)
    w_lo = _sign_extend(w32 & 15)        # rows [0, K/2): low-nibble plane
    w_hi = _sign_extend((w32 >> 4) & 15) # rows [K/2, K): high-nibble plane
    dn = (((1,), (0,)), ((), ()))
    acc = jax.lax.dot_general(x_lo_ref[...], w_lo, dn,
                              preferred_element_type=jnp.int32)
    acc += jax.lax.dot_general(x_hi_ref[...], w_hi, dn,
                               preferred_element_type=jnp.int32)
    acc_ref[...] += acc

    @pl.when(k_i == nk - 1)
    def _epilogue():
        total = acc_ref[...] + b_ref[...].astype(jnp.int32)
        y = fxp.requantize(total, m_ref[0], s_ref[0], bits=8)
        o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk2", "interpret"))
def int4_matmul(
    x_i8: jax.Array,      # int8 (M, K)
    w_packed: jax.Array,  # uint8 (K//2, N) K-planar packed
    bias_i32: jax.Array,  # int32 (N,)
    M_q: jax.Array,       # () or (1,) int32 fixed-point multiplier
    shift_q: jax.Array,
    *,
    bm: int = BM,
    bn: int = BN,
    bk2: int = BK2,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_i8.shape
    k2, n = w_packed.shape
    assert k == 2 * k2, (k, k2)
    bm = min(bm, m)
    bn = min(bn, n)
    bk2 = min(bk2, k2)
    assert m % bm == 0 and n % bn == 0 and k2 % bk2 == 0, (m, n, k2, bm, bn, bk2)
    nk = k2 // bk2
    grid = (m // bm, n // bn, nk)

    return pl.pallas_call(
        _int4_matmul_kernel,
        grid=grid,
        in_specs=[
            # x column blocks [0, K/2) — pair row r of the packed tile
            pl.BlockSpec((bm, bk2), lambda i, j, t: (i, t)),
            # x column blocks [K/2, K) — pair row r's HIGH nibbles
            pl.BlockSpec((bm, bk2), lambda i, j, t, nk=nk: (i, t + nk)),
            pl.BlockSpec((bk2, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bn,), lambda i, j, t: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_i8, x_i8, w_packed, bias_i32,
      jnp.asarray(M_q, jnp.int32).reshape(1), jnp.asarray(shift_q, jnp.int32).reshape(1))


def _bitsplit_kernel(x_ref, w_ref, b_ref, m_ref, s_ref, o_ref, acc_ref):
    k_i = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w32 = w_ref[...].astype(jnp.int32)
    hi = (w32 >> 4).astype(jnp.int8)   # signed high nibble (arithmetic shift)
    lo = (w32 & 15).astype(jnp.int8)   # unsigned low nibble
    x = x_ref[...]
    dn = (((1,), (0,)), ((), ()))
    acc_hi = jax.lax.dot_general(x, hi, dn, preferred_element_type=jnp.int32)
    acc_lo = jax.lax.dot_general(x, lo, dn, preferred_element_type=jnp.int32)
    acc_ref[...] += (acc_hi << 4) + acc_lo   # BIM Type-A shift-add

    @pl.when(k_i == nk - 1)
    def _epilogue():
        total = acc_ref[...] + b_ref[...].astype(jnp.int32)
        o_ref[...] = fxp.requantize(total, m_ref[0], s_ref[0], bits=8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_bitsplit_matmul(
    x_i8: jax.Array,   # int8 (M, K)
    w_i8: jax.Array,   # int8 (K, N)
    bias_i32: jax.Array,
    M_q: jax.Array,
    shift_q: jax.Array,
    *,
    bm: int = BM,
    bn: int = BN,
    bk: int = 2 * BK2,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_i8.shape
    k_, n = w_i8.shape
    assert k == k_
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _bitsplit_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bn,), lambda i, j, t: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_i8, w_i8, bias_i32,
      jnp.asarray(M_q, jnp.int32).reshape(1), jnp.asarray(shift_q, jnp.int32).reshape(1))
