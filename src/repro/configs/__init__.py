"""Per-architecture configs (exact published dims) + smoke reductions."""
from repro.configs import (  # noqa: F401
    qwen2_moe_a2p7b, mixtral_8x22b, llama3_405b, qwen3_4b, yi_6b,
    stablelm_1p6b, jamba_1p5_large, xlstm_1p3b, qwen2_vl_2b,
    musicgen_medium, bert_base,
)
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, get_config, list_configs,
    cell_is_runnable, LONG_CONTEXT_OK,
)
from repro.configs.smoke import smoke_config  # noqa: F401
