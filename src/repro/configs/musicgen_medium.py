"""MusicGen-medium  [arXiv:2306.05284] — decoder over 4 EnCodec codebooks
(delay pattern); codebook embeddings summed, 4 parallel LM heads."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    norm_type="layernorm", act="gelu", learned_pos=True, max_position=32_768,
    frontend="audio_codebooks", n_codebooks=4, n_lm_heads=4,
    param_dtype="bfloat16",
))
