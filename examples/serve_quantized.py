"""Continuous-batching serving example: calibrate, fold to integers, then
stream mixed-length requests through the slot-table engine (quantized KV
cache, one-shot integer prefill, per-slot positions, greedy + temperature).

    PYTHONPATH=src python examples/serve_quantized.py --arch yi-6b
    PYTHONPATH=src python examples/serve_quantized.py --arch mixtral-8x22b
"""
import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import calibrated_folded
from repro.serve.engine import EngineConfig, Request, make_engine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="yi-6b")
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--requests", type=int, default=10)
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = smoke_config(args.arch)
key = jax.random.PRNGKey(0)
calib = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
folded = calibrated_folded(cfg, key, calib)

eng = make_engine(cfg, folded, EngineConfig(batch_slots=args.slots,
                                            max_len=128))
rng = np.random.default_rng(0)
# more requests than slots: the scheduler streams them through mid-flight
reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                    (int(rng.integers(4, 24)),)
                                    ).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)]
for i, r in enumerate(eng.generate(reqs)):
    print(f"req{i}: prompt[{len(r.prompt)}]={r.prompt[:6].tolist()}.. "
          f"-> {r.out.tolist()}")
if hasattr(eng, "stats"):
    print(f"engine stats: {eng.stats()}")
