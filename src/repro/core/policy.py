"""Quantization policy — which parts of the model are quantized, at what width.

The flags mirror the rows of paper Table II exactly, so the ablation benchmark
is just a sweep over policies:

    row 1: POLICY_FP32          (nothing quantized)
    row 2: w/a                  (weights 4b + activations 8b)
    row 3: w/a + scale          (+ scale factors to 8-significant-bit fixed pt)
    row 4: w/a + scale + softmax(+ LUT softmax)
    row 5: FULL (paper FQ-BERT) (+ integer layernorm)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    quantize_wa: bool = True        # weights + activations
    quantize_scale: bool = True     # scale factors to 8-bit precision
    quantize_softmax: bool = True   # LUT softmax
    quantize_layernorm: bool = True # integer LN / RMSNorm
    w_bits: int = 4
    a_bits: int = 8
    kv_bits: int = 8                # quantized KV cache (beyond paper: serving)
    per_channel_w: bool = False     # beyond-paper option; paper = per-tensor
    ema_decay: float = 0.99
    grad_compress_bits: int = 0     # 0 = off; 8 = int8 DP gradient all-reduce

    @property
    def any_quant(self) -> bool:
        return self.quantize_wa or self.quantize_softmax or self.quantize_layernorm


POLICY_FP32 = QuantPolicy(False, False, False, False)
POLICY_WA = QuantPolicy(True, False, False, False)
POLICY_WA_SCALE = QuantPolicy(True, True, False, False)
POLICY_WA_SCALE_SM = QuantPolicy(True, True, True, False)
POLICY_FQ = QuantPolicy()                      # full FQ-BERT (paper row 5)
POLICY_W8A8 = QuantPolicy(w_bits=8)            # Q8BERT-style comparison point

TABLE2_ROWS = [
    ("fp32", POLICY_FP32),
    ("w/a", POLICY_WA),
    ("w/a+scale", POLICY_WA_SCALE),
    ("w/a+scale+softmax", POLICY_WA_SCALE_SM),
    ("full (FQ-BERT)", POLICY_FQ),
]


def quantize_scale_8bit(s: float) -> float:
    """Model the paper's 8-bit scale factors: keep 8 significant bits.

    s -> nearest value of form m * 2^e with m an 8-bit integer.  Applied to
    s_a/s_w/s_y when policy.quantize_scale is on, so the requantization
    multiplier carries only 8 bits of precision (Table II row 3).
    """
    import math

    if s <= 0:
        return s
    e = math.floor(math.log2(s)) - 7
    m = round(s / (2.0**e))
    return m * (2.0**e)
