"""Mixture-of-Experts layer (GShard-style capacity dispatch) with QAT hooks.

Router logits stay fp32 (top-k argmax is quantization-hostile; DESIGN.md §4);
expert FFNs are W4A8 like every other linear.  Dispatch/combine use the
classic dense one-hot einsum formulation, which shards cleanly on TPU:
experts dim over the ``model`` mesh axis (EP), tokens over ``data``.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core import quant as q
from repro.models.layers import Obs, fake_quant_act

CAPACITY_FACTOR = 1.25


def capacity(tokens: int, n_experts: int, top_k: int, factor=CAPACITY_FACTOR) -> int:
    c = int(math.ceil(tokens * top_k * factor / n_experts))
    return max(c, top_k, 4)


def topk_routing(gate_logits: jax.Array, top_k: int, cap: int):
    """Scatter-based routing plan (NO (T,E,C) one-hot tensor — the classic
    GShard dispatch einsum costs O(T*E*C*d) phantom FLOPs, measured 400x the
    useful compute on qwen2-moe; see EXPERIMENTS.md §Perf iteration 2).

    Returns per-choice flat destinations and weights:
      dest   (k, T) int32 in [0, E*cap)  (capacity-dropped -> E*cap sentinel)
      gates  (k, T) f32 renormalized combine weights
      aux    load-balancing loss
    """
    t, e = gate_logits.shape
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), -1)
    remaining = probs
    fill = jnp.zeros((e,), jnp.int32)
    dests, gates = [], []
    load = jnp.zeros((e,), jnp.float32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, -1)                       # (T,)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)
        gate = jnp.sum(probs * onehot, -1)
        pos = fill[None, :] + jnp.cumsum(onehot, 0).astype(jnp.int32) - 1
        pos_tok = jnp.sum(pos * onehot.astype(jnp.int32), -1)  # (T,)
        keep = (pos_tok < cap) & (pos_tok >= 0)
        dest = jnp.where(keep, idx * cap + pos_tok, e * cap)   # drop -> sentinel
        dests.append(dest.astype(jnp.int32))
        gates.append(jnp.where(keep, gate, 0.0))
        load = load + jnp.sum(onehot * keep[:, None], 0)
        fill = fill + jnp.sum(onehot * keep[:, None], 0).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)
    me = jnp.mean(probs, 0)
    ce = load / t
    aux = e * jnp.sum(me * ce) / max(top_k, 1)
    g = jnp.stack(gates)                                       # (k, T)
    denom = jnp.maximum(g.sum(0, keepdims=True), 1e-9)
    return jnp.stack(dests), g / denom, aux


def scatter_dispatch(x, dest, e, cap):
    """x (T, d), dest (k, T) -> xe (E*cap, d) via scatter-add: O(T*k*d)."""
    t, d = x.shape
    k = dest.shape[0]
    xe = jnp.zeros((e * cap + 1, d), x.dtype)
    for i in range(k):
        xe = xe.at[dest[i]].add(x, mode="drop",
                                unique_indices=False)
    return xe[:-1]                                             # drop sentinel row


def gather_combine(ye_flat, dest, gates, dtype):
    """ye_flat (E*cap, d), dest/gates (k, T) -> y (T, d): O(T*k*d)."""
    k, t = dest.shape
    yp = jnp.concatenate([ye_flat, jnp.zeros_like(ye_flat[:1])], 0)
    y = 0.0
    for i in range(k):
        y = y + gates[i][:, None] * jnp.take(yp, dest[i], axis=0)
    return y.astype(dtype)


def _expert_ffn_qat(xe, p, amax, policy: QuantPolicy, prefix: str):
    """xe (E, C, d); stacked expert weights (E, d, f)/(E, f, d)."""
    obs: Obs = {}

    def fq_w(w):
        if not policy.quantize_wa:
            return w
        wm = jax.lax.stop_gradient(q.per_tensor_max(w))
        return q.fake_quant(w, wm.astype(w.dtype), policy.w_bits)

    xq, obs[f"{prefix}_in"] = fake_quant_act(
        xe, amax[f"{prefix}_in"], policy.a_bits, policy.quantize_wa)
    g = jnp.einsum("ecd,edf->ecf", xq, fq_w(p["wg"]))
    u = jnp.einsum("ecd,edf->ecf", xq, fq_w(p["wu"]))
    g, obs[f"{prefix}_g"] = fake_quant_act(
        jax.nn.silu(g), amax[f"{prefix}_g"], policy.a_bits, policy.quantize_wa)
    u, obs[f"{prefix}_u"] = fake_quant_act(
        u, amax[f"{prefix}_u"], policy.a_bits, policy.quantize_wa)
    h = g * u
    h, obs[f"{prefix}_h"] = fake_quant_act(
        h, amax[f"{prefix}_h"], policy.a_bits, policy.quantize_wa)
    y = jnp.einsum("ecf,efd->ecd", h, fq_w(p["wd"]))
    return y, obs


def moe_qat(
    x: jax.Array,                # (B, S, d)
    p: Dict,
    amax: Dict[str, jax.Array],
    policy: QuantPolicy,
    cfg,
) -> Tuple[jax.Array, Obs, jax.Array]:
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    obs: Obs = {}
    gate_logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    cap = capacity(t, cfg.n_experts, cfg.top_k)
    dest, gates, aux = topk_routing(gate_logits, cfg.top_k, cap)
    xe = scatter_dispatch(xt, dest, cfg.n_experts, cap)
    xe = xe.reshape(cfg.n_experts, cap, d)
    ye, eobs = _expert_ffn_qat(xe, p["experts"], amax, policy, "exp")
    obs.update(eobs)
    yt = gather_combine(ye.reshape(cfg.n_experts * cap, d), dest, gates,
                        x.dtype)
    if cfg.n_shared_experts:
        xs = xt[None]                                            # (1, T, d)
        xsb = jnp.broadcast_to(xs, (cfg.n_shared_experts, t, d))
        ys, sobs = _expert_ffn_qat(xsb, p["shared"], amax, policy, "shr")
        obs.update(sobs)
        yt = yt + jnp.sum(ys, 0)
    return yt.reshape(b, s, d), obs, aux


MOE_SITES = ("exp_in", "exp_g", "exp_u", "exp_h")
MOE_SHARED_SITES = ("shr_in", "shr_g", "shr_u", "shr_h")
