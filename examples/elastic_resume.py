"""Fault-tolerance / elasticity demo: train, 'crash', resume on a DIFFERENT
mesh (elastic resize) with bit-exact state restoration.

    PYTHONPATH=src python examples/elastic_resume.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as ck
from repro.train.trainer import TrainerConfig, train

cfg = smoke_config("yi-6b", n_layers=2, d_model=64, vocab_size=128)
shape = ShapeConfig("demo", 32, 4, "train")
opt = AdamWConfig(lr=1e-3)
ckdir = tempfile.mkdtemp(prefix="elastic_")

# phase 1: train 6 steps on a (1,1) mesh, checkpoint every 3
mesh1 = jax.make_mesh((1, 1), ("data", "model"))
t1 = TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=ckdir, log_every=2)
log = lambda s, m: print(f"  step {s} loss {m['loss']:.4f}")
print("phase 1 (mesh 1x1):")
train(cfg, shape, mesh1, opt, t1, fsdp=False, log_fn=log)
print(f"checkpoints: {sorted(p.name for p in __import__('pathlib').Path(ckdir).iterdir())}")

# phase 2: 'crash' happened; resume on a DIFFERENT mesh shape
n = len(jax.devices())
mesh2 = jax.make_mesh((n, 1), ("data", "model"))
print(f"phase 2 (elastic resume on mesh {n}x1):")
t2 = TrainerConfig(steps=10, ckpt_every=5, ckpt_dir=ckdir, log_every=2)
state, hist = train(cfg, shape, mesh2, opt, t2, fsdp=False, log_fn=log)
print(f"resumed and finished at step {int(state.step)} "
      f"(ran {len(hist)} new steps — exactly-once data)")
