"""Continuous-batching engine: scheduler mechanics, token-for-token
equivalence with the lockstep baseline, and mid-flight admission."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import Engine, LockstepEngine, Request
from repro.serve.scheduler import Scheduler

KEY = jax.random.PRNGKey(0)


# --- scheduler unit tests -----------------------------------------------------

def test_scheduler_fifo_admission_and_eviction():
    s = Scheduler(2)
    rids = [s.submit(f"req{i}") for i in range(4)]
    assert rids == [0, 1, 2, 3]
    placed = s.admit()
    assert [(b, st.rid) for b, st in placed] == [(0, 0), (1, 1)]
    assert s.n_free == 0 and len(s.waiting) == 2
    assert s.admit() == []                     # table full -> no-op
    s.evict(0)
    placed = s.admit()                         # freed slot takes next FIFO
    assert [(b, st.rid) for b, st in placed] == [(0, 2)]
    assert s.active == [0, 1]
    s.evict(0)
    s.evict(1)
    placed = s.admit()
    assert [(b, st.rid) for b, st in placed] == [(0, 3)]
    s.evict(0)
    assert not s.has_work


def test_scheduler_evict_empty_slot_asserts():
    s = Scheduler(1)
    with pytest.raises(AssertionError):
        s.evict(0)


# --- engine equivalence -------------------------------------------------------

def _folded(cfg):
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return F.fold_params(cfg, params, obs)


def _mixed_requests(cfg, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, (ln,)
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for ln, mn in zip(lens, max_news)]


def test_continuous_matches_lockstep_token_for_token():
    """Greedy continuous batching (one-shot prefill, per-slot positions,
    mid-flight admission) must reproduce, per request, exactly what the
    lockstep engine produces for that request alone."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    lens = [3, 11, 6, 17, 5]
    max_news = [4, 6, 5, 3, 6]

    lock = LockstepEngine(cfg, folded, batch_slots=1, max_len=64)
    truth = []
    for r in _mixed_requests(cfg, lens, max_news):
        lock.reset()
        truth.append(lock.generate([r])[0].out.tolist())

    eng = Engine(cfg, folded, batch_slots=2, max_len=64, prefill_bucket=4)
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    got = [r.out.tolist() for r in out]
    assert got == truth
    # more requests than slots -> the scheduler really streamed them
    assert eng.stats["completed"] == len(lens)
    assert eng.stats["oneshot_prefills"] == len(lens)
    assert eng.stats["loop_prefill_steps"] == 0


def test_engine_streaming_admission_and_determinism():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=2, max_len=64)

    def run():
        eng.reset()
        reqs = _mixed_requests(cfg, [4, 9, 6, 5], [5, 5, 5, 5], seed=3)
        return [r.out.tolist() for r in eng.generate(reqs)]

    a, b = run(), run()
    assert a == b                       # greedy decode is deterministic
    assert all(len(o) == 5 for o in a)


def test_engine_eos_eviction_frees_slot():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=1, max_len=64)
    # discover the greedy continuation, then rerun with it as the EOS token
    probe = _mixed_requests(cfg, [5, 7], [6, 6], seed=1)
    out = eng.generate(probe)
    eos = int(out[0].out[2])            # third emitted token of request 0
    eng.reset()
    reqs = _mixed_requests(cfg, [5, 7], [6, 6], seed=1)
    reqs[0].eos_token = eos
    out2 = eng.generate(reqs)
    assert out2[0].out.tolist() == out[0].out.tolist()[:3]  # stopped at EOS
    assert out2[1].out.tolist() == out[1].out.tolist()      # unaffected
    assert eng.stats["completed"] == 2


def test_engine_rejects_overlong_request():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, batch_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(12, np.int32), max_new_tokens=8))


@pytest.mark.slow
def test_continuous_matches_lockstep_hybrid_arch():
    """Hybrid (attention+mamba) archs take the batch-1 decode-loop prefill
    path; outputs must still match the lockstep engine per request."""
    cfg = smoke_config("jamba-1.5-large-398b")
    folded = _folded(cfg)
    lens = [3, 7]
    max_news = [4, 4]

    lock = LockstepEngine(cfg, folded, batch_slots=1, max_len=32)
    truth = []
    for r in _mixed_requests(cfg, lens, max_news):
        lock.reset()
        truth.append(lock.generate([r])[0].out.tolist())

    eng = Engine(cfg, folded, batch_slots=2, max_len=32)
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    assert eng.stats["oneshot_prefills"] == 0
    assert eng.stats["loop_prefill_steps"] == sum(lens)
