"""On-demand page growth + preemption: forced-preemption runs must be
greedy-token-identical to unlimited-pool runs, spilled work must be
recoverable through the prefix registry, and sustained overload must not
starve any request.

The pool sizes here are chosen so the step loop *must* preempt: total
worst-case page demand exceeds capacity while every individual request
fits (``submit`` guarantees the latter, which is what makes the engine's
preemption loop always able to find pages after spilling victims).
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import Engine, EngineConfig, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def folded_cfg():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def _requests(cfg, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, (ln,)
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for ln, mn in zip(lens, max_news)]


def _truth(cfg, folded, lens, max_news, seed=0, **kw):
    """Unlimited-pool reference: same engine, default (ample) n_pages."""
    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           cache_layout="paged", page_size=4,
                                           **kw))
    out = eng.generate(_requests(cfg, lens, max_news, seed=seed))
    assert eng.counters["preemptions"] == 0      # really unlimited
    return [r.out.tolist() for r in out]


def _drive(eng, requests, max_ticks=3000):
    """Submit everything, step to completion under a tick cap (a cap-hit
    is a livelock — exactly what the starvation guard must rule out),
    asserting the stats invariants + allocator sweep every tick."""
    for r in requests:
        eng.submit(r)
    ticks = 0
    while eng.sched.has_work:
        assert ticks < max_ticks, "engine livelocked under preemption"
        ticks += 1
        eng.step()
        g = eng.stats(check=True)                # + allocator sweep
        assert g["decode_slots_active"] + g["prefill_slots"] \
            + g["free_slots"] == eng.batch
        assert g["pages_in_use"] + g["pages_free"] + g["pages_cached_lru"] \
            == g["pages_capacity"]
    return requests


def test_mid_decode_victim_token_identical(folded_cfg):
    """Two decode-heavy requests whose combined page demand overflows the
    pool: growth must spill the younger decoder and replay it to the exact
    same greedy tokens the unlimited pool produces."""
    cfg, folded = folded_cfg
    lens, max_news = [4, 4], [12, 12]            # worst 4 pages each
    truth = _truth(cfg, folded, lens, max_news)

    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=4,
        n_pages=6))                                  # capacity 5 < 4+4
    out = _drive(eng, _requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    c = eng.counters
    assert c["preemptions"] >= 1 and c["preempted_decode"] >= 1
    assert c["restores"] == c["preemptions"]     # every victim came back
    assert c["grown_pages"] >= 2                 # decode really grew pages
    assert c["spilled_rows"] > 0                 # victims held real rows
    assert eng.alloc.live == 0


def test_mid_prefill_victim_token_identical(folded_cfg):
    """A long prompt mid-chunked-prefill is the first-choice victim when an
    older decoder needs a page: spill at the chunk boundary, requeue, and
    replay through the ordinary chunk-continuation path — token identity
    against the unlimited pool."""
    cfg, folded = folded_cfg
    # the long prompt's 6 prompt pages fill the pool at admission, so the
    # older slots' FIRST decode growth already lands while it chunks
    lens, max_news = [4, 4, 24], [12, 12, 4]
    truth = _truth(cfg, folded, lens, max_news, max_prefill_chunk=4)

    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=3, max_len=64, cache_layout="paged", page_size=4,
        n_pages=9, max_prefill_chunk=4))         # capacity 8 < 4+4+7
    out = _drive(eng, _requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    c = eng.counters
    assert c["preempted_prefill"] >= 1           # the chunking slot spilled
    assert c["restores"] == c["preemptions"] >= 1
    assert c["completed"] == 3 and eng.alloc.live == 0


def test_restore_hits_prefix_registry(folded_cfg):
    """Spill registers the victim's finished pages; a prompt re-admission
    before allocation pressure reclaims them replays only the lost tail.
    Pool sized so the grower stops growing right after the spill: the
    victim's LRU pages survive and most spilled rows come back as a
    prefix hit instead of recompute."""
    cfg, folded = folded_cfg
    lens, max_news = [4, 12], [8, 4]
    truth = _truth(cfg, folded, lens, max_news, max_prefill_chunk=4)

    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=4,
        n_pages=7, max_prefill_chunk=4))         # capacity 6 < 3+4
    out = _drive(eng, _requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    c = eng.counters
    assert c["preempted_decode"] >= 1
    assert c["spilled_rows"] > 0
    # the registry gave most of the spill back: only the partial page past
    # the boundary was recomputed
    assert 0 < c["recomputed_tokens"] < c["spilled_rows"]
    assert c["prefix_hits"] >= 1                 # restore-as-cache-hit
    assert eng.alloc.live == 0


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_sustained_overload_every_request_finishes(folded_cfg, kv_bits):
    """Starvation guard: a queue several times the pool's worst-case
    capacity must drain completely — preemption recycles pages but
    requeue-at-front + head-of-line victim immunity keep every request
    progressing to completion with its full decode budget.  Runs at both
    KV precisions: the packed pool must survive the same spill/restore
    traffic (scales travel with their pages by construction)."""
    cfg, folded = folded_cfg
    n = 8
    lens, max_news = [4] * n, [8] * n            # worst 3 pages each
    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=4,
        n_pages=6, kv_bits=kv_bits))             # capacity 5
    out = _drive(eng, _requests(cfg, lens, max_news))
    assert eng.counters["completed"] == n
    assert all(r.out is not None and len(r.out) == 8 for r in out)
    assert eng.counters["preemptions"] >= 1      # it really was overload
    assert eng.alloc.live == 0 and len(eng.sched.waiting) == 0


def test_kv4_spill_restore_mechanics(folded_cfg):
    """kv_bits=4 under the forced mid-decode spill: the packed pool runs
    the identical grow/spill/registry/replay machinery (a page id names
    the packed payload AND its per-page scales, so nothing extra moves).

    Token identity against a kv4-unlimited run is deliberately NOT
    asserted: a replayed partial page is re-quantized with a whole-page
    prefill scale while the original run froze the scale at the page's
    first decode row — kv4 is a quality A/B contract, identity stays
    int8-only.  What must hold: full completion, balanced counters, an
    empty pool at drain, and every request receiving its full budget."""
    cfg, folded = folded_cfg
    lens, max_news = [4, 4], [12, 12]            # worst 4 pages each
    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=4,
        n_pages=6, kv_bits=4))                   # capacity 5 < 4+4
    out = _drive(eng, _requests(cfg, lens, max_news))
    c = eng.counters
    assert c["completed"] == 2
    assert all(len(r.out) == 12 for r in out)
    assert c["preemptions"] >= 1 and c["restores"] == c["preemptions"]
    assert c["spilled_rows"] > 0
    assert eng.alloc.live == 0
    # packed pages really are half-width (plus two fp32 scales)
    eng8 = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=4,
        n_pages=6))
    assert eng.alloc.bytes_per_page < eng8.alloc.bytes_per_page * 0.6


def test_full_reservation_policy_never_preempts(folded_cfg):
    """reserve_policy="full" keeps the PR-2 contract under the same
    overload: admission waits, decode never grows, nothing is spilled."""
    cfg, folded = folded_cfg
    lens, max_news = [4, 4, 4], [12, 12, 12]
    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=4,
        n_pages=6, reserve_policy="full"))
    out = _drive(eng, _requests(cfg, lens, max_news))
    c = eng.counters
    assert c["completed"] == 3
    assert c["preemptions"] == 0 and c["grown_pages"] == 0
    assert c["pool_wait_ticks"] > 0              # overload stalled admission
    assert all(len(r.out) == 12 for r in out)
