import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import (device count locks at first init).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both

Outputs one JSON per cell under results/dryrun/.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, get_config, cell_is_runnable, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.sharding import partition as Pt
from repro.train import steps as St

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend == "audio_codebooks":
            return {"tokens": jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), i32)}
        if cfg.frontend == "vision_stub":
            n_img = 256  # stub: 256 patch embeddings per example
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - n_img), i32),
                "extra_embeds": jax.ShapeDtypeStruct((b, n_img, cfg.d_model),
                                                     cfg.dtype),
                "pos3": jax.ShapeDtypeStruct((b, s, 3), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.frontend == "audio_codebooks":
            return {"tokens": jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a cache of seq_len
    if cfg.frontend == "audio_codebooks":
        return {"tokens": jax.ShapeDtypeStruct((b, cfg.n_codebooks, 1), i32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


# ---------------------------------------------------------------------------
# collective-bytes extraction from lowered/compiled HLO
# ---------------------------------------------------------------------------

_DT_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
             "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
             "f64": 8}
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def collective_stats(hlo_text: str):
    """Sum result sizes of every collective op in (post-SPMD) HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if m:
            shape_str, op = m.groups()
            out[op]["count"] += 1
            out[op]["bytes"] += _tensor_bytes(shape_str)
    return out


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------

def lower_train(cfg, shape, mesh, fsdp=True, accum_steps=4):
    opt_cfg = AdamWConfig(quantize_moments=True)
    batch = input_specs(cfg, shape)
    jitted, state_shard, batch_shard = St.jit_train_step(
        cfg, mesh, opt_cfg, batch, fsdp=fsdp, accum_steps=accum_steps)
    state_shape = jax.eval_shape(
        lambda k: St.init_train_state(cfg, k, opt_cfg), jax.random.PRNGKey(0))
    return jitted.lower(state_shape, batch)


def lower_serve(cfg, shape, mesh):
    from repro.models import fold as F
    from repro.models import serve_int as S
    from repro.models import transformer as T

    opt = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))
    amax_shape = jax.eval_shape(lambda: T.init_amax(cfg))
    folded_shape = jax.eval_shape(lambda p, a: F.fold_params(cfg, p, a),
                                  opt, amax_shape)
    f_shard = Pt.make_param_shardings(mesh, folded_shape, mode="serve")
    batch = input_specs(cfg, shape)
    tok_shard = Pt.batch_sharding(mesh, batch["tokens"].ndim,
                                  batch["tokens"].shape)

    if shape.kind == "prefill":
        def step(folded, tokens):
            logits, _ = S.serve_forward(cfg, folded, tokens, mode="prefill")
            return logits

        jitted = jax.jit(step, in_shardings=(f_shard, tok_shard),
                         out_shardings=tok_shard)
        return jitted.lower(folded_shape, batch["tokens"])

    cache_shape = jax.eval_shape(
        lambda: S.init_cache(cfg, shape.global_batch, shape.seq_len))
    c_shard = Pt.cache_sharding(mesh, cache_shape)

    def step(folded, cache, tokens, pos):
        logits, new_cache = S.serve_forward(
            cfg, folded, tokens, cache=cache, pos_offset=pos, mode="decode")
        return logits, new_cache

    jitted = jax.jit(step,
                     in_shardings=(f_shard, c_shard, tok_shard, None),
                     out_shardings=(tok_shard, c_shard),
                     donate_argnums=(1,))
    return jitted.lower(folded_shape, cache_shape, batch["tokens"],
                        jax.ShapeDtypeStruct((), jnp.int32))


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, fsdp=True,
             save=True, cfg_overrides=None, tag=""):
    shape = SHAPES[shape_name]
    cfg = get_config(arch, **(cfg_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "chips": n_chips, "fsdp": fsdp, "tag": tag}
    try:
        Pt.set_mesh_ctx(mesh)
        lowered = (lower_train(cfg, shape, mesh, fsdp=fsdp,
                               accum_steps=int(os.environ.get(
                                   "REPRO_ACCUM", "4")))
                   if shape.kind == "train"
                   else lower_serve(cfg, shape, mesh))
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                              getattr(mem, "temp_size_in_bytes", 0)),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals")}
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        # loop-aware (trip-count-scaled) costs — the roofline's real inputs
        try:
            from repro.analysis import hlo_cost
            hc = hlo_cost.analyze(hlo)
            hc.pop("loop_report", None)
            rec["hlo_cost"] = hc
        except Exception as e:  # noqa: BLE001
            rec["hlo_cost_error"] = str(e)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        Pt.set_mesh_ctx(None)
    rec["total_s"] = round(time.time() - t0, 1)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        mp = "multipod" if multi_pod else "pod"
        suffix = f"-{tag}" if tag else ""
        out = RESULTS / f"{arch}--{shape_name}--{mp}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {arch} x {shape_name} x {'2pod' if multi_pod else '1pod'}"
          f" lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s"
          + ("" if rec["ok"] else f"  {rec['error'][:200]}"), flush=True)
    return rec


ALL_ARCHS = [
    "qwen2-moe-a2.7b", "mixtral-8x22b", "llama3-405b", "qwen3-4b", "yi-6b",
    "stablelm-1.6b", "jamba-1.5-large-398b", "xlstm-1.3b", "qwen2-vl-2b",
    "musicgen-medium",
]
ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="off")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
    cells = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = ALL_SHAPES if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for sh in shapes:
            if not cell_is_runnable(a, sh):
                continue
            for mp in pods:
                cells.append((a, sh, mp))
    n_fail = 0
    for a, sh, mp in cells:
        rec = run_cell(a, sh, mp, fsdp=not args.no_fsdp, tag=args.tag)
        n_fail += 0 if rec["ok"] else 1
    print(f"done: {len(cells) - n_fail}/{len(cells)} cells OK")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
