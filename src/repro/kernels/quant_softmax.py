"""LUT softmax Pallas kernel — the paper's "Softmax Core" on the TPU VPU.

One grid step owns a block of rows held fully in VMEM.  The 256-entry exp
table is realized MXU/VPU-natively as an equality-select against an iota —
the systolic-array idiom for a small LUT (a gather would serialize on TPU).
All arithmetic is int32; semantics are bit-identical to
``repro.core.qsoftmax.quant_softmax`` (tests assert exact equality).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixedpoint as fxp
from repro.core.qsoftmax import LUT_SIZE


def lut_lookup(idx: jax.Array, lut: jax.Array) -> jax.Array:
    """TPU-native 256-entry LUT: one-hot select-and-sum (no gather).

    idx: int32 (..., n) in [0, 255]; lut: (256,) int32.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, (*idx.shape, LUT_SIZE), idx.ndim)
    onehot = (idx[..., None] == iota)
    return jnp.sum(jnp.where(onehot, lut, 0), axis=-1)


def _softmax_kernel(x_ref, lut_ref, m_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    m = jnp.max(x, axis=-1, keepdims=True)
    d = m - x
    idx = jnp.clip(fxp.rescale(d, m_ref[0], s_ref[0], out_bits=9), 0, LUT_SIZE - 1)
    num = lut_lookup(idx, lut_ref[...].astype(jnp.int32))
    den = jnp.maximum(jnp.sum(num, axis=-1, keepdims=True), 1)
    p = (num * 128 + den // 2) // den
    o_ref[...] = jnp.clip(p, 0, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quant_softmax(
    x_int: jax.Array,   # int32 (R, S) pre-masked logit codes
    M_idx: jax.Array,
    shift_idx: jax.Array,
    lut: jax.Array,     # (256,) int32
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    r, s = x_int.shape
    br = min(block_rows, r)
    assert r % br == 0
    return pl.pallas_call(
        _softmax_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, s), lambda i: (i, 0)),
            pl.BlockSpec((LUT_SIZE,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((br, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, s), jnp.int8),
        interpret=interpret,
    )(x_int, lut,
      jnp.asarray(M_idx, jnp.int32).reshape(1),
      jnp.asarray(shift_idx, jnp.int32).reshape(1))
