"""Static analysis of the integer serving datapath.

Submodules (import them directly; this package namespace stays empty so
``boundary`` can be imported from kernel modules without cycles):

* ``boundary``    — registry of named kernel-equivalent scopes.
* ``jaxpr_audit`` — walks hot-graph jaxprs enforcing integer-datapath rules.
* ``pallas_lint`` — static checks over the Pallas kernels' BlockSpecs.
* ``hlo_cost``    — loop-aware HLO FLOP/byte accounting (moved from
  ``benchmarks/``; a shim re-exports it there).
* ``report``      — frozen versioned ANALYSIS.json schema + baseline diff.
* ``fixtures``    — intentionally-broken graphs the auditor must flag.
* ``analyze``     — CLI: ``python -m repro.analysis.analyze``.
"""
