"""Hierarchical cost extraction from post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scan-over-layers / grad-accumulation programs by the trip
count.  This module parses the compiled HLO, builds the computation call
graph, multiplies every computation's costs by the product of enclosing
``known_trip_count`` values, and returns loop-aware totals:

  * ``dot_flops``      — 2 * prod(output dims) * prod(contracting dims)
  * ``hbm_bytes``      — sum of operand+result bytes of top-level ops per
                         computation (post-fusion, so fusion internals do not
                         double-count; a standard HBM-traffic model)
  * ``collective_bytes`` / per-op-kind breakdown — result bytes of
                         all-gather/all-reduce/reduce-scatter/all-to-all/
                         collective-permute
  * ``hbm_bytes_by_dtype`` — the same HBM-traffic model split per element
                         dtype (packed-int4-in-u8 payloads attributed at
                         0.5 byte under ``u8``), which is how the analysis
                         lane proves a serving graph's traffic is integer-
                         dominated rather than silently float.

Everything is derived from the dry-run artifact itself (deliverable g), with
the trip-count scaling auditable via ``loop_report``.  Lives in
``repro.analysis`` since PR 9 (``benchmarks/hlo_cost.py`` is an import
shim) so the analysis subsystem can consume it without path games.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {"pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2,
             "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
             "s64": 8, "u64": 8, "f64": 8, "token": 0, "u1": 1}

# Ops whose names/metadata carry one of these markers move PACKED int4
# payloads in u8 carriers (two nibbles per element — the kv4 pool and the
# int4 weight path pack along the trailing axis), so their u8 buffers are
# attributed at 0.5 byte/element.  True s4/u4 shapes are always 0.5.
PACKED_U8_MARKERS = ("_q4", "kv4", "int4_pack", "pack_int4")

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(s: str, u8_half: bool = False) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        per = 0.5 if (u8_half and dt == "u8") else _DT_BYTES[dt]
        total += n * per
    return total


def _shape_bytes_by_dtype(s: str, u8_half: bool = False) -> Dict[str, float]:
    """Like ``_shape_bytes`` but split per element dtype."""
    out: Dict[str, float] = {}
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        per = 0.5 if (u8_half and dt == "u8") else _DT_BYTES[dt]
        out[dt] = out.get(dt, 0.0) + n * per
    return out


def _add_scaled(dst: Dict[str, float], src: Dict[str, float],
                factor: float) -> None:
    for dt, b in src.items():
        dst[dt] = dst.get(dt, 0.0) + b * factor


def _shape_dims(s: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(s)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Comp:
    name: str
    lines: List[str] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # %value -> shape str


def parse_computations(hlo: str) -> Tuple[Dict[str, Comp], str]:
    comps: Dict[str, Comp] = {}
    entry = None
    cur: Optional[Comp] = None
    header_re = re.compile(r"^(ENTRY )?%?([\w.\-]+)\s*\((.*?)\)\s*->")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if not line.startswith(" ") and header_re.match(ls) and ls.endswith("{"):
            m = header_re.match(ls)
            cur = Comp(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            # parameter shapes from the signature
            for pm in re.finditer(r"%?([\w.\-]+): ([^,)]+)", m.group(3) if False else ls):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if ls == "}" or ls == "})":
            cur = None
            continue
        if cur is None or not ls or ls.startswith("//"):
            continue
        cur.lines.append(ls)
        dm = re.match(r"(?:ROOT )?%?([\w.\-]+) = (\(?[\w\[\],{}\s/]+?\)?) [a-z][\w\-]*\(", ls)
        if dm:
            cur.shapes[dm.group(1)] = dm.group(2)
    return comps, entry


_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{)%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)(.*)$")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')


def _op_kind(ls: str) -> Optional[str]:
    m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (?:\(?[\w\[\],{}\s/]+?\)?) "
                 r"([a-z][\w\-]*)\(", ls)
    return m.group(1) if m else None


def _operands(ls: str, comp: Comp) -> List[str]:
    # operand list inside the first (...) after the op name
    m = re.search(r"\((.*)\)", ls)
    if not m:
        return []
    ops = []
    for om in re.finditer(r"%([\w.\-]+)", m.group(1)):
        if om.group(1) in comp.shapes:
            ops.append(om.group(1))
    return ops


def analyze(hlo: str, packed_u8_markers=PACKED_U8_MARKERS) -> Dict:
    comps, entry = parse_computations(hlo)
    # multipliers via BFS from entry
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    loop_report = []
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for ls in comp.lines:
            wm = _WHILE_RE.search(ls)
            if wm:
                cond, body, rest = wm.groups()
                tm = _TRIP_RE.search(rest)
                trips = int(tm.group(1)) if tm else 1
                loop_report.append({"body": body, "trips": trips,
                                    "parent": cname})
                for sub, f in ((body, trips), (cond, trips + 1)):
                    mult[sub] += mult[cname] * f
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
                continue
            for cm in _CALL_RE.finditer(ls):
                sub = cm.group(1)
                if sub in (cname,):
                    continue
                mult[sub] += mult[cname]
                if sub not in seen:
                    seen.add(sub)
                    order.append(sub)

    flops = 0.0
    coll = {k: {"count": 0.0, "bytes": 0.0} for k in _COLL_KINDS}
    hbm = 0.0
    hbm_dt: Dict[str, float] = {}
    for cname, comp in comps.items():
        f = mult.get(cname, 0.0)
        if f == 0.0:
            continue
        fused = cname.startswith("fused_") or ".fused" in cname or \
            "fused_computation" in cname
        for ls in comp.lines:
            kind = _op_kind(ls)
            if kind is None:
                continue
            # packed-int4-in-u8 annotation: attribute this op's u8 buffers
            # at half a byte per element (nibble-planar payloads)
            half = any(m in ls for m in packed_u8_markers)
            if kind == "dot":
                out_dims = _shape_dims(ls.split(" dot(")[0]) or []
                opnds = _operands(ls, comp)
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ls)
                cdims = [int(x) for x in cm.group(1).split(",") if x] if cm else []
                if opnds:
                    lhs_shape = _shape_dims(comp.shapes.get(opnds[0], "")) or []
                    cprod = 1
                    for cd in cdims:
                        if cd < len(lhs_shape):
                            cprod *= lhs_shape[cd]
                    import math as _m

                    flops += f * 2.0 * cprod * _m.prod(out_dims) if out_dims \
                        else 0.0
            if kind in _COLL_KINDS and not ls.startswith("%" + cname):
                shape_part = ls.split(f" {kind}(")[0]
                b = _shape_bytes(shape_part, half)
                coll[kind]["count"] += f
                coll[kind]["bytes"] += f * b
            if not fused and kind not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional", "call", "after-all",
                    "iota", "partition-id", "replica-id") \
                    and kind not in _COLL_KINDS:
                # HBM traffic model: bytes actually touched per op kind.
                # Fusions are classified by XLA's root-op naming so that a
                # slice-fusion reading one layer from a loop-carried stacked
                # tensor is charged the slice, not the whole stack.
                res_s = ls.split(" " + kind + "(")[0]
                res_b = _shape_bytes(res_s, half)
                name = ls.split(" = ")[0]
                opnd_shapes = [comp.shapes.get(o, "")
                               for o in _operands(ls, comp)]

                if kind == "dynamic-update-slice" or (
                        kind == "fusion" and "dynamic-update-slice" in name):
                    upd_s = min(opnd_shapes,
                                key=lambda s2: _shape_bytes(s2, half),
                                default=res_s)
                    # read+write the slice only
                    hbm += f * 2 * _shape_bytes(upd_s, half)
                    _add_scaled(hbm_dt, _shape_bytes_by_dtype(upd_s, half),
                                f * 2)
                elif kind in ("dynamic-slice", "gather", "broadcast",
                              "reshape", "transpose", "copy", "convert",
                              "slice", "pad", "reverse") or (
                        kind == "fusion" and any(
                            t in name for t in ("slice_fusion", "gather",
                                                "broadcast_fusion"))):
                    hbm += f * 2 * res_b        # touch ~result-sized data
                    _add_scaled(hbm_dt, _shape_bytes_by_dtype(res_s, half),
                                f * 2)
                elif kind == "dot" or kind in (
                        "reduce", "reduce-window", "scatter", "sort",
                        "concatenate", "select-and-scatter") or (
                        kind == "fusion" and "reduce" in name):
                    hbm += f * (res_b + sum(_shape_bytes(s2, half)
                                            for s2 in opnd_shapes))
                    _add_scaled(hbm_dt, _shape_bytes_by_dtype(res_s, half), f)
                    for s2 in opnd_shapes:
                        _add_scaled(hbm_dt,
                                    _shape_bytes_by_dtype(s2, half), f)
                else:
                    # elementwise-ish (incl. generic fusions): inputs are
                    # broadcast-or-same-shape — cap each at 4x result size
                    hbm += f * (res_b + sum(
                        min(_shape_bytes(s2, half), 4 * res_b)
                        for s2 in opnd_shapes))
                    _add_scaled(hbm_dt, _shape_bytes_by_dtype(res_s, half), f)
                    for s2 in opnd_shapes:
                        ob = _shape_bytes(s2, half)
                        scale = 1.0 if ob <= 4 * res_b else (
                            4 * res_b / ob if ob else 0.0)
                        _add_scaled(hbm_dt,
                                    _shape_bytes_by_dtype(s2, half),
                                    f * scale)
    return {
        "dot_flops": flops,
        "hbm_bytes": hbm,
        "hbm_bytes_by_dtype": hbm_dt,
        "collectives": {k: v for k, v in coll.items()},
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        "loop_report": loop_report,
        "n_computations": len(comps),
    }


def analyze_file(path: str) -> Dict:
    return analyze(Path(path).read_text())


if __name__ == "__main__":
    import sys

    out = analyze_file(sys.argv[1])
    out.pop("loop_report")
    print(json.dumps(out, indent=1))
