"""Bench regression gate: compare freshly produced BENCH_*.json artifacts
against the committed baselines in ``benchmarks/baselines/``.

The bench scripts already exit non-zero on token divergence; this gate adds
the two checks they don't make:

  * every ``outputs_match`` / ``slo_ok`` / ``affinity_ok`` flag anywhere
    in the current artifact must be truthy (a bench that tolerated a
    mismatch — e.g. on the pallas backend — still fails the gate, which
    only ever runs on the CPU lanes where bit-identity is the contract);
  * every throughput metric (keys named ``tok_per_s`` / ``*_tok_per_s``,
    at any nesting depth) present in BOTH the current artifact and its
    baseline must not drop more than ``--max-drop`` (default 25%);
  * every ``engine_counters`` / ``router_counters`` dict in the CURRENT
    artifact must match the frozen stats schema exactly (baselines are
    exempt: they may predate schema growth, but nothing fresh may drift).

Artifacts whose top-level ``kind`` is ``analysis_report`` (ANALYSIS.json
from ``python -m repro.analysis.analyze``) take a different gate: the
frozen analysis schema must validate, a FRESH report must carry zero
violations (the committed baseline is exempt from re-validation growth,
but a clean tree can never ship a violating report), and the per-graph
float-primitive set must not grow vs the committed baseline (the one-way
"integer datapath regressed toward float" ratchet).

Speedup-ratio and latency keys are deliberately NOT gated: on 2-core CI
runners wall-clock percentiles are too noisy (they remain in the artifacts
for the perf trajectory); aggregate tok/s over a whole smoke run is the
stable end of the measurement.

    python benchmarks/check_regression.py BENCH_PR.json BENCH_PREFIX.json
    python benchmarks/check_regression.py BENCH_TP.json --max-drop 0.4

A missing baseline is an ERROR, not a skip — when a new bench artifact is
added, run it once with ``--smoke`` and commit the JSON under
``benchmarks/baselines/`` in the same PR, so the gate can never silently
stop gating.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE_DIR = Path(__file__).resolve().parent / "baselines"
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

GATED_FLAGS = ("outputs_match", "slo_ok", "affinity_ok")


def walk_metrics(obj, path=""):
    """Yield (dotted_path, key, value) for every dict entry, depth-first."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            sub = f"{path}.{k}" if path else str(k)
            yield sub, k, v
            yield from walk_metrics(v, sub)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from walk_metrics(v, f"{path}[{i}]")


def tok_per_s_metrics(doc):
    return {p: float(v) for p, k, v in walk_metrics(doc)
            if (k == "tok_per_s" or k.endswith("_tok_per_s"))
            and isinstance(v, (int, float))}


def divergence_flags(doc):
    return {p: bool(v) for p, k, v in walk_metrics(doc)
            if k in GATED_FLAGS}


def counter_schema_errors(doc):
    """Validate every engine_counters/router_counters dict in ``doc``
    against the frozen stats schema (exact key sets, versioned in
    ``repro.serve.stats``)."""
    from repro.serve import stats as SS
    errs = []
    for p, k, v in walk_metrics(doc):
        if not isinstance(v, dict):
            continue
        try:
            if k == "engine_counters":
                SS.validate_counters(v)
            elif k == "router_counters":
                SS.validate_router_counters(v)
        except ValueError as e:
            errs.append(f"{p}: {e}")
    return errs


def check_analysis_artifact(cur_path: Path, cur: dict, baseline_dir: Path):
    """Gate an ANALYSIS.json: schema-valid, zero violations when fresh,
    float-primitive ratchet vs the committed baseline."""
    from repro.analysis import report as AR
    failures = []
    try:
        AR.validate_report(cur, what=cur_path.name)
    except ValueError as e:
        return [f"{cur_path.name}: analysis schema: {e}"]
    n_viol = AR.count_violations(cur)
    status = "ok" if n_viol == 0 else "VIOLATIONS"
    print(f"{cur_path.name}: analysis report v{cur['schema_version']}, "
          f"{len(cur['presets'])} preset(s), {n_viol} violation(s) "
          f"[{status}]")
    if n_viol:
        failures.append(f"{cur_path.name}: fresh analysis report carries "
                        f"{n_viol} violation(s)")
    base_path = baseline_dir / cur_path.name
    if not base_path.exists():
        failures.append(
            f"{cur_path.name}: no committed baseline at {base_path} — run "
            f"`python -m repro.analysis.analyze --out` and commit its JSON "
            f"there")
        return failures
    base = json.loads(base_path.read_text())
    try:
        ratchet = AR.compare_to_baseline(cur, base)
    except ValueError as e:
        return failures + [f"{cur_path.name}: baseline unreadable: {e}"]
    for msg in ratchet:
        failures.append(f"{cur_path.name}: {msg}")
    if not ratchet:
        print(f"{cur_path.name}: float-primitive ratchet vs baseline holds")
    return failures


def check_artifact(cur_path: Path, baseline_dir: Path, max_drop: float):
    failures = []
    cur = json.loads(cur_path.read_text())
    if isinstance(cur, dict) and cur.get("kind") == "analysis_report":
        return check_analysis_artifact(cur_path, cur, baseline_dir)
    for p, ok in sorted(divergence_flags(cur).items()):
        status = "ok" if ok else "DIVERGED"
        print(f"{cur_path.name}: flag {p} = {ok} [{status}]")
        if not ok:
            failures.append(f"{cur_path.name}: divergence flag {p} is set")
    for err in counter_schema_errors(cur):
        failures.append(f"{cur_path.name}: stats schema: {err}")
    base_path = baseline_dir / cur_path.name
    if not base_path.exists():
        failures.append(
            f"{cur_path.name}: no committed baseline at {base_path} — run "
            f"the bench with --smoke and commit its JSON there")
        return failures
    base = json.loads(base_path.read_text())
    cur_m, base_m = tok_per_s_metrics(cur), tok_per_s_metrics(base)
    for p in sorted(cur_m.keys() & base_m.keys()):
        b, c = base_m[p], cur_m[p]
        if b <= 0:
            continue
        ratio = c / b
        status = "ok" if ratio >= 1.0 - max_drop else "REGRESSED"
        print(f"{cur_path.name}: {p}: base={b:.2f} cur={c:.2f} "
              f"ratio={ratio:.3f} [{status}]")
        if status == "REGRESSED":
            failures.append(
                f"{cur_path.name}: {p} dropped {(1 - ratio) * 100:.1f}% "
                f"(> {max_drop * 100:.0f}% allowed)")
    only_base = base_m.keys() - cur_m.keys()
    if only_base:
        # a vanished metric is a silently-stopped measurement, not a pass
        failures.append(f"{cur_path.name}: baseline metrics missing from "
                        f"current run: {sorted(only_base)}")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", type=Path,
                    help="freshly produced BENCH_*.json files")
    ap.add_argument("--baselines", type=Path, default=BASELINE_DIR,
                    help="directory of committed baseline JSONs "
                         "(matched by filename)")
    ap.add_argument("--max-drop", type=float, default=0.25,
                    help="max allowed fractional tok/s drop vs baseline")
    args = ap.parse_args(argv)
    failures = []
    for art in args.artifacts:
        if not art.exists():
            failures.append(f"{art}: artifact not found (did its bench run?)")
            continue
        failures.extend(check_artifact(art, args.baselines, args.max_drop))
    if failures:
        print("\nBENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"\nbench regression gate passed "
          f"({len(args.artifacts)} artifact(s), max drop "
          f"{args.max_drop * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
