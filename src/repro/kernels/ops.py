"""Public jit'd wrappers over the Pallas kernels, with backend dispatch.

Backends (env ``REPRO_KERNELS`` or per-call override):
  * ``pallas``    — compiled Pallas (the TPU target).
  * ``interpret`` — Pallas interpret mode (CPU correctness; used by tests).
  * ``ref``       — pure-jnp oracles from ``kernels/ref.py`` (identical
                    integer semantics; what the dry-run lowers on CPU so
                    cost_analysis reflects the real algorithm, not the
                    interpreter).
  * ``auto``      — pallas on TPU, ref elsewhere (default).

Wrappers flatten leading dims, pad rows to tile multiples, and unpad —
model code never sees tiling constraints.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qlayernorm import QLNParams
from repro.core.qlinear import FoldedLinear
from repro.core.qsoftmax import MASK_OFFSET
from repro.kernels import ref as _ref
from repro.kernels import int4_matmul as _mm
from repro.kernels import quant_softmax as _sm
from repro.kernels import quant_layernorm as _ln
from repro.kernels import flash_qattention as _fa


def backend(override: Optional[str] = None) -> str:
    b = override or os.environ.get("REPRO_KERNELS", "auto")
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return b


def _pad_rows(x: jax.Array, mult: int):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def linear_w4a8(x_i8: jax.Array, f: FoldedLinear, *, impl: Optional[str] = None):
    """y_i8 = requant(x_i8 @ unpack(W4) + b).  x: int8 (..., K) -> (..., N)."""
    lead = x_i8.shape[:-1]
    k = x_i8.shape[-1]
    x2 = x_i8.reshape(-1, k)
    b = backend(impl)
    if b == "ref" or f.w_bits != 4:
        ref_mm = (_ref.int4_matmul_ref if f.w_bits == 4 else
                  _ref.int8_bitsplit_matmul_ref)
        y = ref_mm(x2, f.w_packed, f.bias_i, f.M, f.shift)
        return y.reshape(*lead, -1)
    x2p, m = _pad_rows(x2, 8)
    y = _mm.int4_matmul(x2p, f.w_packed, f.bias_i, f.M, f.shift,
                        interpret=(b == "interpret"))
    return y[:m].reshape(*lead, -1)


def linear_w8a8_bitsplit(x_i8, w_i8, bias_i, M, shift, *, impl=None):
    """8x8 matmul realized as two 8x4 passes (BIM Type-A)."""
    lead = x_i8.shape[:-1]
    x2 = x_i8.reshape(-1, x_i8.shape[-1])
    b = backend(impl)
    if b == "ref":
        y = _ref.int8_bitsplit_matmul_ref(x2, w_i8, bias_i, M, shift)
        return y.reshape(*lead, -1)
    x2p, m = _pad_rows(x2, 8)
    y = _mm.int8_bitsplit_matmul(x2p, w_i8, bias_i, M, shift,
                                 interpret=(b == "interpret"))
    return y[:m].reshape(*lead, -1)


def softmax_q(x_int, M_idx, shift_idx, lut, mask=None, *, impl=None):
    """Quantized softmax over the last axis.  x_int: int32 codes."""
    b = backend(impl)
    if b == "ref":
        return _ref.quant_softmax_ref(x_int, M_idx, shift_idx, lut, mask=mask)
    if mask is not None:
        x_int = jnp.where(mask, x_int, x_int - MASK_OFFSET)
    lead = x_int.shape[:-1]
    s = x_int.shape[-1]
    x2 = x_int.reshape(-1, s)
    x2p, m = _pad_rows(x2, 8)
    y = _sm.quant_softmax(x2p, M_idx, shift_idx, lut, interpret=(b == "interpret"))
    return y[:m].reshape(*lead, s)


def layernorm_q(x_i8, p: QLNParams, *, eps_codes: int = 1, impl=None):
    b = backend(impl)
    if b == "ref":
        return _ref.quant_layernorm_ref(x_i8, p, eps_codes)
    lead = x_i8.shape[:-1]
    n = x_i8.shape[-1]
    x2 = x_i8.reshape(-1, n)
    x2p, m = _pad_rows(x2, 8)
    y = _ln.quant_layernorm(
        x2p, p.gamma_i, p.beta_aligned, p.M_out, p.shift_out,
        subtract_mean=p.subtract_mean, eps_codes=eps_codes,
        interpret=(b == "interpret"))
    return y[:m].reshape(*lead, n)


def decode_attention_q(
    q_i8, k_i8, v_i8, lengths, M_idx, shift_idx, lut_q7, inv_s_logit,
    out_scale, *, bkv: Optional[int] = None, impl=None,
):
    """Continuous-batching decode attention with per-slot length masking.

    (B, Hkv, G, D) grouped queries x (B, Smax, Hkv, D) cache-native int8 KV
    -> (B, Hkv, G, D) int8 context.  ref backend = row oracle (exact);
    pallas = the batched single-query flash kernel (skips KV blocks past
    each slot's length).  ``bkv=None`` (the default) lets
    ``kernels/autotune.py`` pick the KV tile from the roofline cost table
    for this shape; pass an int to pin it.
    """
    b = backend(impl)
    if b == "ref":
        return _ref.decode_qattention_ref(
            q_i8, k_i8.transpose(0, 2, 1, 3), v_i8.transpose(0, 2, 1, 3),
            lengths, M_idx, shift_idx, lut_q7, out_scale)
    if bkv is None:
        from repro.kernels import autotune
        bsz, smax, hkv, hd = k_i8.shape
        bkv = autotune.decode_bkv(smax, batch_slots=bsz, hkv=hkv, hd=hd)
    from repro.kernels.decode_attention import decode_qattention
    return decode_qattention(q_i8, k_i8, v_i8, lengths, M_idx, shift_idx,
                             lut_q7, inv_s_logit, out_scale, bkv=bkv,
                             interpret=(b == "interpret"))


def paged_decode_attention_q(
    q_i8, k_pool, v_pool, block_tables, lengths, M_idx, shift_idx, lut_q7,
    inv_s_logit, out_scale, *, impl=None,
):
    """Paged continuous-batching decode attention.

    (B, Hkv, G, D) grouped queries x (n_pages, P, Hkv, D) global int8 page
    pool, addressed per slot through a (B, max_blocks) block table ->
    (B, Hkv, G, D) int8 context.  ref backend = the block-online oracle
    (kernel-exact accumulation order); pallas = the scalar-prefetch paged
    kernel, bit-exact vs. the oracle for any page count.

    Under tensor parallelism the caller passes the rank-LOCAL head slice
    (q and pool Hkv axes both divided by tp) with the scalar-prefetched
    block table replicated — neither backend distinguishes a local slice
    from a small model, so no TP branch exists at this layer.
    """
    b = backend(impl)
    if b == "ref":
        return _ref.paged_decode_qattention_ref(
            q_i8, k_pool, v_pool, block_tables, lengths, M_idx, shift_idx,
            lut_q7, inv_s_logit, out_scale)
    from repro.kernels.decode_attention import paged_decode_qattention
    return paged_decode_qattention(
        q_i8, k_pool, v_pool, block_tables, lengths, M_idx, shift_idx,
        lut_q7, inv_s_logit, out_scale, interpret=(b == "interpret"))


def paged_decode_attention_q4(
    q_i8, k_pool_u8, v_pool_u8, k_scale, v_scale, block_tables, lengths,
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale, *, impl=None,
):
    """Paged decode attention over the int4-PACKED page pool.

    Same contract as ``paged_decode_attention_q`` but the pool leaves are
    (n_pages, P, Hkv, D//2) uint8 nibble-planar with (n_pages,) fp32 shared
    scales per page; the pallas backend fuses dequant into the kernel's
    inner loop (half the HBM bytes per page), the ref backend dequantizes
    the whole pool and runs the int8 oracle — bit-exact either way."""
    b = backend(impl)
    if b == "ref":
        return _ref.paged_decode_qattention_q4_ref(
            q_i8, k_pool_u8, v_pool_u8, k_scale, v_scale, block_tables,
            lengths, M_idx, shift_idx, lut_q7, inv_s_logit, out_scale)
    from repro.kernels.decode_attention import paged_decode_qattention_q4
    return paged_decode_qattention_q4(
        q_i8, k_pool_u8, v_pool_u8, k_scale, v_scale, block_tables, lengths,
        M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
        interpret=(b == "interpret"))


def paged_prefill_attention_q(
    q_i8, k_pool, v_pool, block_tables, pos0, M_idx, shift_idx, lut_q7,
    inv_s_logit, out_scale, *, bq: Optional[int] = None, impl=None,
):
    """Paged chunked-prefill attention.

    (B, H, S, D) chunk queries at positions [pos0, pos0+S) x (n_pages, P,
    Hkv, D) global int8 page pool, addressed per slot through a
    (B, max_blocks) block table -> (B, H, S, D) int8 context over each
    slot's whole mapped chain.  ref backend = the block-online oracle
    (kernel-exact accumulation order); pallas = the block-table-walking
    flash kernel, bit-exact vs. the oracle for any page count and q-block
    size.  The chunk's own K/V rows must already be scattered into the
    pool.  Under tensor parallelism the caller passes the rank-local head
    slice with the block table replicated (see paged_decode_attention_q);
    the chunk is the cross-rank work-division unit — every rank walks the
    same chunk over its own heads.  ``bq=None`` (the default) lets
    ``kernels/autotune.py`` pick the q-block from the roofline cost table
    for this shape (output is bq-independent, so tuning never moves bits);
    pass an int to pin it."""
    b = backend(impl)
    if b == "ref":
        return _ref.paged_prefill_qattention_ref(
            q_i8, k_pool, v_pool, block_tables, pos0, M_idx, shift_idx,
            lut_q7, inv_s_logit, out_scale)
    if bq is None:
        bq = _autotuned_bq(q_i8, k_pool, block_tables, kv_bits=8)
    from repro.kernels.prefill_attention import paged_prefill_qattention
    return paged_prefill_qattention(
        q_i8, k_pool, v_pool, block_tables, pos0, M_idx, shift_idx, lut_q7,
        inv_s_logit, out_scale, bq=bq, interpret=(b == "interpret"))


def _autotuned_bq(q_i8, k_pool, block_tables, *, kv_bits: int) -> int:
    from repro.kernels import autotune
    bsz, h, sq, hd = q_i8.shape
    return autotune.prefill_bq(
        sq, batch_slots=bsz, page_size=k_pool.shape[1],
        hkv=k_pool.shape[2], hd=hd, kv_bits=kv_bits,
        n_blocks=block_tables.shape[1], n_heads=h)


def paged_prefill_attention_q4(
    q_i8, k_pool_u8, v_pool_u8, k_scale, v_scale, block_tables, pos0,
    M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, bq: Optional[int] = None, impl=None,
):
    """Paged chunked-prefill attention over the int4-PACKED page pool (see
    ``paged_decode_attention_q4`` for the packed-pool contract and
    ``paged_prefill_attention_q`` for the prefill semantics)."""
    b = backend(impl)
    if b == "ref":
        return _ref.paged_prefill_qattention_q4_ref(
            q_i8, k_pool_u8, v_pool_u8, k_scale, v_scale, block_tables,
            pos0, M_idx, shift_idx, lut_q7, inv_s_logit, out_scale)
    if bq is None:
        bq = _autotuned_bq(q_i8, k_pool_u8, block_tables, kv_bits=4)
    from repro.kernels.prefill_attention import paged_prefill_qattention_q4
    return paged_prefill_qattention_q4(
        q_i8, k_pool_u8, v_pool_u8, k_scale, v_scale, block_tables, pos0,
        M_idx, shift_idx, lut_q7, inv_s_logit, out_scale, bq=bq,
        interpret=(b == "interpret"))


def attention_q(
    q_i8, k_i8, v_i8, M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
    *, causal: bool = True, q_offset: int = 0, impl=None,
):
    """Quantized attention, (B, H, Sq, D) x (B, Hkv, Skv, D) -> (B, H, Sq, D).

    ref backend = paper-style row softmax (exact); pallas = online flash.
    """
    b = backend(impl)
    bsz = q_i8.shape[0]

    if b == "ref":
        fn = lambda qq, kk, vv: _ref.qattention_ref(
            qq, kk, vv, M_idx, shift_idx, lut_q7, out_scale,
            causal=causal, q_offset=q_offset)
        return jax.vmap(fn)(q_i8, k_i8, v_i8)
    assert causal, "flash kernel is causal-only; BERT uses softmax_q"
    fn = lambda qq, kk, vv: _fa.flash_qattention(
        qq, kk, vv, M_idx, shift_idx, lut_q7, inv_s_logit, out_scale,
        q_offset=q_offset, interpret=(b == "interpret"))
    return jax.vmap(fn)(q_i8, k_i8, v_i8)
