"""kernels/autotune.py: roofline-derived tile selection, caching, overrides.

Autotune only moves DMA/grid overhead around — the paged kernels' outputs
are tile-size independent (dead-block clamping) — so these tests check the
selection MACHINERY: picked values are legal (candidate-derived divisors),
cached per shape, overridable by env, and the off switch restores the
legacy fixed defaults.
"""
import pytest

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _clean(monkeypatch):
    for var in ("REPRO_DECODE_BKV", "REPRO_PREFILL_BQ", "REPRO_AUTOTUNE"):
        monkeypatch.delenv(var, raising=False)
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_decode_bkv_legal_divisor():
    for smax in (64, 512, 2048, 96):
        got = autotune.decode_bkv(smax, batch_slots=8, hkv=8, hd=128)
        assert smax % got == 0 and got >= 1
        assert got <= max(autotune.DECODE_BKV_CANDIDATES)


def test_prefill_bq_legal_divisor():
    for sq in (16, 128, 384):
        got = autotune.prefill_bq(sq, batch_slots=8, page_size=16, hkv=8,
                                  hd=128, n_blocks=32, n_heads=32)
        assert sq % got == 0 and got >= 1
        assert got <= max(autotune.PREFILL_BQ_CANDIDATES)


def test_bigger_q_blocks_for_long_chains():
    """The KV-restream term dominates for long chains: each page streams
    once per q-block, so the model must not pick a tiny bq when the chain
    is long (it would multiply KV traffic)."""
    big = autotune.prefill_bq(256, batch_slots=8, page_size=16, hkv=8,
                              hd=128, n_blocks=128, n_heads=32)
    assert big >= 128


def test_selection_cached_per_shape():
    k = ("decode_bkv", 4, 8, 128, 1024, 8)
    autotune.decode_bkv(1024, batch_slots=4, hkv=8, hd=128)
    assert k in autotune._cache
    autotune._cache[k] = 128          # poison: cache hit must win
    assert autotune.decode_bkv(1024, batch_slots=4, hkv=8, hd=128) == 128
    autotune.clear_cache()
    assert autotune.decode_bkv(1024, batch_slots=4, hkv=8, hd=128) != 128 \
        or autotune._cache[k] != 128


def test_env_override_pins_value(monkeypatch):
    monkeypatch.setenv("REPRO_DECODE_BKV", "256")
    assert autotune.decode_bkv(1024, batch_slots=4, hkv=8, hd=128) == 256
    # override still divisor-fitted to the actual length
    assert autotune.decode_bkv(96, batch_slots=4, hkv=8, hd=128) == 96
    monkeypatch.setenv("REPRO_PREFILL_BQ", "64")
    assert autotune.prefill_bq(128, batch_slots=4, page_size=16, hkv=8,
                               hd=128) == 64


def test_off_mode_restores_legacy_defaults(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert autotune.decode_bkv(1024, batch_slots=4, hkv=8, hd=128) == \
        autotune.DEFAULT_DECODE_BKV
    assert autotune.prefill_bq(256, batch_slots=4, page_size=16, hkv=8,
                               hd=128) == autotune.DEFAULT_PREFILL_BQ


def test_kv4_halves_tile_bytes():
    """A 4-bit pool halves page bytes — selections stay legal and the key
    space distinguishes bit widths (no cross-contamination)."""
    a = autotune.decode_bkv(2048, batch_slots=8, hkv=8, hd=128, kv_bits=8)
    b = autotune.decode_bkv(2048, batch_slots=8, hkv=8, hd=128, kv_bits=4)
    assert 2048 % a == 0 and 2048 % b == 0
    keys = {k for k in autotune._cache if k[0] == "decode_bkv"}
    assert len(keys) == 2


def test_measure_mode_decode_bkv(monkeypatch):
    """REPRO_AUTOTUNE=measure races the live decode kernel: the pick is a
    legal candidate-derived divisor, cached per shape (second call runs no
    kernels — asserted by poisoning the cache), and env pins still win."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "measure")
    got = autotune.decode_bkv(128, batch_slots=2, hkv=2, hd=64)
    assert 128 % got == 0 and got >= 1
    key = ("measure", "decode_bkv", 2, 2, 64, 128, 8)
    assert autotune._cache[key] == got
    autotune._cache[key] = 64            # poison: cache hit must win
    assert autotune.decode_bkv(128, batch_slots=2, hkv=2, hd=64) == 64
    monkeypatch.setenv("REPRO_DECODE_BKV", "32")
    assert autotune.decode_bkv(128, batch_slots=2, hkv=2, hd=64) == 32


def test_measure_mode_prefill_bq(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "measure")
    got = autotune.prefill_bq(16, batch_slots=2, page_size=8, hkv=2, hd=64,
                              n_blocks=4, n_heads=2)
    assert 16 % got == 0 and got >= 1
    key = ("measure", "prefill_bq", 2, 8, 2, 64, 16, 8, 4, 2)
    assert autotune._cache[key] == got


def test_measure_mode_falls_back_without_kernel(monkeypatch):
    """An int4 contiguous decode has no kernel to race: measured mode must
    fall back to the roofline pick instead of crashing."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "measure")
    got = autotune.decode_bkv(256, batch_slots=2, hkv=2, hd=64, kv_bits=4)
    assert 256 % got == 0 and got >= 1
    assert not any(k[0] == "measure" for k in autotune._cache)


def test_small_bq_candidates_stay_priced_out():
    """The 8/16 candidates added for speculative verify shapes must not
    leak into ordinary long-chain chunk tuning (KV restream dominates)."""
    big = autotune.prefill_bq(256, batch_slots=8, page_size=16, hkv=8,
                              hd=128, n_blocks=128, n_heads=32)
    assert big >= 128
    # tiny verify-shaped sq: every candidate divisor-fits to sq
    small = autotune.prefill_bq(4, batch_slots=8, page_size=16, hkv=8,
                                hd=128, n_blocks=8, n_heads=32)
    assert small in (1, 2, 4)


def test_measure_best_caches_argmin():
    times = {32: 3.0, 64: 1.0, 128: 2.0}
    calls = []

    def timer(c):
        calls.append(c)
        return times[c]

    got = autotune.measure_best((32, 64, 128), timer, key=("m", 1))
    assert got == 64
    assert autotune.measure_best((32, 64, 128), timer, key=("m", 1)) == 64
    assert len(calls) == 3            # second call served from cache


def test_hw_constants_single_source_no_drift():
    """Satellite of the analysis PR: the roofline constants live ONCE in
    kernels/hw_constants.py; both consumers (the tuner and the
    benchmarks/roofline.py model) must resolve to the very same objects —
    a re-declared copy in either file is exactly the drift this pins."""
    import importlib.util
    from pathlib import Path

    from repro.kernels import hw_constants as HW

    assert autotune.VMEM_BUDGET is HW.VMEM_BUDGET
    assert autotune.VMEM_FILL is HW.VMEM_FILL
    assert autotune.HBM_BW is HW.HBM_BW
    assert autotune.PEAK_INT8_FLOPS is HW.PEAK_INT8_FLOPS
    assert autotune.STEP_OVERHEAD_S is HW.STEP_OVERHEAD_S

    roofline_py = (Path(__file__).resolve().parents[1] / "benchmarks"
                   / "roofline.py")
    spec = importlib.util.spec_from_file_location("roofline", roofline_py)
    roofline = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(roofline)
    assert roofline.PEAK_FLOPS is HW.PEAK_FLOPS
    assert roofline.HBM_BW is HW.HBM_BW
    assert roofline.ICI_BW is HW.ICI_BW
    assert roofline.ICI_LINKS is HW.ICI_LINKS
    assert HW.PEAK_FLOPS is HW.PEAK_INT8_FLOPS
