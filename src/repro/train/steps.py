"""Loss and train/serve step builders (the pjit surface of the framework).

``train_step``: QAT loss -> grads -> (optional int8 gradient compression for
the DP all-reduce) -> AdamW (optionally int8 moments) -> EMA update of the
activation-calibration tree (paper Eq. 3).

All steps are pure functions of (state, batch); the launchers wrap them in
jax.jit with NamedShardings from sharding/partition.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant import ema_tree_update
from repro.models import transformer as T
from repro.optim import adamw
from repro.sharding import partition as Pt

AUX_WEIGHT = 0.01


@partial(jax.tree_util.register_dataclass,
         data_fields=["params", "opt", "amax", "step"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: Any
    amax: Any
    step: jax.Array


def init_train_state(cfg: ModelConfig, key, opt_cfg: adamw.AdamWConfig):
    params = T.init_params(cfg, key)
    return TrainState(params=params,
                      opt=adamw.init_state(params, opt_cfg),
                      amax=T.init_amax(cfg),
                      step=jnp.zeros((), jnp.int32))


def _sharded_ce(lg: jax.Array, tgt: jax.Array) -> jax.Array:
    """Cross-entropy that stays sharded over a model-parallel vocab axis.

    take_along_axis on a vocab-sharded tensor forces a full all-gather of the
    logits (16+ GB/device at 4k x 256); the one-hot einsum form partitions
    cleanly (partial dot + small psum) and logsumexp reduces over the sharded
    axis with a scalar-per-token all-reduce."""
    lg = lg.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=lg.dtype)
    picked = jnp.einsum("...v,...v->...", lg, onehot)
    return jnp.mean(lse - picked)


def lm_loss(cfg: ModelConfig, params, amax, batch) -> Tuple[jax.Array, Dict]:
    """Next-token CE.  batch: {'tokens': (B,S) or (B,K,S), 'extra_embeds'?,
    'pos3'?}.  Labels are tokens shifted by one (standard LM)."""
    tokens = batch["tokens"]
    logits, obs, aux = T.forward(
        cfg, params, amax, tokens,
        extra_embeds=batch.get("extra_embeds"),
        pos3=batch.get("pos3"))
    if cfg.frontend == "audio_codebooks":
        # logits (B, K, S, V); per-codebook next-token CE
        tgt = tokens[:, :, 1:]
        lg = logits[:, :, :-1]
    else:
        tgt = tokens[:, 1:]
        lg = logits[:, :-1]
        if batch.get("extra_embeds") is not None:
            # vlm: image positions are prepended; only text positions score
            n_img = batch["extra_embeds"].shape[1]
            lg = lg[:, n_img:]
    loss = _sharded_ce(lg, tgt)
    total = loss + AUX_WEIGHT * aux
    return total, {"obs": obs, "ce": loss, "aux": aux}


def _compress_grads(grads, bits: int):
    """int8 gradient compression (per-tensor symmetric) applied before the
    (XLA-inserted) DP reduction — on-theme distributed-optimization trick.
    Quantize-dequantize: the all-reduce then moves ~4x fewer effective bits
    when XLA fuses the cast (and exactly models the accuracy cost)."""
    def qdq(g):
        g32 = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(g32))
        s = (2.0 ** (bits - 1) - 1) / jnp.maximum(amax, 1e-12)
        return jnp.round(g32 * s) / s
    return jax.tree.map(qdq, grads)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    ema_decay: float = 0.99, accum_steps: int = 1):
    """accum_steps > 1: microbatched gradient accumulation.  Memory: the
    per-layer activation residuals scale with the microbatch, which is what
    fits train_4k (global batch 256) in HBM; at multi-pod scale it also lets
    the cross-pod DCN all-reduce of the previous microbatch overlap the next
    microbatch's compute (XLA latency-hiding scheduler)."""

    def one_micro(params, amax, mb):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, amax, mb), has_aux=True)(params)
        return loss, aux, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if accum_steps == 1:
            loss, aux, grads = one_micro(state.params, state.amax, batch)
            obs = aux["obs"]
        else:
            def split(t):
                return t.reshape(accum_steps, t.shape[0] // accum_steps,
                                 *t.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)

            def body(carry, mb):
                gsum, loss_sum = carry
                loss, aux, grads = one_micro(state.params, state.amax, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, loss_sum + loss), aux["obs"]

            (gsum, loss_sum), obs_stack = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = loss_sum / accum_steps
            obs = jax.tree.map(lambda t: jnp.max(t, axis=0), obs_stack)
            aux = {"ce": loss, "aux": jnp.zeros(())}
        if cfg.quant.grad_compress_bits:
            grads = _compress_grads(grads, cfg.quant.grad_compress_bits)
        new_params, new_opt = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        gn = new_opt.pop("grad_norm")
        new_amax = ema_tree_update(state.amax, obs, ema_decay)
        new_state = TrainState(params=new_params, opt=new_opt, amax=new_amax,
                               step=state.step + 1)
        metrics = {"loss": loss, "ce": aux["ce"], "aux": aux["aux"],
                   "grad_norm": gn}
        return new_state, metrics

    return train_step


def make_bert_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                         ema_decay: float = 0.99):
    """Classification fine-tuning step (the paper's SST-2 setting)."""
    from repro.models import bert as B

    def loss_fn(params, amax, batch):
        logits, obs, aux = B.bert_classify(cfg, params, amax, batch["tokens"],
                                           batch.get("mask"))
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], 1)[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(
            jnp.float32))
        return jnp.mean(nll) + AUX_WEIGHT * aux, {"obs": obs, "acc": acc}

    def train_step(state: TrainState, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(p, state.amax, batch), has_aux=True
        )(state.params)
        if cfg.quant.grad_compress_bits:
            grads = _compress_grads(grads, cfg.quant.grad_compress_bits)
        new_params, new_opt = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        new_opt.pop("grad_norm")
        new_amax = ema_tree_update(state.amax, aux["obs"], ema_decay)
        return (TrainState(new_params, new_opt, new_amax, state.step + 1),
                {"loss": loss, "acc": aux["acc"]})

    return train_step


# --- jit wiring ----------------------------------------------------------------

def jit_train_step(cfg, mesh, opt_cfg, batch_example, *, fsdp: bool = True,
                   donate: bool = True, bert: bool = False,
                   accum_steps: int = 1):
    """Build the sharded, jitted train step + the state shardings."""
    step_fn = (make_bert_train_step(cfg, opt_cfg) if bert else
               make_train_step(cfg, opt_cfg, accum_steps=accum_steps))
    init = (init_bert_train_state if bert else init_train_state)
    state_shape = jax.eval_shape(
        lambda k: init(cfg, k, opt_cfg), jax.random.PRNGKey(0))
    p_shard = Pt.make_param_shardings(mesh, state_shape.params, fsdp=fsdp)
    opt_shard = {
        "m": Pt.make_param_shardings(mesh, state_shape.opt["m"], fsdp=fsdp),
        "v": Pt.make_param_shardings(mesh, state_shape.opt["v"], fsdp=fsdp),
        "step": Pt.replicated(mesh),
    }
    amax_shard = jax.tree.map(lambda _: Pt.replicated(mesh), state_shape.amax)
    state_shard = TrainState(params=p_shard, opt=opt_shard, amax=amax_shard,
                             step=Pt.replicated(mesh))
    batch_shard = jax.tree.map(
        lambda v: Pt.batch_sharding(mesh, v.ndim, v.shape), batch_example)
    metric_shard = None
    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, metric_shard),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_shard, batch_shard


def init_bert_train_state(cfg, key, opt_cfg):
    from repro.models import bert as B
    params = B.init_bert_params(cfg, key)
    return TrainState(params=params, opt=adamw.init_state(params, opt_cfg),
                      amax=B.init_bert_amax(cfg), step=jnp.zeros((), jnp.int32))
