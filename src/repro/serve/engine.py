"""Serving engines over the folded integer model.

``Engine`` — true continuous batching around a single token-budget step
loop: a fixed slot table shares one compiled decode graph; every slot
carries its own position (per-slot ``pos`` vector into ``serve_forward``),
requests are admitted mid-flight into free slots and evicted on
EOS/max-tokens by the ``Scheduler``.  Prefill is no longer a monolithic
one-shot forward at admission: each tick the scheduler carves waiting and
partially-prefilled prompts into page-aligned chunks under a shared token
budget (``max_batched_tokens`` per tick, ``max_prefill_chunk`` per slot)
and interleaves them with the decode batch, so a very long prompt can no
longer stall every decoding slot for the duration of its prefill.  A slot
keeps a ``prefill_pos`` cursor; its final chunk's last-row logits hand the
request into decode without an extra forward.  With both knobs unset a
prompt still prefills in one chunk — the pre-chunking behavior, now just a
degenerate schedule of the same loop.

Chunk forwards run through the decode-identical row datapath on the
ref/interpret kernel backends (CPU serving and CI), so a request's greedy
tokens are bit-for-bit what the lockstep engine produces for it alone —
and bit-for-bit identical across chunk sizes: chunking changes latency,
not outputs.  On the compiled pallas backend both prefill chunks and
decode dispatch to the q7 flash family instead (chunks go through the
block-table-walking ``paged_prefill_qattention`` kernel; self-consistent
integer datapath, but not bit-identical to the jnp path).  SSM/hybrid
architectures (whose prefill is a recurrence) fall back to a batch-1
decode-loop prefill, run as a single chunk of the same loop.

Cache layouts (``cache_layout=``):

* ``"paged"`` — the int8 KV cache is a global pool of fixed-size pages; each
  slot carries a block-table row instead of an exclusive ``Smax`` stripe.
  By default (``reserve_policy="ondemand"``) admission reserves only the
  PROMPT's pages; decode slots request their next page when the write
  cursor crosses a page boundary, and when the pool runs dry the engine
  preempts a victim — spill registers its finished pages in the prefix
  registry and requeues it at the queue front; restore replays through the
  ordinary chunk-continuation path, hitting the registry for whatever
  survived.  ``reserve_policy="full"`` keeps the PR-2 contract (prompt +
  decode budget reserved up front, decode can never OOM, overload stalls
  admission) for latency-critical serving where recompute is unacceptable.
  Prompt prefixes are shared at page granularity through the allocator's
  refcounted registry: a repeated system prompt maps cached pages and only
  the unseen suffix runs through the model.  Chunked prefill requires this
  layout (chunks are pages).
* ``"contiguous"`` — the original dense ``(B, Smax, Hkv, hd)`` stripe per
  slot (kept for one release as the A/B baseline; SWA ring buffers and
  SSM/hybrid archs always use it).  Prefill is always one whole-prompt
  chunk.
* ``"auto"`` (default) — paged when the arch supports it (all-attention,
  no sliding window), else contiguous.

Tensor parallelism (``tp=N`` or an explicit ``mesh``, paged layout only):
the page pool shards over its KV-head axis — every rank holds its heads'
slice of EVERY page, so page ids are global, block tables replicate, and
the host-side allocator/scheduler stays a single authority whose
admission/grow/preempt/spill decisions bind all ranks at once
(spill/restore never moves data across ranks; registration and replay are
rank-local).  Decode and prefill-chunk forwards run under one shard_map:
heads split per rank, chunks are the cross-rank work-division unit for
prefill, and contexts all-gather before the output projection, so sharded
greedy outputs are bit-identical to the unsharded engine on the
ref/interpret backends.  On CPU, simulate ranks with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the test-tp CI
lane's recipe).

Event-driven serving API (the surface the asyncio frontend, the replica
router, and the bench all drive): ``submit()`` / ``poll()`` / ``cancel()``.
``poll()`` runs ONE scheduler tick — admit → chunk-prefill-under-budget →
decode, exactly the loop described above — and returns the tick's
``TokenEvent`` stream: one event per emitted token plus terminal events
for cancelled and deadline-shed requests.  ``step()``, ``run()`` and
``generate()`` are thin wrappers over ``poll()``, so a bench run and a
server run cannot diverge in behavior — they are the same code path.
Requests carry an explicit lifecycle (``RequestStatus``: WAITING →
PREFILL → DECODE → FINISHED, with CANCELLED and FAILED exits) and a
``result()`` accessor; ``cancel()`` flows through this state machine and
frees a seated request's pages via the ordinary eviction path.
Construction takes a typed ``EngineConfig`` (the PR-6 legacy ``**kwargs``
surface is gone; keyword options raise a TypeError naming the fix).

Speculative decoding (``EngineConfig(spec_k=K)``, paged int8 layout):
each tick a pluggable :class:`~repro.serve.draft.DraftSource` proposes up
to K tokens per greedy decode slot; ONE verify forward (``mode="verify"``
— the chunk-prefill datapath at per-slot ragged positions) scores every
slot's ``[last_token, drafts...]`` rows at once, and the engine accepts
the longest prefix whose drafts match the argmax chain plus one bonus
token.  Accepted rows' K/V are already committed through the block table
(pages grown up front via ``Scheduler.grow``); a rejected tail just
leaves the write cursor behind the garbage rows, which the causal length
masks hide until the owner rewrites them — allocator state never moves.
Because acceptance is exact argmax matching, speculative greedy outputs
are bit-identical to plain decode (``spec_k=0``) on the row-exact
backends; the counters ``drafted`` / ``accepted`` / ``rejected`` /
``accept_len_hist`` report the win rate.

``LockstepEngine`` — the original batch demo (kept as the benchmark baseline
and for SSM/audio archs): lockstep decoding with one shared position scalar,
prefill replayed token-by-token for the whole batch, admission only between
``generate()`` calls.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import serve_int as S
from repro.models.transformer import slot_kinds
from repro.serve import stats as stats_schema
from repro.serve.scheduler import (BlockAllocator, Scheduler, SlotState,
                                   pages_needed)


class RequestStatus(enum.Enum):
    """Explicit request lifecycle.  WAITING → PREFILL → DECODE → FINISHED
    is the happy path; preemption moves a seated request back to WAITING;
    CANCELLED (explicit cancel or deadline shed) and FAILED are terminal
    exits.  Callers read ``Request.status`` / ``Request.result()`` instead
    of peeking at engine internals."""
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    FAILED = "failed"

    @property
    def terminal(self) -> bool:
        return self in (RequestStatus.FINISHED, RequestStatus.CANCELLED,
                        RequestStatus.FAILED)


class RequestCancelled(RuntimeError):
    """Raised by ``Request.result()`` for a cancelled / shed request."""


class RequestFailed(RuntimeError):
    """Raised by ``Request.result()`` for a failed request."""


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One element of the ``poll()`` event stream.

    ``token`` is None for terminal events that do not carry a token
    (cancellation, deadline shed, failure); ``index`` is the token's
    0-based position in the request's output stream (for a terminal
    non-token event: the number of tokens emitted before it).  ``final``
    marks the request's last event — its status is terminal from here and
    ``finish_reason`` says why: ``length`` / ``eos`` (FINISHED),
    ``cancelled`` / ``deadline`` (CANCELLED), ``error`` (FAILED)."""
    rid: int
    token: Optional[int]
    index: int
    final: bool
    finish_reason: Optional[str] = None


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_token: Optional[int] = None
    deadline_tick: Optional[int] = None  # shed if still WAITING at this tick
    out: Optional[np.ndarray] = None
    # --- lifecycle (owned by the engine/router after submit) -------------
    rid: Optional[int] = None
    status: RequestStatus = RequestStatus.WAITING
    finish_reason: Optional[str] = None

    def result(self) -> np.ndarray:
        """The generated tokens once FINISHED.  Raises ``RequestCancelled``
        / ``RequestFailed`` on the terminal exits (``out`` still holds the
        partial tokens emitted before the exit) and ``RuntimeError`` while
        the request is in flight."""
        if self.status is RequestStatus.FINISHED:
            return self.out
        if self.status is RequestStatus.CANCELLED:
            raise RequestCancelled(
                f"request rid={self.rid} cancelled ({self.finish_reason}); "
                f"{0 if self.out is None else len(self.out)} partial "
                f"token(s) in .out")
        if self.status is RequestStatus.FAILED:
            raise RequestFailed(
                f"request rid={self.rid} failed ({self.finish_reason})")
        raise RuntimeError(
            f"request rid={self.rid} still in flight "
            f"(status={self.status.value})")


class EngineConfigError(ValueError):
    """An EngineConfig is invalid or incompatible with the model config."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Typed, validated engine construction options (replaces the old
    opaque ``**kwargs``).  Field-level constraints are checked by
    ``validate()`` at engine construction; model-dependent compatibility
    (paged layout support, TP divisibility) is checked by the engine with
    the same ``EngineConfigError``.  Unknown options raise ``TypeError``
    naming the valid fields (``from_kwargs``)."""
    batch_slots: int = 8
    max_len: int = 512
    seed: int = 0
    prefill_bucket: int = 16
    cache_layout: str = "auto"           # auto | paged | contiguous
    page_size: int = 16
    n_pages: Optional[int] = None
    max_batched_tokens: Optional[int] = None
    max_prefill_chunk: Optional[int] = None
    reserve_policy: Optional[str] = None  # None | "full" | "ondemand"
    kv_bits: int = 8                      # 8 (identity default) | 4 (packed)
    tp: int = 1
    mesh: object = None
    spec_k: int = 0                       # max draft tokens/slot/tick (0=off)
    draft: object = "prompt_lookup"       # DraftSource instance or name

    @classmethod
    def from_kwargs(cls, **kw) -> "EngineConfig":
        """Build from keyword options; unknown names raise a TypeError
        listing the valid fields (the old ``**kw`` surface silently
        warned or dropped — now it is an error)."""
        valid = [f.name for f in dataclasses.fields(cls)]
        unknown = sorted(set(kw) - set(valid))
        if unknown:
            raise TypeError(
                f"unknown engine option(s) {', '.join(unknown)}; valid "
                f"EngineConfig fields: {', '.join(valid)}")
        return cls(**kw)

    def validate(self) -> "EngineConfig":
        """Field-level validation (model-independent); raises
        ``EngineConfigError`` with an actionable message."""
        def bad(msg):
            raise EngineConfigError(f"invalid EngineConfig: {msg}")
        if self.batch_slots < 1:
            bad(f"batch_slots must be >= 1 (got {self.batch_slots})")
        if self.max_len < 1:
            bad(f"max_len must be >= 1 (got {self.max_len})")
        if self.prefill_bucket < 1:
            bad(f"prefill_bucket must be >= 1 (got {self.prefill_bucket})")
        if self.cache_layout not in ("auto", "paged", "contiguous"):
            bad(f"cache_layout must be auto|paged|contiguous "
                f"(got {self.cache_layout!r})")
        if self.page_size < 1:
            bad(f"page_size must be >= 1 (got {self.page_size})")
        if self.n_pages is not None and self.n_pages < 2:
            bad(f"n_pages must be >= 2 — page 0 is the reserved trash page "
                f"(got {self.n_pages})")
        if self.reserve_policy not in (None, "full", "ondemand"):
            bad(f"reserve_policy must be full|ondemand "
                f"(got {self.reserve_policy!r})")
        chunky = self.max_batched_tokens is not None or \
            self.max_prefill_chunk is not None
        if chunky and self.cache_layout == "contiguous":
            bad("chunked prefill (max_batched_tokens / max_prefill_chunk) "
                "requires cache_layout='paged' — chunks are pages")
        if self.max_batched_tokens is not None and self.max_batched_tokens < 1:
            bad(f"max_batched_tokens must be >= 1 "
                f"(got {self.max_batched_tokens})")
        if self.max_prefill_chunk is not None and (
                self.max_prefill_chunk < self.page_size
                or self.max_prefill_chunk % self.page_size):
            bad(f"max_prefill_chunk must be a positive multiple of "
                f"page_size={self.page_size} (got {self.max_prefill_chunk})")
        if self.reserve_policy == "ondemand" and \
                self.cache_layout == "contiguous":
            bad("reserve_policy='ondemand' (on-demand page growth) requires "
                "cache_layout='paged'")
        if self.kv_bits not in (8, 4):
            bad(f"kv_bits must be one of 8, 4 (got {self.kv_bits})")
        if self.kv_bits != 8 and self.cache_layout == "contiguous":
            bad("kv_bits=4 packs the paged KV pool; "
                "cache_layout='contiguous' stores int8 rows only")
        if self.tp < 1:
            bad(f"tp must be >= 1 (got {self.tp})")
        if (self.tp != 1 or self.mesh is not None) and \
                self.cache_layout == "contiguous":
            bad("tensor parallelism shards the paged KV pool; "
                "cache_layout='contiguous' has no TP path")
        if self.spec_k < 0:
            bad(f"spec_k must be >= 0 (got {self.spec_k})")
        if self.spec_k > 0 and self.cache_layout == "contiguous":
            bad("speculative decoding (spec_k > 0) verifies through the "
                "paged prefill path; cache_layout='contiguous' has no "
                "verify forward")
        if self.spec_k > 0 and self.kv_bits != 8:
            bad("spec_k > 0 with kv_bits=4 is not supported: a verify "
                "forward's multi-row write + rollback would re-derive "
                "page scales decode already froze (spec x kv4 interaction "
                "is a tracked ROADMAP follow-up)")
        return self


_DEFAULT_CONFIG = EngineConfig()
# fields the LockstepEngine has no use for; make_engine warns when they
# deviate from their defaults and resets them before construction
_CONTINUOUS_ONLY_FIELDS = ("prefill_bucket", "cache_layout", "page_size",
                           "n_pages", "max_batched_tokens",
                           "max_prefill_chunk", "reserve_policy", "kv_bits",
                           "tp", "mesh", "spec_k", "draft")


def _config_only(config: Optional[EngineConfig], kw: dict,
                 caller: str) -> EngineConfig:
    """Engines construct from an EngineConfig ONLY.  The PR-6 one-release
    ``**kwargs`` DeprecationWarning shim is gone; the old keyword surface
    now fails fast with a TypeError that names the replacement instead of
    python's generic unexpected-keyword message."""
    if kw:
        raise TypeError(
            f"{caller}(cfg, folded, {next(iter(kw))}=..., ...) keyword "
            f"options were removed (deprecated one release ago); pass "
            f"{caller}(cfg, folded, EngineConfig(...)) — valid fields: "
            f"{', '.join(f.name for f in dataclasses.fields(EngineConfig))}")
    return (config if config is not None else EngineConfig()).validate()


def supports_continuous(cfg: ModelConfig) -> bool:
    """Continuous batching serves single-head token-LM archs; codebook/audio
    and multi-head archs go through LockstepEngine (see make_engine)."""
    return cfg.frontend == "none" and cfg.n_lm_heads == 1


def make_engine(cfg: ModelConfig, folded,
                config: Optional[EngineConfig] = None, **kw):
    """The continuous engine when the arch supports it, else the lockstep
    baseline (same generate() surface).  Continuous-only EngineConfig
    fields set to non-default values for a lockstep arch are reset with a
    warning — not silently."""
    config = _config_only(config, kw, "make_engine")
    if supports_continuous(cfg):
        return Engine(cfg, folded, config)
    dropped = sorted(f for f in _CONTINUOUS_ONLY_FIELDS
                     if getattr(config, f) != getattr(_DEFAULT_CONFIG, f))
    if dropped:
        warnings.warn(
            f"make_engine: arch {cfg.name!r} takes the LockstepEngine, "
            f"which ignores {', '.join(dropped)}", stacklevel=2)
        config = dataclasses.replace(
            config, **{f: getattr(_DEFAULT_CONFIG, f) for f in dropped})
    return LockstepEngine(cfg, folded, config)


class Engine:
    """Continuous-batching integer serving engine (token-budget step loop)."""

    def __init__(self, cfg: ModelConfig, folded,
                 config: Optional[EngineConfig] = None, **kw):
        config = _config_only(config, kw, "Engine")
        if not supports_continuous(cfg):
            raise EngineConfigError(
                f"continuous engine serves token-LM archs; arch "
                f"{cfg.name!r} needs LockstepEngine (use make_engine)")
        self.cfg = cfg
        self.folded = folded
        self.config = config
        batch_slots, max_len = config.batch_slots, config.max_len
        cache_layout, page_size = config.cache_layout, config.page_size
        tp, mesh = config.tp, config.mesh
        self.batch = batch_slots
        self.max_len = max_len
        self.smax = S.cache_rows(cfg, max_len)
        self.prefill_bucket = config.prefill_bucket
        # one-shot prefill needs every mixer to be cache-writing attention
        self._attn_only = cfg.causal and \
            all(m == "attn" for m, _ in slot_kinds(cfg))
        # the paged pool ignores the ACTIVATION-constraint mesh context
        # (that ctx drives the contiguous layout's SPMD constrain guards):
        # under an active ctx auto falls back to contiguous and an explicit
        # "paged" is refused rather than silently slow.  Tensor parallelism
        # for the paged pool goes through the engine-level ``tp``/``mesh``
        # config fields instead (shard_map over the pool's Hkv axis, below).
        from repro.sharding import partition as Pt
        pageable = self._attn_only and not cfg.sliding_window \
            and Pt.get_mesh_ctx() is None
        if cache_layout == "auto":
            cache_layout = "paged" if pageable else "contiguous"
        if cache_layout == "paged" and not pageable:
            raise EngineConfigError(
                "cache_layout='paged' requires an all-attention, non-SWA "
                "arch and no active device mesh; use cache_layout='auto' "
                "to fall back to contiguous")
        self.layout = cache_layout
        self.page_size = page_size
        if cache_layout != "paged" and (
                config.max_batched_tokens is not None
                or config.max_prefill_chunk is not None):
            raise EngineConfigError(
                "chunked prefill (max_batched_tokens / max_prefill_chunk) "
                "requires the paged cache layout, but cache_layout resolved "
                f"to {cache_layout!r} for arch {cfg.name!r}")
        self.max_batched_tokens = config.max_batched_tokens
        self.max_prefill_chunk = config.max_prefill_chunk
        # page-reservation policy: on-demand growth + preemption is the
        # default for the paged pool (the memory win paging exists for);
        # "full" restores the reserve-everything-at-admission contract
        if self.layout == "paged":
            self.reserve_policy = config.reserve_policy or "ondemand"
        else:
            if config.reserve_policy == "ondemand":
                raise EngineConfigError(
                    "reserve_policy='ondemand' requires the paged cache "
                    "layout, but cache_layout resolved to "
                    f"{cache_layout!r} for arch {cfg.name!r}")
            self.reserve_policy = "full"
        # KV pool precision: 8 is the identity-contract default; 4 packs
        # pages to nibbles (paged layout only — validate() already rejects
        # an EXPLICIT contiguous+kv4 combination, this handles 'auto'
        # resolving to contiguous for archs the paged pool can't serve)
        self.kv_bits = config.kv_bits
        if self.kv_bits != 8 and self.layout != "paged":
            warnings.warn(
                f"kv_bits={self.kv_bits} requires the paged cache layout, "
                f"but cache_layout resolved to {self.layout!r} for arch "
                f"{cfg.name!r}; falling back to kv_bits=8", stacklevel=2)
            self.kv_bits = 8
        # speculative decoding: validate() rejects explicit bad combos;
        # this guards 'auto' resolving to a layout the verifier can't serve
        self.spec_k = config.spec_k
        self.draft = None
        if self.spec_k:
            if self.layout != "paged" or self.kv_bits != 8:
                raise EngineConfigError(
                    f"speculative decoding (spec_k={self.spec_k}) requires "
                    f"the int8 paged cache layout, but arch {cfg.name!r} "
                    f"resolved to layout={self.layout!r} "
                    f"kv_bits={self.kv_bits}")
            from repro.serve.draft import make_draft_source
            self.draft = make_draft_source(config.draft)
        if self.layout == "paged":
            self.max_blocks = pages_needed(self.smax, page_size)
            # +1: page 0 is the reserved trash page (inactive-slot writes)
            self.n_pages = config.n_pages if config.n_pages is not None \
                else batch_slots * self.max_blocks + 1
            assert self.n_pages >= 2
        # --- tensor parallelism (paged pool sharded over KV heads) -------
        # Every rank holds its heads' slice of EVERY page: page ids stay
        # global, the block tables replicated, and the host-side
        # allocator/scheduler a single authority whose grow/preempt/spill
        # decisions apply to all ranks' slices at once.  tp=1 with an
        # explicit 1-device mesh runs the same shard_map path degenerately
        # (the no-simulation CI fallback).
        if mesh is None and tp != 1:
            from repro.launch.mesh import make_tp_mesh
            mesh = make_tp_mesh(tp)
        self.mesh = mesh
        if mesh is not None:
            if self.layout != "paged":
                raise EngineConfigError(
                    "tensor parallelism shards the paged KV pool; the "
                    "contiguous layout has no TP path")
            assert "model" in mesh.axis_names, mesh.axis_names
            self.tp = int(mesh.shape["model"])
            assert tp in (1, self.tp), (tp, self.tp)
            if cfg.n_kv_heads % self.tp:
                raise EngineConfigError(
                    f"TP={self.tp} must divide n_kv_heads={cfg.n_kv_heads} "
                    "(each rank owns a whole slice of KV heads)")
        else:
            self.tp = 1
        # cross-replica shared prefix tier (attach_prefix_tier): survives
        # reset() — attachment is construction-level wiring, like the mesh
        self.prefix_tier = None
        self._init_state(config.seed)

        if self.layout == "paged":
            tp_axis = "model" if self.mesh is not None else None

            def decode_step(folded_, cache, tok, pos, btab):
                return S.serve_forward(cfg, folded_, tok, cache=cache,
                                       pos_offset=pos, mode="decode",
                                       block_tables=btab, tp_axis=tp_axis)

            def prefill(folded_, cache, toks, btab, pos0):
                return S.serve_forward(cfg, folded_, toks, cache=cache,
                                       pos_offset=pos0, mode="prefill",
                                       block_tables=btab, tp_axis=tp_axis)

            def verify(folded_, cache, toks, pos, btab, nrows):
                return S.serve_forward(cfg, folded_, toks, cache=cache,
                                       pos_offset=pos, mode="verify",
                                       block_tables=btab, verify_rows=nrows,
                                       tp_axis=tp_axis)

            if self.mesh is not None:
                # one shard_map around the whole forward: the pool enters
                # as the rank-local Hkv slice; tokens, positions, and the
                # block table replicate; logits come back replicated (the
                # forward all-gathers heads before the output projection)
                from jax.sharding import PartitionSpec as P
                from repro.sharding import partition as Pt
                # per-leaf specs: kv4 pools carry 2-D (n_reps, n_pages)
                # scale leaves next to the 5-D packed payloads, so one
                # broadcast pspec would rank-mismatch — match each leaf
                pool, rep = Pt.kv_pool_specs(self.cache, self.mesh), P()
                decode_step = Pt.shard_map_compat(
                    decode_step, self.mesh,
                    in_specs=(rep, pool, rep, rep, rep),
                    out_specs=(rep, pool))
                prefill = Pt.shard_map_compat(
                    prefill, self.mesh,
                    in_specs=(rep, pool, rep, rep, rep),
                    out_specs=(rep, pool))
                verify = Pt.shard_map_compat(
                    verify, self.mesh,
                    in_specs=(rep, pool, rep, rep, rep, rep),
                    out_specs=(rep, pool))
            self._decode = jax.jit(decode_step, donate_argnums=(1,))
            # the chunk forward: writes straight through the block table
            # into the (donated) pool at page-aligned ``pos0`` and attends
            # over the slot's whole mapped chain; one compiled shape per
            # chunk size (retraces per distinct padded length)
            self._prefill = jax.jit(prefill, donate_argnums=(1,))
            # the speculative verify forward: (B, spec_k+1) tokens at
            # per-slot ragged positions; one compiled shape total (ragged
            # proposal lengths pad to spec_k+1, verify_rows masks the rest)
            self._verify = jax.jit(verify, donate_argnums=(1,))
        else:
            def decode_step(folded_, cache, tok, pos):
                return S.serve_forward(cfg, folded_, tok, cache=cache,
                                       pos_offset=pos, mode="decode")

            # one graph for the slot table AND (by retrace) the batch-1
            # prefill loop
            self._decode = jax.jit(decode_step, donate_argnums=(1,))

            def prefill(folded_, toks):
                cache1 = S.init_cache(cfg, 1, max_len)
                return S.serve_forward(cfg, folded_, toks, cache=cache1,
                                       mode="prefill")

            self._prefill = jax.jit(prefill)  # retraces per bucketed length

            def write_slot(cache, cache1, b):
                def put(c, c1):
                    starts = (0, b) + (0,) * (c.ndim - 2)
                    return jax.lax.dynamic_update_slice(c, c1, starts)
                return jax.tree.map(put, cache, cache1)

            self._write_slot = jax.jit(write_slot, donate_argnums=(0,))

    @staticmethod
    def _zero_counters() -> Dict[str, int]:
        # built FROM the frozen schema: adding a counter means adding it to
        # repro.serve.stats.COUNTERS (with a description) first — the dict
        # and the schema cannot drift apart
        c: Dict = {k: 0 for k in stats_schema.COUNTERS}
        c["accept_len_hist"] = {}    # the one non-scalar: {accept_len: n}
        return c

    def _init_state(self, seed: int):
        self.requests: Dict[int, Request] = {}
        # terminal events produced between polls (cancel, deadline shed);
        # drained at the head of the next poll()
        self._events: List[TokenEvent] = []
        self.pos = np.zeros(self.batch, np.int32)
        self.rng = np.random.default_rng(seed)
        self.counters = self._zero_counters()
        if self.layout == "paged":
            self.alloc = BlockAllocator(
                self.n_pages, self.page_size,
                bytes_per_page=S.paged_page_nbytes(self.cfg, self.page_size,
                                                   self.kv_bits))
            self.sched = Scheduler(self.batch, allocator=self.alloc,
                                   max_batched_tokens=self.max_batched_tokens,
                                   max_prefill_chunk=self.max_prefill_chunk,
                                   reserve=self.reserve_policy)
            self.cache = S.init_paged_cache(self.cfg, self.n_pages,
                                            self.page_size, self.kv_bits)
            if self.mesh is not None:
                # lay the pool out sharded before the first donated step so
                # every forward reuses the same per-rank Hkv-slice buffers
                from repro.sharding import partition as Pt
                self.cache = jax.device_put(
                    self.cache, Pt.paged_pool_shardings(self.mesh, self.cache))
            self.block_tables = np.zeros((self.batch, self.max_blocks),
                                         np.int32)
        else:
            self.alloc = None
            self.sched = Scheduler(self.batch)
            self.cache = S.init_cache(self.cfg, self.batch, self.max_len)

    def reset(self, seed: int = 0):
        """Clear all serving state; keeps the compiled graphs."""
        self._init_state(seed)

    # --- cross-replica prefix sharing ------------------------------------

    @property
    def prefix_store(self):
        """The engine's local :class:`~repro.serve.prefix.PrefixStore`
        (the allocator-owned registry), or None for the contiguous
        layout.  Read-only consumers — the router's affinity probe, the
        adoption path — program against this; reference-counted access
        stays behind ``BlockAllocator.match_prefix``."""
        return self.alloc.prefix if self.layout == "paged" else None

    def attach_prefix_tier(self, tier):
        """Wire a :class:`~repro.serve.prefix.SharedPrefixTier` into this
        engine: prefill handoffs publish their sealed chains, and waiting
        prompts adopt matching chains before admission (installed through
        the restore path: payload bytes land in freshly allocated pages
        that are registered and parked, so the subsequent admission sees
        an ordinary prefix hit).  Requires the paged int pool on a single
        rank — under TP each rank holds only its Hkv slice of a page, so
        publish/adopt needs per-rank payload slices (a tracked ROADMAP
        follow-up)."""
        if self.layout != "paged":
            raise EngineConfigError(
                "a shared prefix tier needs the paged cache layout; this "
                f"engine resolved to {self.layout!r}")
        if self.mesh is not None:
            raise EngineConfigError(
                "shared prefix tier under TP needs per-rank publish "
                "slices (ROADMAP follow-up); detach TP or the tier")
        if tier.page_size != self.page_size:
            raise EngineConfigError(
                f"tier page_size={tier.page_size} != engine "
                f"page_size={self.page_size}")
        self.prefix_tier = tier

    def _pool_leaves(self):
        """The paged pool as ``[(leaf_name, array)]`` with a stable
        path-derived name per leaf — the key space SealedChain payloads
        use.  Every leaf (int8/int4 payload and kv4 per-page scales) has
        the pool page axis at axis 1, so page gather/scatter is uniform."""
        flat, _ = jax.tree_util.tree_flatten_with_path(self.cache)
        return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]

    def _publish_prefix(self, prompt: List[int]):
        """Export the just-registered chain's pages the tier doesn't hold
        yet (device->host gather per cache leaf)."""
        from repro.serve.prefix import SealedChain
        tier = self.prefix_tier
        chain = self.alloc.prefix.seal(
            prompt, (len(prompt) - 1) // self.page_size)
        if chain.n_pages == 0:
            return
        held = tier.match(chain.tokens(), chain.n_pages).n_pages
        if held >= chain.n_pages:
            return
        idx = np.asarray(chain.pages[held:], np.int32)
        payload = {name: np.asarray(leaf[:, idx])
                   for name, leaf in self._pool_leaves()}
        sealed = SealedChain(self.page_size, chain.keys[held:],
                             chain.segs[held:], payload)
        self.counters["published_pages"] += tier.publish(sealed)

    def _install_pages(self, sealed, pages: List[int]):
        """Scatter a sealed chain's payload bytes into this pool at
        ``pages`` (host->device, one ``.at[].set`` per leaf).  The bytes
        are exact copies of pages an identical engine computed for the
        identical prefix, so everything downstream — suffix prefill,
        decode reads — is bit-identical to having prefilled them here."""
        idx = np.asarray(pages, np.int32)
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.cache)
        leaves = []
        for path, leaf in flat:
            pay = sealed.payload[jax.tree_util.keystr(path)]
            assert pay.shape[1] == len(pages) and \
                pay.shape[2:] == leaf.shape[2:], (pay.shape, leaf.shape)
            leaves.append(leaf.at[:, idx].set(pay))
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)

    def _adopt_from_tier(self):
        """Pre-admission adoption: for each waiting prompt whose local
        registry match is shorter than what the shared tier holds, install
        the missing pages and register the chain — the allocator's
        registry-version bump then makes admission / ``refresh_prefix``
        see an ordinary prefix hit.  Never preempts: under pool pressure
        (alloc returns None) adoption is skipped and the prompt recomputes
        as if the tier did not exist."""
        al = self.alloc
        ps = self.page_size
        for _rid, item in list(self.sched.waiting):
            tokens = item.prompt_tokens() if isinstance(item, SlotState) \
                else item.prompt
            prompt = [int(t) for t in np.asarray(tokens).reshape(-1)]
            want = (len(prompt) - 1) // ps
            if want <= 0 or al.prefix.match(prompt, want).n_pages >= want:
                continue
            sealed = self.prefix_tier.adopt(prompt, want)
            if sealed is None:
                continue
            held = al.match_prefix(prompt, want)     # refs pin the head
            if sealed.n_pages <= len(held):
                al.free_pages(held[::-1])
                continue
            fresh = al.alloc(sealed.n_pages - len(held))
            if fresh is None:                        # pool dry: recompute
                al.free_pages(held[::-1])
                continue
            self._install_pages(sealed.slice(len(held), sealed.n_pages),
                                fresh)
            al.prefix.register(prompt[:sealed.n_pages * ps], held + fresh)
            al.free_pages((held + fresh)[::-1])      # park on the LRU
            self.counters["adopted_pages"] += len(fresh)

    # --- observability ---------------------------------------------------

    def stats(self, check: bool = False) -> Dict:
        """Instantaneous serving gauges + the cumulative ``counters``.

        Invariants the engine maintains (asserted in the tests, logged per
        tick by serve_bench): occupied slots partition into decode-active +
        prefilling; in the paged layout ``pages_in_use + pages_free +
        pages_cached_lru == pages_capacity`` and every prefilling slot's
        pending rows fit the pages it reserved.  ``check=True`` also sweeps
        ``BlockAllocator.check_invariants()`` — O(n_pages), so the tests'
        per-tick assertions opt in while bench/monitoring reads (which time
        the step loop) stay cheap.

        The payload is the frozen, versioned schema in
        ``repro.serve.stats`` (carried under ``schema_version``) and is
        validated against it on every read — the router,
        ``serve_bench.py``, and ``check_regression.py`` all consume the
        same key sets."""
        pre = [self.sched.slots[b] for b in self.sched.prefilling]
        chunk = self.max_prefill_chunk
        pending = [st.prompt_len - st.prefill_pos for st in pre]
        g = dict(
            schema_version=stats_schema.STATS_SCHEMA_VERSION,
            waiting=len(self.sched.waiting),
            decode_slots_active=len(self.sched.decoding),
            prefill_slots=len(pre),
            free_slots=self.sched.n_free,
            prefill_tokens_pending=sum(pending),
            prefill_chunks_pending=sum(
                -(-p // chunk) if chunk else 1 for p in pending),
            spec_k=self.spec_k,
        )
        if self.layout == "paged":
            al = self.alloc
            if check:
                al.check_invariants()
            g.update(pages_in_use=al.live,
                     pages_free=al.free_list_pages,
                     pages_cached_lru=al.lru_pages,
                     pages_capacity=al.capacity,
                     tp=self.tp)
        g["counters"] = dict(self.counters)
        return stats_schema.validate_stats(g, paged=self.layout == "paged")

    def hot_graphs(self) -> Dict[str, tuple]:
        """``name -> (jitted_fn, example_args)`` for every compiled hot
        graph of this engine, with representative arguments built from the
        live state (the real donated cache pytree, zero tokens, the current
        block tables).

        This is the introspection surface ``repro.analysis.jaxpr_audit``
        walks: ``jax.make_jaxpr(fn)(*args)`` / ``fn.lower(*args)`` only
        *trace* the graphs, so the donated cache is never consumed and the
        engine keeps serving afterwards.  Paged engines expose ``decode``,
        ``prefill_chunk`` (one padded chunk at batch 1, the shape
        ``_run_chunk`` compiles) and — when speculation is on — ``verify``;
        the contiguous layout exposes ``decode`` only (its prefill builds a
        private non-donated cache per request)."""
        tok = jnp.zeros((self.batch, 1), jnp.int32)
        pos = jnp.asarray(self.pos)
        if self.layout != "paged":
            return {"decode": (self._decode,
                               (self.folded, self.cache, tok, pos))}
        btab = jnp.asarray(self.block_tables)
        graphs: Dict[str, tuple] = {
            "decode": (self._decode,
                       (self.folded, self.cache, tok, pos, btab)),
        }
        chunk = self.max_prefill_chunk or 2 * self.page_size
        chunk = pages_needed(min(chunk, self.smax),
                             self.page_size) * self.page_size
        graphs["prefill_chunk"] = (self._prefill, (
            self.folded, self.cache, jnp.zeros((1, chunk), jnp.int32),
            btab[:1], jnp.int32(0)))
        if self.spec_k:
            graphs["verify"] = (self._verify, (
                self.folded, self.cache,
                jnp.zeros((self.batch, self.spec_k + 1), jnp.int32),
                pos, btab, jnp.ones((self.batch,), jnp.int32)))
        return graphs

    # --- contiguous-layout helpers ---------------------------------------

    def _bucket_len(self, ln: int) -> int:
        """Padded one-shot prefill length for the contiguous layout: a
        multiple of prefill_bucket so compiled shapes are reused.  (Paged
        chunks pad to whole pages instead — see _run_chunk.)"""
        return min(max(self.prefill_bucket,
                       math.ceil(ln / self.prefill_bucket)
                       * self.prefill_bucket), self.smax)

    def _set_table_row(self, b: int, pages: List[int]):
        self.block_tables[b, :] = 0
        self.block_tables[b, :len(pages)] = pages

    # --- request lifecycle ----------------------------------------------

    def submit(self, request: Request) -> int:
        ln = len(request.prompt)
        # hard validation, not an assert: max_new_tokens >= 1 is what makes
        # the ln + max_new - 1 page reservation always cover the prefill
        # scatter's whole-page padding (pages_needed(ln) rows)
        if ln < 1 or request.max_new_tokens < 1:
            raise ValueError(
                f"request needs a non-empty prompt and max_new_tokens >= 1 "
                f"(got prompt len {ln}, max_new_tokens "
                f"{request.max_new_tokens})")
        if (not self.cfg.sliding_window
                and ln + request.max_new_tokens > self.max_len):
            raise ValueError(
                f"request needs {ln + request.max_new_tokens} cache rows, "
                f"engine max_len={self.max_len}")
        if self.layout == "paged":
            worst = pages_needed(ln + request.max_new_tokens - 1,
                                 self.page_size)
            if worst > self.alloc.capacity:
                raise ValueError(
                    f"request needs up to {worst} cache pages, pool has "
                    f"{self.alloc.capacity} (n_pages={self.n_pages})")
        rid = self.sched.submit(request)
        request.rid = rid
        request.status = RequestStatus.WAITING
        request.finish_reason = None
        request.out = None
        self.requests[rid] = request
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request anywhere in its lifecycle.  A seated request's
        pages are freed through the ordinary eviction path (the same code
        completion runs), a waiting one is removed from the queue; either
        way the request goes CANCELLED, its partial tokens land in
        ``.out``, and the next ``poll()`` emits the terminal event.
        Returns False when ``rid`` is unknown or already terminal."""
        req = self.requests.get(rid)
        if req is None:
            return False
        for b, st in enumerate(self.sched.slots):
            if st is not None and st.rid == rid:
                st = self.sched.evict(b)       # frees the page chain
                self.pos[b] = 0
                if self.layout == "paged":
                    self.block_tables[b, :] = 0
                emitted = st.emitted
                break
        else:
            item = self.sched.remove_waiting(rid)
            assert item is not None, f"rid {rid} tracked but not found"
            # a preempted SlotState kept its emitted tokens; a plain queued
            # request has none (its pages were already freed at preemption)
            emitted = item.emitted if isinstance(item, SlotState) else []
        self._terminate(rid, req, emitted, RequestStatus.CANCELLED,
                        "cancelled")
        self.counters["cancelled"] += 1
        return True

    def _terminate(self, rid: int, req: Request, emitted: List[int],
                   status: RequestStatus, reason: str):
        """Move a request to a terminal exit and queue its final event.
        ``rid`` is passed explicitly (not read off ``req.rid``): a router
        re-stamps ``req.rid`` with its own global id, while the engine's
        table and event stream stay keyed by the engine-local rid."""
        self.requests.pop(rid, None)
        req.out = np.asarray(emitted, np.int32)
        req.status = status
        req.finish_reason = reason
        self._events.append(TokenEvent(rid, None, len(emitted), True,
                                       reason))

    def _shed_expired(self):
        """Shed WAITING requests whose ``deadline_tick`` has passed (run at
        the head of every poll, before admission): they leave through the
        same terminal path as cancellation — a shed request can never be
        holding pages (a queued request has none; a preempted SlotState's
        were freed at preemption), so the pool cannot be poisoned."""
        if not self.sched.waiting:
            return
        t = self.counters["ticks"]
        for rid, item in [(r, i) for r, i in self.sched.waiting]:
            req = item.request if isinstance(item, SlotState) else item
            if req.deadline_tick is None or t < req.deadline_tick:
                continue
            self.sched.remove_waiting(rid)
            emitted = item.emitted if isinstance(item, SlotState) else []
            self._terminate(rid, req, emitted, RequestStatus.CANCELLED,
                            "deadline")
            self.counters["shed_deadline"] += 1

    def _pick_token(self, logits_row: np.ndarray, req: Request) -> int:
        if req.temperature > 0:
            z = logits_row / max(req.temperature, 1e-4)
            z = z + self.rng.gumbel(size=z.shape)
            return int(np.argmax(z))
        return int(np.argmax(logits_row))

    def _prefill_request(self, req: Request) -> Tuple[np.ndarray, object, int]:
        """Contiguous layout: build the batch-1 cache for a prompt; returns
        (last-position logits (V,), cache1, prompt_len)."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        ln = len(prompt)
        if self._attn_only and ln <= self.smax:
            # one-shot: pad to a bucket so compiled prefill shapes are reused;
            # a pad row at cache index r is overwritten by the decode step at
            # pos == r — the same step whose mask first admits index r — so
            # pad garbage is never attended
            bl = self._bucket_len(ln)
            toks = np.zeros((1, bl), np.int32)
            toks[0, :ln] = prompt
            logits, cache1 = self._prefill(self.folded, jnp.asarray(toks))
            return np.asarray(logits[0, ln - 1]), cache1, ln
        # recurrence (SSM/hybrid) or over-long SWA prompt: batch-1 decode loop
        cache1 = S.init_cache(self.cfg, 1, self.max_len)
        logits = None
        for t in range(ln):
            logits, cache1 = self._decode(
                self.folded, cache1, jnp.asarray(prompt[t].reshape(1, 1)),
                jnp.asarray(np.asarray([t], np.int32)))
            self.counters["loop_prefill_steps"] += 1
        return np.asarray(logits[0, -1]), cache1, ln

    def _run_chunk(self, b: int, st: SlotState, pos0: int, ntok: int
                   ) -> List[TokenEvent]:
        """One prefill chunk for slot ``b``: rows [pos0, pos0+ntok) of the
        prompt through the chunk forward.  On the FINAL chunk the last real
        row's logits hand the request straight into decode (first token
        sampled, no extra forward); mid-prompt chunks emit nothing.

        Paged: the chunk scatters its K/V through a local block-table row
        and attends over the slot's whole mapped chain (prior chunks +
        shared prefix pages read directly from the page pool).  The engine's
        shared ``block_tables`` row stays zeroed (trash page) until handoff,
        so decode ticks running while this slot is mid-prefill cannot
        scribble on its pages.  Contiguous: a single whole-prompt chunk via
        the batch-1 prefill + slot write (chunking needs pages).

        A restored preempted slot runs through this same path — its
        ``prompt_tokens`` replay sequence includes any tokens it emitted
        before the spill.  Each chunk charges ``recomputed_tokens`` for the
        rows it re-runs below the slot's high-water mark (the furthest row
        ever computed, across every spill) — rows the prefix registry gave
        back are skipped by the cursor and never charged."""
        req = st.request
        prompt = np.asarray(st.prompt_tokens(), np.int32).reshape(-1)
        ln = len(prompt)
        if pos0 < st.hwm_rows:
            self.counters["recomputed_tokens"] += \
                min(pos0 + ntok, st.hwm_rows) - pos0
        final = pos0 + ntok >= ln
        loop_prefill = False
        if self.layout == "paged":
            # ragged last chunk pads to whole pages (the scatter writes
            # whole pages); pad rows sit causally after every real query
            # and are overwritten by the decode step at their position
            pad = pages_needed(ntok, self.page_size) * self.page_size
            toks = np.zeros((1, pad), np.int32)
            toks[0, :ntok] = prompt[pos0:pos0 + ntok]
            btab = np.zeros((1, self.max_blocks), np.int32)
            btab[0, :len(st.pages)] = st.pages
            logits, self.cache = self._prefill(
                self.folded, self.cache, jnp.asarray(toks),
                jnp.asarray(btab), jnp.int32(pos0))
            last = np.asarray(logits[0, ntok - 1]) if final else None
        else:
            assert pos0 == 0 and final, \
                "contiguous layout prefills in one whole-prompt chunk"
            loop_prefill = not (self._attn_only and ln <= self.smax)
            last, cache1, _ = self._prefill_request(req)
            self.cache = self._write_slot(self.cache, cache1, jnp.int32(b))
        st.prefill_pos = pos0 + ntok
        st.chunks_done += 1
        self.counters["prefill_tokens"] += ntok
        self.counters["prefill_chunks"] += 1
        if not final:
            return []
        # --- handoff into decode (no extra forward) ---
        if self.layout == "paged":
            ptoks = [int(t) for t in prompt]
            self.alloc.register_prefix(ptoks, st.pages)
            if self.prefix_tier is not None:
                self._publish_prefix(ptoks)
            self._set_table_row(b, st.pages)
        # the replay snapshot is spent: decode appends to ``emitted`` from
        # here, so keeping it would silently desync prompt_tokens(); the
        # next spill (if any) rebuilds it from prompt + emitted
        st.tokens = None
        if st.shared_rows:
            self.counters["prefix_hits"] += 1
            self.counters["shared_rows"] += st.shared_rows
            if st.chunks_done == 1:
                self.counters["suffix_prefills"] += 1
        elif st.chunks_done == 1 and not loop_prefill:
            self.counters["oneshot_prefills"] += 1
        if st.chunks_done > 1:
            self.counters["chunked_prefills"] += 1
        self.pos[b] = ln
        st.pos = ln
        tok = self._pick_token(last, req)
        st.last_token = tok
        st.emitted.append(tok)
        req.status = RequestStatus.DECODE
        if self._done(st):
            self._finish(b)
        return [TokenEvent(st.rid, tok, len(st.emitted) - 1,
                           req.status.terminal, req.finish_reason)]

    def _finish(self, b: int):
        st = self.sched.evict(b)        # paged: returns the page chain
        req = self.requests.pop(st.rid)
        req.out = np.asarray(st.emitted, np.int32)
        req.status = RequestStatus.FINISHED
        req.finish_reason = "eos" if (
            req.eos_token is not None and st.emitted
            and st.emitted[-1] == req.eos_token) else "length"
        self.pos[b] = 0
        if self.layout == "paged":
            self.block_tables[b, :] = 0
        self.counters["completed"] += 1

    # --- on-demand growth + preemption -----------------------------------

    def _preempt(self, b: int):
        """Spill slot ``b`` (scheduler registers its finished pages and
        requeues it at the queue front) and clear its engine-side rows."""
        st = self.sched.slots[b]
        was_prefilling = st.prefilling
        self.sched.preempt(b)
        st.request.status = RequestStatus.WAITING
        self.pos[b] = 0
        self.block_tables[b, :] = 0
        self.counters["preemptions"] += 1
        self.counters["preempted_prefill" if was_prefilling
                      else "preempted_decode"] += 1
        self.counters["spilled_rows"] += st.spilled_rows

    def _grow_rows(self, b: int, st: SlotState, rows: int):
        """Grow slot ``b``'s page chain to cover ``rows`` cache rows,
        preempting victims while the pool is dry.  ``submit`` caps every
        request's worst-case pages at pool capacity (speculative rows
        included: the per-slot draft budget keeps the furthest verify row
        at plain decode's worst case), so once every other slot is spilled
        the allocation cannot fail — the RuntimeError is a genuine
        invariant breach, not an operating condition."""
        while True:
            got = self.sched.grow(st, rows)
            if got is not None:
                self.counters["grown_pages"] += got
                break
            v = self.sched.pick_victim(exclude=frozenset({b}))
            if v is None:
                raise RuntimeError(
                    "page pool exhausted with no preemption victim; "
                    "submit() sizing makes this unreachable")
            self._preempt(v)
        if got:                         # chain unchanged -> row already set
            self._set_table_row(b, st.pages)

    def _grow_decode_pages(self):
        """On-demand mode, run between the tick's prefill chunks and its
        decode forward: make sure every decoding slot owns the page its
        write cursor is about to enter.  Slots grow oldest-first; when the
        pool comes up empty the scheduler names a victim (last-admitted
        prefilling slot, else longest-remaining decoder — never the oldest
        seated request while another candidate exists) which is spilled and
        the allocation retried."""
        order = sorted(self.sched.decoding,
                       key=lambda b: self.sched.slots[b].rid)
        for b in order:
            st = self.sched.slots[b]
            if st is None:              # preempted by an earlier grower
                continue
            self._grow_rows(b, st, st.pos + 1)

    def _done(self, st: SlotState) -> bool:
        req = st.request
        if len(st.emitted) >= req.max_new_tokens:
            return True
        return req.eos_token is not None and st.emitted and \
            st.emitted[-1] == req.eos_token

    # --- speculative decode (draft-then-verify) --------------------------

    def _spec_tick(self) -> Optional[List[TokenEvent]]:
        """One speculative decode tick: draft, verify, greedy-accept.

        Replaces the plain (B, 1) decode forward with a single (B,
        ``spec_k``+1) verify forward when at least one slot has draft
        proposals.  Per slot the verify rows are ``[last_token, d_1, ...,
        d_n]`` at cache positions ``pos .. pos+n``; row ``j``'s logits are
        what plain decode would have produced after committing the first
        ``j`` proposals, so greedily accepting while ``d_j == argmax(row
        j-1)`` is bit-identical to running plain decode ``n_acc+1`` times
        (the final row's argmax is the free "bonus" token).  The write
        cursor (``st.pos`` / ``self.pos``) advances only over committed
        tokens — rejected tail rows hold garbage K/V *past* the cursor,
        which the next forward overwrites write-before-read, so rollback
        is a no-op on the allocator.

        Phases:

        1. propose — ask the draft source for up to ``k_b`` tokens per
           greedy decoding slot, where ``k_b`` caps at the slot's
           remaining ``max_new_tokens`` budget minus the bonus token
           (keeps the furthest verify row at plain decode's worst case,
           so ``submit``'s page-cap invariant is untouched).  Sampling
           slots (temperature > 0) are never drafted for: acceptance is
           exact argmax matching.  No proposals anywhere -> return None
           and let the plain decode graph run.
        2. grow (on-demand reservation only) — extend each proposing
           slot's page chain to cover its verify rows, in rid order,
           preempting victims like :meth:`_grow_decode_pages`.  A slot
           preempted by an earlier grower drops its proposals.
        3. verify — ONE forward at the fixed compiled shape (B,
           ``spec_k``+1); non-proposing slots ride along with one real
           row (their plain decode step), padding rows scatter to the
           trash page.
        4. accept — per slot, walk rows while proposals match the argmax
           chain; emit accepted tokens + the first divergent/bonus token,
           truncated by ``max_new_tokens``/EOS exactly as plain decode
           would be.  Counters: ``drafted``/``accepted``/``rejected`` and
           ``accept_len_hist`` (accepted-prefix length -> slot-tick
           count); the forward charges one ``decode_steps``.
        """
        active = self.sched.decoding
        props: Dict[int, List[int]] = {}
        for b in active:
            st = self.sched.slots[b]
            req = st.request
            if req.temperature > 0:
                continue                    # greedy acceptance only
            k_b = min(self.spec_k,
                      req.max_new_tokens - len(st.emitted) - 1)
            if k_b <= 0:
                continue
            ctx = np.concatenate(
                [np.asarray(req.prompt, np.int64).reshape(-1),
                 np.asarray(st.emitted, np.int64)])
            p = [int(t) for t in self.draft.propose(ctx, k_b)[:k_b]]
            if p:
                props[b] = p
        if not props:
            return None                     # plain decode graph this tick
        if self.reserve_policy == "ondemand":
            for b in sorted(props, key=lambda i: self.sched.slots[i].rid
                            if self.sched.slots[i] is not None else -1):
                st = self.sched.slots[b]
                if st is None:              # preempted by an earlier grower
                    continue
                self._grow_rows(b, st, st.pos + 1 + len(props[b]))
        active = self.sched.decoding        # growth may have preempted
        live = set(active)
        props = {b: p for b, p in props.items() if b in live}
        if not props:
            return None
        toks = np.zeros((self.batch, self.spec_k + 1), np.int32)
        nrows = np.ones((self.batch,), np.int32)
        for b in active:
            toks[b, 0] = self.sched.slots[b].last_token
        for b, p in props.items():
            toks[b, 1:1 + len(p)] = p
            nrows[b] = 1 + len(p)
        logits, self.cache = self._verify(
            self.folded, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos), jnp.asarray(self.block_tables),
            jnp.asarray(nrows))
        rows = np.asarray(logits)           # (B, spec_k+1, V)
        events: List[TokenEvent] = []
        n_emitted = 0
        for b in active:
            st = self.sched.slots[b]
            req = st.request
            p = props.get(b, [])
            n_prop = len(p)
            emit: List[int] = []
            j = 0
            while True:
                tok = self._pick_token(rows[b, j], req)
                emit.append(tok)
                if len(st.emitted) + len(emit) >= req.max_new_tokens or (
                        req.eos_token is not None and tok == req.eos_token):
                    break                   # request finishes on this token
                if j < n_prop and p[j] == tok:
                    j += 1                  # proposal matched: next row
                    continue
                break                       # divergence: tok is the repair
            if n_prop:
                # accepted prefix length (the loop only advances on
                # matches, so matching positions form a prefix of emit)
                n_acc = sum(1 for i in range(min(len(emit), n_prop))
                            if p[i] == emit[i])
                self.counters["drafted"] += n_prop
                self.counters["accepted"] += n_acc
                self.counters["rejected"] += n_prop - n_acc
                h = self.counters["accept_len_hist"]
                h[n_acc] = h.get(n_acc, 0) + 1
            for tok in emit:
                st.last_token = tok
                st.emitted.append(tok)
                self.pos[b] += 1
                st.pos += 1
                done = self._done(st)
                if done:
                    self._finish(b)
                events.append(TokenEvent(st.rid, tok, len(st.emitted) - 1,
                                         req.status.terminal,
                                         req.finish_reason))
                if done:
                    break
            n_emitted += len(emit)
        self.counters["decode_steps"] += 1
        self.counters["decode_tokens"] += n_emitted
        return events

    # --- the engine loop ------------------------------------------------

    def poll(self) -> List[TokenEvent]:
        """One scheduler tick of the token-budget loop:

        0. shed WAITING requests whose ``deadline_tick`` has passed, and
           flush terminal events queued by ``cancel()`` since last tick,
        1. seat waiting requests into free slots (paged: reserve their page
           budget; prefill does NOT run here),
        2. run prefill chunks for prefilling slots under the tick's token
           budget (``max_batched_tokens`` minus this tick's decode tokens;
           a final chunk also charges the decode token of its handoff),
           replanning after every chunk so a completion's registered prefix
           is visible to the next slot's first chunk,
        3. (on-demand reservation) grow each decoding slot's page chain
           where its write cursor crosses a page boundary, preempting a
           victim when the pool runs dry,
        4. decode one token for every slot whose prompt is fully cached
           (slots that handed off in step 2 join the same tick's batch).
           With ``spec_k > 0`` and at least one slot holding draft
           proposals, step 4 instead runs one multi-row verify forward
           (:meth:`_spec_tick`) that can commit several tokens per slot.

        Returns this tick's :class:`TokenEvent` stream, in emission order.
        Every request's stream ends with exactly one ``final`` event; a
        cancelled/shed request's final event carries ``token=None``."""
        self.counters["ticks"] += 1
        self._shed_expired()
        events = self._events            # cancel/shed events queued so far
        self._events = []
        if self.prefix_tier is not None and self.sched.waiting:
            self._adopt_from_tier()      # before admission: adopted pages
            #                              surface as ordinary prefix hits
        placed = self.sched.admit()
        for _b, st in placed:
            st.request.status = RequestStatus.PREFILL
            if st.preemptions:          # a spilled request re-seated
                self.counters["restores"] += 1
        if self.layout == "paged" and self.sched.waiting \
                and self.sched.n_free > 0:
            # a request is waiting on PAGES, not slots: the stranded-
            # capacity signal the overload bench A/Bs across policies
            self.counters["pool_wait_ticks"] += 1
        n_decode = len(self.sched.decoding)
        used = 0
        chunked: set = set()
        while True:
            plan = self.sched.next_chunk(n_decode, used,
                                         exclude=frozenset(chunked))
            if plan is None:
                break
            b, st, pos0, ntok = plan
            chunked.add(b)
            # a final chunk hands the slot into this tick's decode batch:
            # charge its decode token so the budget stays a real cap
            used += ntok + (pos0 + ntok >= st.prompt_len)
            events.extend(self._run_chunk(b, st, pos0, ntok))
        for b in self.sched.prefilling:   # scheduler anti-starvation input
            st = self.sched.slots[b]
            st.starved_ticks = 0 if b in chunked else st.starved_ticks + 1
        if self.layout == "paged" and self.reserve_policy == "ondemand":
            self._grow_decode_pages()     # may preempt victims
        active = self.sched.decoding
        if self.layout == "paged":
            self.counters["cache_pages_peak"] = self.alloc.peak_live
        if not active:
            return events
        if self.spec_k and self.draft is not None:
            spec = self._spec_tick()
            if spec is not None:            # verify forward ran this tick
                events.extend(spec)
                self.counters["cache_pages_peak"] = self.alloc.peak_live
                return events
            # no proposals anywhere: fall through to plain decode
        toks = np.zeros((self.batch, 1), np.int32)
        for b in active:
            toks[b, 0] = self.sched.slots[b].last_token
        extra = ((jnp.asarray(self.block_tables),)
                 if self.layout == "paged" else ())
        logits, self.cache = self._decode(
            self.folded, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos), *extra)
        rows = np.asarray(logits[:, -1])          # (B, V)
        for b in active:
            st = self.sched.slots[b]
            req = st.request
            self.pos[b] += 1
            st.pos += 1
            tok = self._pick_token(rows[b], req)
            st.last_token = tok
            st.emitted.append(tok)
            if self._done(st):
                self._finish(b)
            events.append(TokenEvent(st.rid, tok, len(st.emitted) - 1,
                                     req.status.terminal,
                                     req.finish_reason))
        self.counters["decode_steps"] += 1
        self.counters["decode_tokens"] += len(active)
        return events

    @property
    def has_work(self) -> bool:
        """True while a poll() could still produce events: live requests
        anywhere in the pipeline, or queued terminal events."""
        return bool(self._events) or self.sched.has_work

    def step(self) -> List[Tuple[int, int]]:
        """Back-compat wrapper over :meth:`poll`: one tick, returning the
        (rid, token) pairs emitted (token-less terminal events dropped)."""
        return [(e.rid, e.token) for e in self.poll()
                if e.token is not None]

    def run(self) -> List[Tuple[int, int]]:
        """Drain the queue; returns every (rid, token) emitted."""
        out = []
        while self.has_work:
            out.extend(self.step())
        return out

    def generate(self, requests: List[Request]) -> List[Request]:
        """Batch convenience API: submit everything, drain, return the same
        requests with ``.out`` filled (continuous batching inside)."""
        for r in requests:
            self.submit(r)
        self.run()
        return requests


class LockstepEngine:
    """The original lockstep engine: one shared position scalar, prefill
    replayed through the decode graph for the whole (same-length) batch.
    Kept as the serve_bench baseline and for archs the continuous engine
    doesn't take (audio codebooks)."""

    def __init__(self, cfg: ModelConfig, folded,
                 config: Optional[EngineConfig] = None, **kw):
        config = _config_only(config, kw, "LockstepEngine")
        self.cfg = cfg
        self.folded = folded
        self.config = config
        self.batch = config.batch_slots
        self.max_len = config.max_len
        self.cache = S.init_cache(cfg, self.batch, self.max_len)
        self.pos = np.zeros(self.batch, np.int32)
        self.key = jax.random.PRNGKey(config.seed)

        def decode_step(folded_, cache, tok, pos):
            return S.serve_forward(cfg, folded_, tok, cache=cache,
                                   pos_offset=pos, mode="decode")

        self._decode = jax.jit(decode_step, donate_argnums=(1,))

    def reset(self, seed: int = 0):
        self.cache = S.init_cache(self.cfg, self.batch, self.max_len)
        self.pos = np.zeros(self.batch, np.int32)
        self.key = jax.random.PRNGKey(seed)

    def _step(self, tokens_col: np.ndarray, pos_scalar: int):
        tok = jnp.asarray(tokens_col).reshape(self.batch, 1)
        logits, self.cache = self._decode(self.folded, self.cache, tok,
                                          jnp.int32(pos_scalar))
        return logits[:, -1] if logits.ndim == 3 else logits[:, :, -1]

    def generate(self, requests: List[Request]) -> List[Request]:
        """Lockstep decode for a batch of same-length-padded prompts."""
        assert len(requests) <= self.batch
        max_prompt = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((self.batch, max_prompt), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        outs = [[] for _ in requests]
        # prefill via lockstep decode (works uniformly for attn/ssm/hybrid)
        last_logits = None
        for t in range(max_prompt):
            last_logits = self._step(toks[:, t], t)
        cur = np.asarray(jnp.argmax(last_logits, -1)).astype(np.int32)
        for i in range(len(requests)):
            outs[i].append(int(cur[i]))
        for t in range(max_prompt, max_prompt + max_new - 1):
            logits = self._step(cur, t)
            if any(r.temperature > 0 for r in requests):
                self.key, sub = jax.random.split(self.key)
                samp = jax.random.categorical(sub, logits / max(
                    requests[0].temperature, 1e-4), -1)
                cur = np.asarray(samp).astype(np.int32)
            else:
                cur = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            for i in range(len(requests)):
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(cur[i]))
        for r, o in zip(requests, outs, strict=True):
            r.out = np.asarray(o, np.int32)
            r.status = RequestStatus.FINISHED
            r.finish_reason = "length"
        return requests
