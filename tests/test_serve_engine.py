"""Continuous-batching engine: scheduler mechanics, token-for-token
equivalence with the lockstep baseline, and mid-flight admission."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.engine import (Engine, EngineConfig, EngineConfigError,
                                LockstepEngine, Request, make_engine)
from repro.serve.scheduler import Scheduler

KEY = jax.random.PRNGKey(0)


# --- scheduler unit tests -----------------------------------------------------

def test_scheduler_fifo_admission_and_eviction():
    s = Scheduler(2)
    rids = [s.submit(f"req{i}") for i in range(4)]
    assert rids == [0, 1, 2, 3]
    placed = s.admit()
    assert [(b, st.rid) for b, st in placed] == [(0, 0), (1, 1)]
    assert s.n_free == 0 and len(s.waiting) == 2
    assert s.admit() == []                     # table full -> no-op
    s.evict(0)
    placed = s.admit()                         # freed slot takes next FIFO
    assert [(b, st.rid) for b, st in placed] == [(0, 2)]
    assert s.active == [0, 1]
    s.evict(0)
    s.evict(1)
    placed = s.admit()
    assert [(b, st.rid) for b, st in placed] == [(0, 3)]
    s.evict(0)
    assert not s.has_work


def test_scheduler_evict_empty_slot_asserts():
    s = Scheduler(1)
    with pytest.raises(AssertionError):
        s.evict(0)


# --- engine equivalence -------------------------------------------------------

def _folded(cfg):
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return F.fold_params(cfg, params, obs)


def _mixed_requests(cfg, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, (ln,)
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for ln, mn in zip(lens, max_news)]


@pytest.mark.parametrize("layout,kw", [
    ("contiguous", {}),
    ("paged", dict(page_size=8)),
    # tight pool, full reservation: admission stalls, decode never OOMs
    ("paged", dict(page_size=4, n_pages=9, reserve_policy="full")),
    # tight pool, on-demand growth: decode pages granted at boundary
    # crossings, exhaustion resolved by preemption — tokens unchanged
    ("paged", dict(page_size=4, n_pages=9)),
])
def test_continuous_matches_lockstep_token_for_token(layout, kw):
    """Greedy continuous batching (one-shot prefill, per-slot positions,
    mid-flight admission) must reproduce, per request, exactly what the
    lockstep engine produces for that request alone — in BOTH cache
    layouts and BOTH page-reservation policies (including with a pool
    small enough to force out-of-pages waits or preemptions)."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    lens = [3, 11, 6, 17, 5]
    max_news = [4, 6, 5, 3, 6]

    lock = LockstepEngine(cfg, folded, EngineConfig(batch_slots=1, max_len=64))
    truth = []
    for r in _mixed_requests(cfg, lens, max_news):
        lock.reset()
        truth.append(lock.generate([r])[0].out.tolist())

    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           prefill_bucket=4,
                                           cache_layout=layout, **kw))
    assert eng.layout == layout
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    got = [r.out.tolist() for r in out]
    assert got == truth
    # more requests than slots -> the scheduler really streamed them
    assert eng.counters["completed"] == len(lens)
    assert eng.counters["loop_prefill_steps"] == 0
    if eng.counters["preemptions"] == 0:
        assert eng.counters["oneshot_prefills"] == len(lens)
    if layout == "paged":
        # reservation-based pool: peak pages reflect actual, not worst-case,
        # sequence memory — strictly under the contiguous footprint
        assert 0 < eng.counters["cache_pages_peak"] <= eng.alloc.capacity
        assert eng.alloc.live == 0                # all pages came back
        if eng.reserve_policy == "full":
            assert eng.counters["preemptions"] == 0
            assert eng.counters["grown_pages"] == 0


def test_engine_streaming_admission_and_determinism():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64))

    def run():
        eng.reset()
        reqs = _mixed_requests(cfg, [4, 9, 6, 5], [5, 5, 5, 5], seed=3)
        return [r.out.tolist() for r in eng.generate(reqs)]

    a, b = run(), run()
    assert a == b                       # greedy decode is deterministic
    assert all(len(o) == 5 for o in a)


def test_engine_eos_eviction_frees_slot():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, EngineConfig(batch_slots=1, max_len=64))
    # discover the greedy continuation, then rerun with it as the EOS token
    probe = _mixed_requests(cfg, [5, 7], [6, 6], seed=1)
    out = eng.generate(probe)
    eos = int(out[0].out[2])            # third emitted token of request 0
    eng.reset()
    reqs = _mixed_requests(cfg, [5, 7], [6, 6], seed=1)
    reqs[0].eos_token = eos
    out2 = eng.generate(reqs)
    assert out2[0].out.tolist() == out[0].out.tolist()[:3]  # stopped at EOS
    assert out2[0].finish_reason == "eos"
    assert out2[1].out.tolist() == out[1].out.tolist()      # unaffected
    assert out2[1].finish_reason == "length"
    assert eng.counters["completed"] == 2


def test_engine_rejects_overlong_request():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, EngineConfig(batch_slots=1, max_len=16))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(12, np.int32), max_new_tokens=8))


def test_paged_rejects_request_larger_than_pool():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged",
        page_size=4, n_pages=3))                 # 2 allocatable pages
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(10, np.int32), max_new_tokens=4))


def test_paged_prefix_reuse_skips_prefill_and_pages():
    """Requests repeating one system prompt must map its cached pages
    (refcounted sharing), run only the unseen suffix, produce tokens
    identical to the contiguous engine, and use fewer peak pages than
    exclusive stripes would."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(0, cfg.vocab_size, (24,)).astype(np.int32)

    def requests(seed):
        r = np.random.default_rng(seed)
        return [Request(prompt=np.concatenate(
                    [sys_prompt,
                     r.integers(0, cfg.vocab_size, (3 + i,)).astype(np.int32)]),
                    max_new_tokens=4)
                for i in range(5)]

    cont = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                            cache_layout="contiguous"))
    truth = [r.out.tolist() for r in cont.generate(requests(7))]

    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           cache_layout="paged", page_size=8))
    out = eng.generate(requests(7))
    assert [r.out.tolist() for r in out] == truth
    # first request prefills one-shot; the other four share its prefix pages
    assert eng.counters["oneshot_prefills"] == 1
    assert eng.counters["prefix_hits"] == 4
    assert eng.counters["shared_rows"] == 4 * 24     # 3 pages x 8 rows each
    # paged peak well under the contiguous footprint (2 slots x smax rows)
    assert eng.counters["cache_pages_peak"] < eng.batch * eng.max_blocks
    # prefix pages stay cached (LRU) after every sharer finished
    assert eng.alloc.live == 0 and eng.alloc.cached_pages > 0


def test_paged_prefix_cache_survives_eviction():
    """The prefix registry keeps refcount-0 pages cached: a request arriving
    AFTER every earlier sharer completed still hits."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (17,)).astype(np.int32)
    eng = Engine(cfg, folded, EngineConfig(batch_slots=1, max_len=64,
                                           cache_layout="paged", page_size=8))
    first = eng.generate([Request(prompt=prompt.copy(), max_new_tokens=4)])
    assert eng.counters["prefix_hits"] == 0
    second = eng.generate([Request(prompt=prompt.copy(), max_new_tokens=4)])
    assert eng.counters["prefix_hits"] == 1
    assert second[0].out.tolist() == first[0].out.tolist()


# --- EngineConfig + make_engine surface ---------------------------------------

def _lockstep_cfg_folded():
    cfg = smoke_config("musicgen-medium", n_layers=1)
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, cfg.n_codebooks, 8), 0,
                               cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def test_make_engine_warns_on_dropped_config_fields():
    """make_engine must not silently reset continuous-only config fields
    for lockstep archs (musicgen: audio codebooks)."""
    cfg, folded = _lockstep_cfg_folded()
    with pytest.warns(UserWarning, match="prefill_bucket"):
        eng = make_engine(cfg, folded, EngineConfig(
            batch_slots=2, max_len=32, prefill_bucket=8))
    assert isinstance(eng, LockstepEngine)
    with pytest.warns(UserWarning, match="cache_layout"):
        make_engine(cfg, folded, EngineConfig(
            batch_slots=2, max_len=32, cache_layout="paged", page_size=8))


def test_make_engine_passes_config_to_continuous():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = make_engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, prefill_bucket=4,
        cache_layout="paged", page_size=8))
    assert isinstance(eng, Engine)
    assert eng.layout == "paged" and eng.page_size == 8
    assert eng.prefill_bucket == 4
    assert eng.config.page_size == 8


def test_legacy_kwargs_form_removed():
    """The PR-6 one-release **kwargs shim is gone: any keyword option is a
    TypeError whose message names the EngineConfig replacement (not
    python's generic unexpected-keyword error), with or without a config
    positionally present."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    for ctor in (make_engine, Engine):
        with pytest.raises(TypeError, match="EngineConfig"):
            ctor(cfg, folded, batch_slots=2, max_len=64)
        with pytest.raises(TypeError, match="EngineConfig"):
            ctor(cfg, folded, btach_slots=2)      # typo: same clear error
        with pytest.raises(TypeError, match="EngineConfig"):
            ctor(cfg, folded, EngineConfig(), batch_slots=2)
    # the plain config form still constructs engines, no warning involved
    eng = make_engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="paged", page_size=8))
    assert isinstance(eng, Engine) and eng.page_size == 8


def test_engine_config_validation_errors():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    with pytest.raises(EngineConfigError, match="cache_layout"):
        Engine(cfg, folded, EngineConfig(cache_layout="pagd"))
    with pytest.raises(EngineConfigError, match="batch_slots"):
        EngineConfig(batch_slots=0).validate()
    with pytest.raises(EngineConfigError, match="trash page"):
        EngineConfig(cache_layout="paged", n_pages=1).validate()
    # model-dependent: lockstep archs don't take the continuous Engine
    lcfg, lfolded = _lockstep_cfg_folded()
    with pytest.raises(EngineConfigError, match="make_engine"):
        Engine(lcfg, lfolded, EngineConfig(batch_slots=2, max_len=32))


@pytest.mark.slow
def test_continuous_matches_lockstep_hybrid_arch():
    """Hybrid (attention+mamba) archs take the batch-1 decode-loop prefill
    path; outputs must still match the lockstep engine per request."""
    cfg = smoke_config("jamba-1.5-large-398b")
    folded = _folded(cfg)
    lens = [3, 7]
    max_news = [4, 4]

    lock = LockstepEngine(cfg, folded, EngineConfig(batch_slots=1, max_len=32))
    truth = []
    for r in _mixed_requests(cfg, lens, max_news):
        lock.reset()
        truth.append(lock.generate([r])[0].out.tolist())

    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=32))
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    assert eng.counters["oneshot_prefills"] == 0
    assert eng.counters["loop_prefill_steps"] == sum(lens)


# --- chunked prefill (token-budget step loop) ---------------------------------

@pytest.mark.parametrize("chunk_kw", [
    dict(max_prefill_chunk=4),                            # 1 page per chunk
    dict(max_prefill_chunk=8),                            # 2 pages per chunk
    dict(max_prefill_chunk=8, max_batched_tokens=10),     # + shared budget
])
def test_chunked_matches_oneshot_token_identity(chunk_kw):
    """Chunked prefill must be token-identical to one-shot prefill (and the
    lockstep engine) for every chunk size: 1-page chunks, multi-page
    chunks, ragged last chunks (prompt lengths here are deliberately not
    page multiples), and chunks co-scheduled under a token budget."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    lens = [3, 11, 6, 17, 29, 5]        # 17, 29: several chunks + ragged tail
    max_news = [4, 6, 5, 3, 4, 6]

    oneshot = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                               cache_layout="paged",
                                               page_size=4))
    truth = [r.out.tolist()
             for r in oneshot.generate(_mixed_requests(cfg, lens, max_news))]

    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           cache_layout="paged", page_size=4,
                                           **chunk_kw))
    out = eng.generate(_mixed_requests(cfg, lens, max_news))
    assert [r.out.tolist() for r in out] == truth
    # chunking really happened: more chunk forwards than requests, and the
    # long prompts took several chunks each
    assert eng.counters["prefill_chunks"] > len(lens)
    assert eng.counters["chunked_prefills"] >= 2
    assert eng.counters["prefill_tokens"] == sum(lens)
    assert eng.alloc.live == 0


def test_chunked_prefill_interleaves_with_decode():
    """While a long prompt is mid-prefill, decoding slots must keep
    emitting: submit a short request first (so it reaches decode), then a
    long one whose prefill spans several ticks under a tight budget, and
    check the short request emits tokens during those ticks."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           cache_layout="paged", page_size=4,
                                           max_prefill_chunk=4,
                                           max_batched_tokens=6))
    short = Request(prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=12)
    long = Request(prompt=np.arange(5, 38, dtype=np.int32), max_new_tokens=4)
    rid_short = eng.submit(short)
    eng.step()                          # short prefills (4 tok) and decodes
    rid_long = eng.submit(long)
    seen_interleaved = 0
    while eng.sched.has_work:
        long_slots = [b for b in eng.sched.prefilling
                      if eng.sched.slots[b].rid == rid_long]
        emitted = eng.step()
        if long_slots and any(r == rid_short for r, _ in emitted):
            seen_interleaved += 1
    # the long prompt (33 tokens / 4-token chunks, sharing a 6-token budget
    # with the short slot's decode) must have been mid-prefill across ticks
    # in which the short request still emitted tokens
    assert seen_interleaved >= 3
    assert eng.counters["chunked_prefills"] == 1
    assert short.out is not None and long.out is not None


def test_chunked_prefix_hit_lands_mid_chunk():
    """A prefix-registry hit discovered at first-chunk time (registration
    happens at prefill completion, after this request was admitted) must
    skip the shared rows even when they end mid-chunk — here shared_rows ==
    24 with 16-token chunks — and still produce identical tokens."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(0, cfg.vocab_size, (26,)).astype(np.int32)

    def requests():
        r = np.random.default_rng(9)
        return [Request(prompt=np.concatenate(
                    [sys_prompt,
                     r.integers(0, cfg.vocab_size, (4 + i,)).astype(np.int32)]),
                    max_new_tokens=4)
                for i in range(3)]

    # batch_slots=1 so each sharer is admitted after the previous request
    # completed (and registered) — the hit is then discovered by the
    # first-chunk refresh, not at admission
    oneshot = Engine(cfg, folded, EngineConfig(batch_slots=1, max_len=64,
                                               cache_layout="paged",
                                               page_size=8))
    truth = [r.out.tolist() for r in oneshot.generate(requests())]

    eng = Engine(cfg, folded, EngineConfig(batch_slots=1, max_len=64,
                                           cache_layout="paged", page_size=8,
                                           max_prefill_chunk=16))
    out = eng.generate(requests())
    assert [r.out.tolist() for r in out] == truth
    # requests 1, 2 hit the registered 3-page (24-row) prefix, which is not
    # a multiple of the 16-token chunk: their first chunk starts at row 24
    assert eng.counters["prefix_hits"] == 2
    assert eng.counters["shared_rows"] == 2 * 24
    assert eng.alloc.live == 0


def test_engine_stats_invariants_every_tick():
    """Engine.stats() gauges must satisfy the serving invariants at every
    tick: slot partitioning, page-pool partitioning, and pending-work
    consistency with the queue."""
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                           cache_layout="paged", page_size=4,
                                           max_prefill_chunk=4,
                                           max_batched_tokens=8))
    for r in _mixed_requests(cfg, [3, 21, 6, 17, 5], [4, 5, 4, 3, 5]):
        eng.submit(r)
    saw_prefilling = False
    while eng.sched.has_work:
        eng.step()
        g = eng.stats(check=True)   # gauges + allocator invariant sweep
        assert g["decode_slots_active"] + g["prefill_slots"] \
            + g["free_slots"] == eng.batch
        assert g["pages_in_use"] + g["pages_free"] + g["pages_cached_lru"] \
            == g["pages_capacity"]
        assert g["prefill_chunks_pending"] >= (g["prefill_slots"] > 0)
        assert (g["prefill_tokens_pending"] > 0) == (g["prefill_slots"] > 0)
        assert g["waiting"] >= 0
        assert g["counters"]["ticks"] == eng.counters["ticks"]
        saw_prefilling = saw_prefilling or g["prefill_slots"] > 0
    assert saw_prefilling                # budget really deferred prefill
    g = eng.stats()
    assert g["counters"]["completed"] == 5
    assert g["pages_in_use"] == 0 and g["prefill_tokens_pending"] == 0


def test_chunk_knobs_require_paged_layout():
    cfg = smoke_config("yi-6b")
    folded = _folded(cfg)
    with pytest.raises(EngineConfigError, match="paged"):
        Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                         cache_layout="contiguous",
                                         max_prefill_chunk=8))
    with pytest.raises(EngineConfigError, match="multiple"):
        Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64,
                                         cache_layout="paged", page_size=4,
                                         max_prefill_chunk=6))
