"""CI-gated static-analysis driver: ``python -m repro.analysis.analyze``.

Builds one folded smoke model, then for every EngineConfig preset on the
audit matrix (kv_bits 8/4 x tp 1/4 x spec_k 0/3, minus the combinations
``EngineConfig.validate`` rejects) boots a live paged engine, audits every
compiled hot graph (decode, prefill chunk, verify) with
``repro.analysis.jaxpr_audit``, runs the Pallas kernel lint, and emits one
versioned ANALYSIS.json (``repro.analysis.report`` schema).

Exit is non-zero on ANY violation, on a float-primitive ratchet failure
vs ``--baseline``, or — under ``--self-test`` — if any intentionally
broken fixture fails to raise its expected rule id.  Presets needing more
devices than the host has (tp=4 without
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) are recorded
under ``skipped``, never silently dropped.

    python -m repro.analysis.analyze --out ANALYSIS.json
    python -m repro.analysis.analyze --baseline benchmarks/baselines/ANALYSIS.json
    python -m repro.analysis.analyze --self-test
    python -m repro.analysis.analyze --hlo          # + bytes-by-dtype (slow)
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

# (kv_bits, tp, spec_k) — every combination EngineConfig accepts
PRESETS: Tuple[Tuple[int, int, int], ...] = (
    (8, 1, 0), (8, 1, 3), (8, 4, 0), (8, 4, 3), (4, 1, 0), (4, 4, 0),
)


def preset_name(kv_bits: int, tp: int, spec_k: int) -> str:
    return f"kv{kv_bits}_tp{tp}_spec{spec_k}"


def _build_folded():
    import jax
    from repro.configs import smoke_config
    from repro.models import fold as F
    from repro.models import transformer as T
    cfg = smoke_config("yi-6b")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def run_audits(*, with_hlo: bool = False,
               presets=PRESETS) -> Tuple[Dict, List[Dict]]:
    """(presets payload for ``report.build_report``, skipped list)."""
    import jax
    from repro.analysis import hlo_cost, jaxpr_audit
    from repro.serve.engine import Engine, EngineConfig

    cfg, folded = _build_folded()
    n_dev = jax.device_count()
    out: Dict = {}
    skipped: List[Dict] = []
    for kv_bits, tp, spec_k in presets:
        name = preset_name(kv_bits, tp, spec_k)
        if tp > n_dev:
            skipped.append({
                "preset": name,
                "reason": f"needs {tp} devices, host exposes {n_dev} (set "
                          "XLA_FLAGS=--xla_force_host_platform_device_count"
                          f"={tp})"})
            print(f"[analyze] {name}: SKIP ({skipped[-1]['reason']})")
            continue
        eng = Engine(cfg, folded, EngineConfig(
            batch_slots=4, max_len=64, cache_layout="paged", page_size=8,
            kv_bits=kv_bits, tp=tp, spec_k=spec_k))
        results = jaxpr_audit.audit_engine(eng)
        hbm: Dict[str, Dict] = {}
        if with_hlo:
            for gname, (fn, args) in eng.hot_graphs().items():
                text = jaxpr_audit.lowered_hlo(fn, args)
                hbm[gname] = hlo_cost.analyze(text)["hbm_bytes_by_dtype"]
        nv = sum(len(r.violations) for r in results.values())
        print(f"[analyze] {name}: {len(results)} graph(s), "
              f"{sum(r.n_eqns for r in results.values())} eqns, "
              f"{nv} violation(s)")
        out[name] = ({"kv_bits": kv_bits, "tp": tp, "spec_k": spec_k},
                     results, hbm)
    return out, skipped


def self_test() -> int:
    from repro.analysis import fixtures
    res = fixtures.run_self_test()
    for name, fr in res["fixtures"].items():
        want = fr["expected_rule"] or "(clean)"
        status = "ok" if fr["ok"] else "FAILED"
        print(f"[self-test] {name}: expected {want}, "
              f"flagged {fr['flagged_rules']} [{status}]")
    if not res["ok"]:
        print("[self-test] FAILED: a broken fixture was not flagged with "
              "its rule id (or a negative control was) — the analyzers "
              "cannot be trusted", file=sys.stderr)
        return 1
    print(f"[self-test] all {len(res['fixtures'])} fixtures behaved")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.analyze",
        description="integer-datapath jaxpr audit + pallas kernel lint")
    ap.add_argument("--out", type=Path, default=None,
                    help="write the versioned JSON report here")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="committed ANALYSIS.json to ratchet float "
                         "primitives against")
    ap.add_argument("--hlo", action="store_true",
                    help="also compile each hot graph and record HLO "
                         "bytes-by-dtype (slower)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the intentionally-broken fixtures instead of "
                         "auditing the tree")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()

    import jax
    from repro.analysis import pallas_lint, report

    presets, skipped = run_audits(with_hlo=args.hlo)
    pallas = pallas_lint.run_all()
    doc = report.build_report(presets=presets, skipped=skipped,
                              pallas=pallas, jax_version=jax.__version__)
    if args.out:
        args.out.write_text(json.dumps(doc, indent=1, sort_keys=True))
        print(f"[analyze] wrote {args.out}")

    rc = 0
    total = doc["violations_total"]
    if total:
        print(f"\nANALYSIS FAILED: {total} violation(s):", file=sys.stderr)
        for p in doc["presets"].values():
            for g in p["graphs"].values():
                for v in g["violations"]:
                    print(f"  - [{v['rule']}] {v['graph']}{v['scope']}: "
                          f"{v['detail']}", file=sys.stderr)
        for v in doc["pallas_lint"]["violations"]:
            print(f"  - [{v['rule']}] {v['graph']}: {v['detail']}",
                  file=sys.stderr)
        rc = 1
    else:
        n_graphs = sum(len(p["graphs"]) for p in doc["presets"].values())
        print(f"[analyze] zero violations across {len(doc['presets'])} "
              f"preset(s) / {n_graphs} graph(s) + pallas lint"
              + (f" ({len(skipped)} preset(s) skipped)" if skipped else ""))

    if args.baseline:
        if not args.baseline.exists():
            print(f"[analyze] baseline {args.baseline} missing — commit one "
                  "(run with --out and check it in)", file=sys.stderr)
            rc = rc or 1
        else:
            base = json.loads(args.baseline.read_text())
            failures = report.compare_to_baseline(doc, base)
            for f in failures:
                print(f"[baseline] {f}", file=sys.stderr)
            if failures:
                rc = rc or 1
            else:
                print(f"[analyze] float-primitive ratchet vs "
                      f"{args.baseline} holds")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
