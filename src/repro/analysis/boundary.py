"""Registered kernel boundaries for the jaxpr auditor.

The integer-datapath contract says a whole-pool dequant to float may only
happen *inside a kernel*: on TPU the Pallas kernels dequantize int4 tiles
in VMEM, and on the ref/CPU backend the bit-exact oracles (and serve_int's
gathered-view fallback) do the equivalent in plain jnp.  The auditor can't
see Pallas kernel bodies in the jaxpr (they are opaque calls), but the jnp
equivalents are inline — so they must be *named scopes* the auditor can
recognize and exempt from the pool-scale-cast rule (while still auditing
everything around them).

``kernel_boundary`` wraps a function in a non-inlined ``jax.jit`` so it
shows up as a ``pjit`` eqn carrying the function's name, and records that
name here.  ``repro.analysis.jaxpr_audit`` treats eqn scopes whose name is
registered as kernel interiors.

This module must stay import-light (no jax import at module scope beyond
the lazy wrap) so kernel modules can import it without cycles; the rest of
``repro.analysis`` imports *from* kernels, never the other way around.
"""
from __future__ import annotations

from typing import Callable

# scope name -> short human description of why the interior is exempt
REGISTRY: dict[str, str] = {}


def register(name: str, why: str) -> None:
    REGISTRY[name] = why


def kernel_boundary(*, why: str, static_argnums=()) -> Callable:
    """Decorator: mark ``fn`` as a kernel-equivalent scope.

    Wraps ``fn`` in ``jax.jit(..., inline=False)`` so that when traced
    inside an outer jit it appears as a named ``pjit`` eqn, and registers
    the name for the auditor.  Numerics are unchanged; under an outer jit
    the XLA inliner still fuses the body after lowering.
    """
    def deco(fn):
        import jax
        register(fn.__name__, why)
        return jax.jit(fn, static_argnums=static_argnums, inline=False)
    return deco
