"""int4-packed KV pool: packing round-trips + fused-dequant kernel oracles.

The packed-pool contract has three parties that must agree bit-for-bit:
``core/packing.py`` (quantize/dequantize formulas), the Pallas kernels'
in-VMEM ``dequant_kv_tile``, and the ``kernels/ref.py`` q4 oracles (whole
pool dequant + int8 block-online oracle).  These tests pin all three to
each other on CPU interpret mode, over multi-page chains and ragged
lengths — the same harness shapes as the int8 paged kernel tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixedpoint as fxp
from repro.core import packing
from repro.core import qsoftmax as qs
from repro.kernels import ops
from repro.kernels import ref as R


# --- packing round-trips -------------------------------------------------------

@pytest.mark.parametrize("shape,axis", [
    ((6,), 0),
    ((3, 8), 1),          # odd leading dim
    ((5, 7, 4), 2),       # odd dims everywhere but the packed axis
    ((2, 3, 5, 16), -1),  # pool-like rank
])
def test_planar_pack_round_trip(shape, axis):
    rng = np.random.default_rng(0)
    codes = rng.integers(-8, 8, shape).astype(np.int8)
    packed = packing.pack_int4_planar(jnp.asarray(codes), axis=axis)
    assert packed.shape[axis % len(shape)] == shape[axis % len(shape)] // 2
    assert packed.dtype == jnp.uint8
    back = packing.unpack_int4_planar(packed, axis=axis)
    np.testing.assert_array_equal(np.asarray(back), codes)


@pytest.mark.parametrize("shape,axis", [((4,), 0), ((3, 6), 1), ((8, 3), 0)])
def test_pair_pack_round_trip(shape, axis):
    rng = np.random.default_rng(1)
    codes = rng.integers(-8, 8, shape).astype(np.int8)
    back = packing.unpack_int4(packing.pack_int4(jnp.asarray(codes),
                                                 axis=axis), axis=axis)
    np.testing.assert_array_equal(np.asarray(back), codes)


def test_pack_rejects_odd_axis():
    with pytest.raises(AssertionError):
        packing.pack_int4_planar(jnp.zeros((3, 5), jnp.int8), axis=1)
    with pytest.raises(AssertionError):
        packing.pack_int4(jnp.zeros((7,), jnp.int8), axis=0)


def test_packed_nbytes_odd_shapes():
    assert packing.packed_nbytes((5, 7, 16), axis=-1) == 5 * 7 * 8
    assert packing.packed_nbytes((6, 3), axis=0) == 3 * 3


def test_kv_page_quant_round_trip_properties():
    """Page quantization: extremes round-trip exactly, everything else is
    within half a step, and the all-zero (trash) page stays all-zero."""
    rng = np.random.default_rng(2)
    page = rng.integers(-127, 128, (16, 2, 32)).astype(np.int8)
    page.flat[0] = 127                     # force a known amax
    s = packing.kv_page_scale(jnp.asarray(page))
    assert float(s) == pytest.approx(127.0 / 7.0)
    packed = packing.quantize_kv_page(jnp.asarray(page), s, axis=-1)
    assert packed.shape == (16, 2, 16) and packed.dtype == jnp.uint8
    deq = np.asarray(packing.dequantize_kv_page(packed, s, axis=-1),
                     np.int32)
    assert deq.flat[0] == 127              # amax element exact
    assert np.max(np.abs(deq - page.astype(np.int32))) <= \
        int(np.ceil(float(s) / 2)) + 1
    # trash page: scale well-defined, codes stay zero
    z = jnp.zeros((16, 2, 32), jnp.int8)
    sz = packing.kv_page_scale(z)
    assert float(sz) == pytest.approx(1.0 / 7.0)
    np.testing.assert_array_equal(
        np.asarray(packing.dequantize_kv_page(
            packing.quantize_kv_page(z, sz), sz)), np.zeros((16, 2, 32)))


def test_small_codes_round_trip_exactly():
    """|codes| <= 7 quantize losslessly (scale <= 1 covers the range)."""
    rng = np.random.default_rng(3)
    page = rng.integers(-7, 8, (8, 1, 16)).astype(np.int8)
    page.flat[0] = 7
    s = packing.kv_page_scale(jnp.asarray(page))    # == 1.0
    deq = packing.dequantize_kv_page(
        packing.quantize_kv_page(jnp.asarray(page), s), s)
    np.testing.assert_array_equal(np.asarray(deq), page)


# --- q4 kernels vs oracles -----------------------------------------------------

def _pack_pool(pool_i8):
    """(n_pages, P, Hkv, D) int8 -> packed uint8 pool + (n_pages,) scales,
    the exact per-page shared-scale quantization the write path performs."""
    pool = jnp.asarray(pool_i8)
    scales = jax.vmap(packing.kv_page_scale)(pool)
    packed = jax.vmap(
        lambda p, s: packing.quantize_kv_page(p, s, axis=-1))(pool, scales)
    return packed, scales


def _paged_inputs(b, hkv, g, d, psize, n_pages, nb, lengths, seed=31):
    rng = np.random.default_rng(seed)
    q = rng.integers(-64, 65, (b, hkv, g, d)).astype(np.int8)
    kp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    vp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    perm = iter(rng.permutation(np.arange(1, n_pages)))
    btab = np.zeros((b, nb), np.int32)
    for bb, ln in enumerate(lengths):
        for i in range(-(-int(ln) // psize)):
            btab[bb, i] = next(perm)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    return q, kp, vp, btab, M, sh, s_logit


@pytest.mark.parametrize("psize,lengths", [
    (64, [1, 37, 64]),          # one page covers every slot
    (16, [1, 23, 48]),          # cross-page fp32 carry
    (8, [5, 17, 40]),           # many ragged pages
])
def test_paged_decode_q4_bit_exact_vs_oracle(psize, lengths):
    """Fused-dequant paged decode kernel vs the q4 oracle (whole-pool
    dequant + int8 block-online oracle): BIT-EXACT for any page count."""
    from repro.kernels.decode_attention import paged_decode_qattention_q4

    b, hkv, g, d = 3, 2, 4, 64
    nb = 64 // psize
    n_pages = b * nb + 1
    q, kp, vp, btab, M, sh, s_logit = _paged_inputs(
        b, hkv, g, d, psize, n_pages, nb, lengths)
    kpk, ks = _pack_pool(kp)
    vpk, vs = _pack_pool(vp)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), kpk, vpk, ks, vs, jnp.asarray(btab),
            jnp.asarray(lengths, jnp.int32), jnp.int32(M), jnp.int32(sh),
            lut7, jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    got = np.asarray(paged_decode_qattention_q4(*args, interpret=True),
                     np.int32)
    want = np.asarray(R.paged_decode_qattention_q4_ref(*args), np.int32)
    np.testing.assert_array_equal(got, want)
    # quality sanity: int4 KV stays in the ballpark of the int8-pool answer
    # (random uncorrelated KV is worst-case for a shared page scale, so the
    # bound is loose — real divergence is a reported metric, not an assert)
    i8 = np.asarray(R.paged_decode_qattention_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(btab),
        jnp.asarray(lengths, jnp.int32), jnp.int32(M), jnp.int32(sh), lut7,
        jnp.float32(1.0 / s_logit), jnp.float32(1.0)), np.int32)
    assert np.mean(np.abs(got - i8)) < 24.0


@pytest.mark.parametrize("psize,sq,pos0,bq", [
    (16, 16, [0, 16], 16),        # single q block, chunk continuation
    (8, 16, [8, 32], 8),          # multi q block, mid-chain chunks
    (8, 24, [0, 16], 4),          # bq < page, ragged grid mix
    (16, 32, [16, 48], 32),       # chunk spanning several pages
])
def test_paged_prefill_q4_bit_exact_vs_oracle(psize, sq, pos0, bq):
    """Fused-dequant paged prefill kernel vs the q4 oracle: BIT-EXACT for
    any chunk position and q-block size (causal-frontier clamping makes the
    output bq-independent, so autotune can never move bits)."""
    from repro.kernels.prefill_attention import paged_prefill_qattention_q4

    b, h, hkv, d = 2, 4, 2, 64
    pos0 = np.asarray(pos0, np.int32)
    nb = -(-(int(pos0.max()) + sq) // psize) + 1     # + one dead tail block
    rng = np.random.default_rng(37)
    q = rng.integers(-64, 65, (b, h, sq, d)).astype(np.int8)
    n_pages = b * nb + 1
    kp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    vp = rng.integers(-64, 65, (n_pages, psize, hkv, d)).astype(np.int8)
    perm = iter(rng.permutation(np.arange(1, n_pages)))
    btab = np.zeros((b, nb), np.int32)
    for bb in range(b):
        for i in range(-(-(int(pos0[bb]) + sq) // psize)):
            btab[bb, i] = next(perm)
    s_logit = 1.0 / (0.05 * np.sqrt(d))
    M, sh = fxp.quantize_multiplier(1.0 / (s_logit * qs.LUT_DELTA))
    kpk, ks = _pack_pool(kp)
    vpk, vs = _pack_pool(vp)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), kpk, vpk, ks, vs, jnp.asarray(btab),
            jnp.asarray(pos0), jnp.int32(M), jnp.int32(sh), lut7,
            jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    got = np.asarray(paged_prefill_qattention_q4(*args, bq=bq,
                                                 interpret=True), np.int32)
    want = np.asarray(R.paged_prefill_qattention_q4_ref(*args), np.int32)
    np.testing.assert_array_equal(got, want)


def test_q4_ops_dispatch_decode_and_prefill():
    """ops.paged_{decode,prefill}_attention_q4: ref and interpret backends
    agree bit-for-bit (same dispatch contract as the int8 wrappers)."""
    b, hkv, g, d, psize, nb = 2, 1, 2, 32, 8, 4
    q, kp, vp, btab, M, sh, s_logit = _paged_inputs(
        b, hkv, g, d, psize, b * nb + 1, nb, [9, 32], seed=5)
    kpk, ks = _pack_pool(kp)
    vpk, vs = _pack_pool(vp)
    lut7 = jnp.asarray(R.make_exp_lut_q7())
    args = (jnp.asarray(q), kpk, vpk, ks, vs, jnp.asarray(btab),
            jnp.asarray([9, 32], jnp.int32), jnp.int32(M), jnp.int32(sh),
            lut7, jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    a = ops.paged_decode_attention_q4(*args, impl="ref")
    c = ops.paged_decode_attention_q4(*args, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    sq = 16
    pos0 = np.asarray([0, 8], np.int32)
    rng = np.random.default_rng(7)
    qp = rng.integers(-64, 65, (b, 2, sq, d)).astype(np.int8)
    btab2 = np.zeros((b, nb), np.int32)
    perm = iter(range(1, b * nb + 1))
    for bb in range(b):
        for i in range(-(-(int(pos0[bb]) + sq) // psize)):
            btab2[bb, i] = next(perm)
    pargs = (jnp.asarray(qp), kpk, vpk, ks, vs, jnp.asarray(btab2),
             jnp.asarray(pos0), jnp.int32(M), jnp.int32(sh), lut7,
             jnp.float32(1.0 / s_logit), jnp.float32(1.0))
    a = ops.paged_prefill_attention_q4(*pargs, impl="ref")
    c = ops.paged_prefill_attention_q4(*pargs, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
