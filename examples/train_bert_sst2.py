"""End-to-end driver (paper's own experiment): QAT fine-tune BERT on an
SST-2-style binary classification task, then fold and measure the
fp32-vs-FQ accuracy gap (paper Table I) — synthetic data stands in for
GLUE offline.

    PYTHONPATH=src python examples/train_bert_sst2.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import bert as B
from repro.optim.adamw import AdamWConfig, init_state
from repro.train import steps as St

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=150)
ap.add_argument("--d-model", type=int, default=128)
args = ap.parse_args()

cfg = smoke_config("bert-base", d_model=args.d_model, n_layers=2)
key = jax.random.PRNGKey(0)

# synthetic sentiment task: label = whether "positive" tokens outnumber
# "negative" tokens (tokens < 16 are positive, 16..31 negative)
def make_batch(step, b=16, s=32):
    rng = np.random.default_rng(step)
    toks = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    n_sent = rng.integers(4, 12, (b,))
    for i in range(b):
        sent = rng.integers(0, 32, (n_sent[i],))
        toks[i, 1:1 + n_sent[i]] = sent
    labels = ((toks < 16).sum(1) > ((toks >= 16) & (toks < 32)).sum(1))
    return {"tokens": jnp.asarray(toks),
            "mask": jnp.ones((b, s), bool),
            "labels": jnp.asarray(labels.astype(np.int32))}

opt = AdamWConfig(lr=1e-3)
params = B.init_bert_params(cfg, key)
state = St.TrainState(params, init_state(params, opt), B.init_bert_amax(cfg),
                      jnp.zeros((), jnp.int32))
step_fn = jax.jit(St.make_bert_train_step(cfg, opt))
for step in range(args.steps):
    state, m = step_fn(state, make_batch(step))
    if step % 25 == 0 or step == args.steps - 1:
        print(f"step {step:4d} loss {float(m['loss']):.4f} "
              f"acc {float(m['acc']):.3f}")

# eval: QAT (fake-quant) vs fp32-policy on held-out batches
import dataclasses
from repro.core.policy import POLICY_FP32
accs = {"fq": [], "fp32": []}
cfg_fp = dataclasses.replace(cfg, quant=POLICY_FP32)
for step in range(1000, 1010):
    b = make_batch(step)
    for name, c in (("fq", cfg), ("fp32", cfg_fp)):
        lg, _, _ = B.bert_classify(c, state.params, state.amax, b["tokens"],
                                   b["mask"])
        accs[name].append(float((lg.argmax(-1) == b["labels"]).mean()))
print(f"held-out acc  FQ(QAT)={np.mean(accs['fq']):.3f}  "
      f"fp32-exec={np.mean(accs['fp32']):.3f}  "
      f"drop={np.mean(accs['fp32']) - np.mean(accs['fq']):.3f} "
      f"(paper: 0.8% on SST-2)")
