"""Slot-table scheduler + paged KV-cache block allocator.

The decode graph is compiled once for a fixed number of slots; this module
owns the bookkeeping that lets requests stream through that fixed shape:
a FIFO waiting queue, a slot table, admission of waiting requests into free
slots, and eviction on completion.  It is deliberately model-agnostic — the
engine owns prefill/decode; the scheduler only decides *who sits where*.

``BlockAllocator`` extends "where" from slots to cache memory: instead of an
exclusive ``Smax`` stripe per slot, the paged engine draws fixed-size KV
pages from one global pool.  The allocator keeps a free list, per-page
refcounts, and a prefix registry keyed by the page's *cumulative* token
prefix (K/V rows depend on every earlier token, so content identity is the
whole prefix, not the page's own tokens).  Pages whose refcount drops to
zero but that are still registered stay cached (their pool content is
intact) on an LRU list and are reclaimed only under allocation pressure —
so a repeated system prompt keeps hitting even after its first request
finished.  Shared pages are mapped copy-on-write: sharers only ever read
them; a writer must own the page exclusively (``ensure_exclusive``), which
the engine guarantees structurally by sharing only whole pages strictly
before the first position it will write.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

TRASH_PAGE = 0   # inactive slots' block tables point here; never allocated


def pages_needed(rows: int, page_size: int) -> int:
    return -(-rows // page_size)


class BlockAllocator:
    """Fixed-size KV page pool: free list, refcounts, prefix reuse.

    Page 0 is reserved as the trash page — zeroed block-table entries of
    inactive slots alias it, so a full-table decode step can harmlessly
    scatter its garbage rows somewhere that no live request reads.
    """

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages >= 2 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.free: Deque[int] = collections.deque(range(1, n_pages))
        self.ref: List[int] = [0] * n_pages
        # chained-prefix registry: key -> (page, that page's own tokens)
        self._cached: Dict[int, Tuple[int, tuple]] = {}
        self._key_of: Dict[int, int] = {}     # page -> its registry key
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()         # refcount-0 cached pages
        self.live = 0                         # pages with refcount > 0
        self.peak_live = 0

    # --- capacity -------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the trash page)."""
        return self.n_pages - 1

    def available(self) -> int:
        return len(self.free) + len(self._lru)

    def can_alloc(self, n: int) -> bool:
        return n <= self.available()

    # --- allocation -----------------------------------------------------

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` exclusive pages (refcount 1), reclaiming LRU cached
        pages if the free list runs short.  None if the pool can't cover
        the request — the caller waits, it never partially allocates."""
        if not self.can_alloc(n):
            return None
        pages = []
        for _ in range(n):
            if self.free:
                p = self.free.popleft()
            else:
                p, _ = self._lru.popitem(last=False)     # oldest cached page
                del self._cached[self._key_of.pop(p)]
            self.ref[p] = 1
            pages.append(p)
        self._bump_live(n)
        return pages

    def free_pages(self, pages: Sequence[int]):
        """Drop one reference per page; refcount-0 pages return to the free
        list, unless registered — those stay cached for prefix reuse."""
        for p in pages:
            assert self.ref[p] > 0, f"double free of page {p}"
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self.live -= 1
                if p in self._key_of:
                    self._lru[p] = None
                else:
                    self.free.append(p)

    def _bump_live(self, n: int):
        self.live += n
        self.peak_live = max(self.peak_live, self.live)

    # --- prefix sharing -------------------------------------------------

    def _walk_keys(self, tokens: Sequence[int], n: int):
        """Chained per-page registry keys: ``key_i = hash((key_{i-1},
        page_i tokens))``.  K/V rows depend on every earlier token, so a
        page's identity is its *cumulative* prefix — the chained hash gives
        that in O(page_size) per page instead of re-hashing the whole
        prefix (O(L^2) over a prompt).  Lookups verify the page's own
        tokens against the stored segment, and the parent key is verified
        inductively by the walk, so a false hit needs a 64-bit hash
        collision AND an identical current segment."""
        ps = self.page_size
        key = 0
        for i in range(n):
            seg = tuple(tokens[i * ps:(i + 1) * ps])
            key = hash((key, seg))
            yield key, seg

    def match_prefix(self, tokens: Sequence[int], max_pages: int) -> List[int]:
        """Longest chain of registered pages covering full-page prefixes of
        ``tokens`` (at most ``max_pages``).  Matched pages get a reference;
        release with ``free_pages`` if the reservation is abandoned."""
        pages = []
        for key, seg in self._walk_keys(tokens, max_pages):
            hit = self._cached.get(key)
            if hit is None or hit[1] != seg:
                break
            pages.append(hit[0])
        for p in pages:
            if self.ref[p] == 0:           # revive a cached (LRU) page
                self._lru.pop(p, None)
                self._bump_live(1)
            self.ref[p] += 1
        return pages

    def register_prefix(self, tokens: Sequence[int], pages: Sequence[int]):
        """Publish a prompt's full pages for reuse.  Only pages strictly
        before the last prompt token are registered — at least one token
        must run through the model so admission has next-token logits, and
        the page the first write lands in must stay exclusive (COW
        discipline without ever copying)."""
        n = min((len(tokens) - 1) // self.page_size, len(pages))
        for (key, seg), p in zip(self._walk_keys(tokens, n), pages):
            if key in self._cached or p in self._key_of:
                continue       # identical content already published
            self._cached[key] = (p, seg)
            self._key_of[p] = key

    def ensure_exclusive(self, pages: List[int], idx: int
                         ) -> Tuple[int, Optional[int]]:
        """Copy-on-write: make ``pages[idx]`` safe to overwrite.  Returns
        ``(page, copy_src)`` — ``copy_src`` is the old page whose rows must
        be copied into the fresh page when the original was shared (or
        registered, i.e. passively shareable), else None.  The paged engine
        only ever writes pages it allocated exclusively, so in practice
        this is a no-op assert; the hook exists so future preemption/swap
        code inherits correct semantics."""
        p = pages[idx]
        if self.ref[p] == 1 and p not in self._key_of:
            return p, None
        fresh = self.alloc(1)
        if fresh is None:
            raise RuntimeError("pool exhausted during copy-on-write")
        self.free_pages([p])
        pages[idx] = fresh[0]
        return fresh[0], p

    @property
    def cached_pages(self) -> int:
        return len(self._cached)


@dataclasses.dataclass
class SlotState:
    """One occupied slot of the decode batch."""
    rid: int
    request: object                 # the engine's Request
    pos: int = 0                    # next cache write position for this slot
    last_token: int = 0             # token to feed at the next decode step
    emitted: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    shared_rows: int = 0            # prompt rows mapped from cached pages


class Scheduler:
    def __init__(self, n_slots: int,
                 allocator: Optional[BlockAllocator] = None,
                 rows_fn: Optional[Callable[[object, int], int]] = None):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.allocator = allocator
        # rows_fn(request, shared_rows) -> cache rows to reserve (the engine
        # knows about prefill bucketing; the scheduler stays model-agnostic)
        self.rows_fn = rows_fn or (
            lambda req, shared: len(req.prompt) + req.max_new_tokens - 1)
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self.waiting: Deque[Tuple[int, object]] = collections.deque()
        self._next_rid = 0

    # --- queue side -----------------------------------------------------

    def submit(self, request) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append((rid, request))
        return rid

    # --- slot side ------------------------------------------------------

    def _reserve(self, st: SlotState, request) -> bool:
        """Map shared prefix pages and allocate the exclusive tail.  False
        when the pool can't cover the request — admission stalls (FIFO is
        preserved: later, smaller requests do NOT jump the queue)."""
        al = self.allocator
        ps = al.page_size
        prompt = [int(t) for t in request.prompt]
        shared = al.match_prefix(prompt, (len(prompt) - 1) // ps)
        shared_rows = len(shared) * ps
        rows = self.rows_fn(request, shared_rows)
        need = max(0, pages_needed(rows, ps) - len(shared))
        excl = al.alloc(need)
        if excl is None:
            al.free_pages(shared)          # abandon the speculative mapping
            return False
        st.pages = shared + excl
        st.shared_rows = shared_rows
        return True

    def admit(self, limit: Optional[int] = None
              ) -> List[Tuple[int, SlotState]]:
        """Seat waiting requests in free slots (FIFO).  Returns the new
        (slot index, state) pairs; the engine prefills them and fills in
        ``pos`` / ``last_token``.  With a BlockAllocator, admission also
        reserves the request's KV pages (shared prefix + exclusive tail)
        up front — a head-of-line request that doesn't fit stalls the queue
        instead of OOMing mid-decode."""
        placed = []
        for b in range(self.n_slots):
            if limit is not None and len(placed) >= limit:
                break
            if self.slots[b] is not None or not self.waiting:
                continue
            rid, request = self.waiting[0]
            st = SlotState(rid=rid, request=request)
            if self.allocator is not None and not self._reserve(st, request):
                break                       # out of pages: wait, keep FIFO
            self.waiting.popleft()
            self.slots[b] = st
            placed.append((b, st))
        return placed

    def evict(self, b: int) -> SlotState:
        st = self.slots[b]
        assert st is not None, f"evicting empty slot {b}"
        self.slots[b] = None
        if self.allocator is not None and st.pages:
            self.allocator.free_pages(st.pages)
        return st

    # --- queries --------------------------------------------------------

    @property
    def active(self) -> List[int]:
        return [b for b, st in enumerate(self.slots) if st is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(st is not None for st in self.slots)

    @property
    def n_free(self) -> int:
        return sum(st is None for st in self.slots)
