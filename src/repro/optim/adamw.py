"""AdamW with optional int8-quantized moments (beyond-paper, on-theme:
quantized optimizer state is what lets 405B training state fit 256 v5e chips
— see DESIGN.md §3).

Functional, pytree-based, no optax dependency.  Moment quantization uses the
paper's own symmetric-linear scheme per tensor (block-wise scale on the
leading axis for stacked layer params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    quantize_moments: bool = False   # int8 m/v (paper-style symmetric)


class QMoment(NamedTuple):
    codes: jax.Array   # int8
    scale: jax.Array   # per-last-axis-vector fp32 scales


def _q(x: jax.Array) -> QMoment:
    """Per-last-axis-vector symmetric int8 quantization (first moment)."""
    if x.ndim == 0:
        s = 127.0 / jnp.maximum(jnp.abs(x), 1e-12)
        return QMoment(jnp.round(x * s).astype(jnp.int8), s)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = 127.0 / jnp.maximum(amax, 1e-12)
    return QMoment(jnp.clip(jnp.round(x * s), -127, 127).astype(jnp.int8), s)


def _dq(m: QMoment) -> jax.Array:
    return m.codes.astype(jnp.float32) / m.scale


def _q_v(x: jax.Array) -> QMoment:
    """Second moment in sqrt-domain: int8 codes of sqrt(v), which halves the
    dynamic range the 8 bits must cover (the update uses sqrt(v) anyway)."""
    r = jnp.sqrt(jnp.maximum(x, 0.0))
    if x.ndim == 0:
        s = 127.0 / jnp.maximum(r, 1e-12)
        return QMoment(jnp.round(r * s).astype(jnp.int8), s)
    amax = jnp.max(r, axis=-1, keepdims=True)
    s = 127.0 / jnp.maximum(amax, 1e-12)
    return QMoment(jnp.clip(jnp.round(r * s), 0, 127).astype(jnp.int8), s)


def _dq_v(m: QMoment) -> jax.Array:
    r = m.codes.astype(jnp.float32) / m.scale
    return r * r


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    def zero_like(p, q):
        z = jnp.zeros(p.shape, jnp.float32)
        return q(z) if cfg.quantize_moments else z

    return {
        "m": jax.tree.map(lambda p: zero_like(p, _q), params),
        "v": jax.tree.map(lambda p: zero_like(p, _q_v), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jax.Array | float = 1.0) -> Tuple[Any, Dict]:
    step = state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dq(m) if cfg.quantize_moments else m
        v_f = _dq_v(v) if cfg.quantize_moments else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd_ = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        if cfg.quantize_moments:
            return p_new, _q(m_f), _q_v(v_f)
        return p_new, m_f, v_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    if cfg.quantize_moments:
        flat_m = jax.tree.flatten(state["m"], is_leaf=lambda x: isinstance(x, QMoment))[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=lambda x: isinstance(x, QMoment))[0]
    else:
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step,
                   "grad_norm": gn}
