"""Speculative decoding (draft-then-verify on the paged verify forward):
greedy spec outputs must be bit-identical to plain decode on every
workload shape — that IS the acceptance rule (accept while draft ==
argmax), so these tests drive the identity matrix with draft sources
pinned at both extremes (oracle: 100% acceptance, anti-oracle: 0%) plus
the shipping prompt-lookup proposer, and assert the counters tell the
true story (``drafted == accepted + rejected``, histogram mass equals
proposal ticks)."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve.draft import (DraftSource, PromptLookupDraft,
                               SequenceDraft, make_draft_source)
from repro.serve.engine import (Engine, EngineConfig, EngineConfigError,
                                Request)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def folded_cfg():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def _cycle_requests(cfg, lens, max_news, seed=7, period=3):
    """Prompt-lookup-friendly prompts: each is a tiled short cycle, so the
    suffix n-gram always reoccurs earlier in the context."""
    rng = np.random.default_rng(seed)
    reqs = []
    for ln, mn in zip(lens, max_news):
        pat = rng.integers(0, cfg.vocab_size, (period,)).astype(np.int32)
        reqs.append(Request(prompt=np.tile(pat, ln // period + 1)[:ln],
                            max_new_tokens=mn))
    return reqs


def _outs(eng, reqs):
    return [r.out.tolist() for r in eng.generate(reqs)]


def _truth(cfg, folded, mkreqs, **kw):
    """Plain-decode reference outputs + the truth sequences (prompt +
    continuation) the oracle/anti-oracle drafts are built from."""
    eng = Engine(cfg, folded, EngineConfig(**kw))
    reqs = mkreqs()
    outs = _outs(eng, reqs)
    seqs = [list(np.asarray(r.prompt).ravel()) + o
            for r, o in zip(reqs, outs)]
    return outs, seqs


class AntiDraft(SequenceDraft):
    """Anti-oracle: proposes (truth_token + 1) % vocab, guaranteeing every
    proposal diverges from the argmax chain — forces full-rejection ticks
    deterministically (no reliance on what a random model happens to
    emit)."""

    def __init__(self, vocab, sequences=()):
        super().__init__(sequences)
        self.vocab = vocab

    def propose(self, context, k):
        p = super().propose(context, k)
        return [(t + 1) % self.vocab for t in p]


BASE = dict(batch_slots=2, max_len=64, prefill_bucket=4,
            cache_layout="paged", page_size=4)
LENS = [3, 11, 6, 17, 5]
MAX_NEWS = [6, 8, 5, 4, 7]


def test_spec_k0_is_plain_decode(folded_cfg):
    """spec_k=0 must not even build a draft source — it IS plain decode
    (same engine object graph, no verify dispatch)."""
    cfg, folded = folded_cfg
    eng = Engine(cfg, folded, EngineConfig(**BASE))
    assert eng.spec_k == 0 and eng.draft is None
    assert eng.stats(check=True)["spec_k"] == 0


def test_spec_no_proposals_falls_back_to_plain(folded_cfg):
    """A draft source that never proposes leaves every tick on the plain
    decode graph: outputs identical, zero spec counters."""
    cfg, folded = folded_cfg

    class Mute(DraftSource):
        def propose(self, context, k):
            return []

    mk = lambda: _cycle_requests(cfg, LENS, MAX_NEWS)
    truth, _ = _truth(cfg, folded, mk, **BASE)
    eng = Engine(cfg, folded, EngineConfig(spec_k=3, draft=Mute(), **BASE))
    assert _outs(eng, mk()) == truth
    assert eng.counters["drafted"] == 0
    assert eng.counters["accept_len_hist"] == {}


def test_spec_prompt_lookup_identical(folded_cfg):
    """The shipping prompt-lookup proposer on a lookup-friendly workload:
    bit-identical outputs, real acceptances, counters consistent."""
    cfg, folded = folded_cfg
    mk = lambda: _cycle_requests(cfg, LENS, MAX_NEWS)
    truth, _ = _truth(cfg, folded, mk, **BASE)
    eng = Engine(cfg, folded, EngineConfig(spec_k=3, **BASE))
    assert _outs(eng, mk()) == truth
    c = eng.counters
    assert c["drafted"] == c["accepted"] + c["rejected"]
    assert c["drafted"] > 0
    assert sum(c["accept_len_hist"].values()) > 0
    assert all(0 <= k <= 3 for k in c["accept_len_hist"])
    assert eng.stats(check=True)["spec_k"] == 3


def test_spec_full_rejection_ticks_identical(folded_cfg):
    """Anti-oracle draft: every proposal diverges, every tick rolls the
    whole tail back — outputs still bit-identical, accepted == 0, and the
    histogram is all mass at length 0."""
    cfg, folded = folded_cfg
    mk = lambda: _cycle_requests(cfg, LENS, MAX_NEWS)
    truth, seqs = _truth(cfg, folded, mk, **BASE)
    eng = Engine(cfg, folded, EngineConfig(
        spec_k=3, draft=AntiDraft(cfg.vocab_size, seqs), **BASE))
    assert _outs(eng, mk()) == truth
    c = eng.counters
    assert c["drafted"] > 0 and c["accepted"] == 0
    assert c["rejected"] == c["drafted"]
    assert set(c["accept_len_hist"]) == {0}


def test_spec_oracle_accepts_across_page_boundary(folded_cfg):
    """Oracle draft (100% acceptance): with page_size=4 and spec_k=3 a
    fully-accepted tick commits 4 rows — every verify crosses a page
    boundary, exercising grow-mid-verify on the on-demand policy.  Outputs
    bit-identical, zero rejections, decode forwards cut by ~spec_k+1."""
    cfg, folded = folded_cfg
    mk = lambda: _cycle_requests(cfg, LENS, MAX_NEWS)
    truth, seqs = _truth(cfg, folded, mk, **BASE)
    plain_steps = Engine(cfg, folded, EngineConfig(**BASE))
    _outs(plain_steps, mk())
    eng = Engine(cfg, folded, EngineConfig(
        spec_k=3, draft=SequenceDraft(seqs), **BASE))
    assert _outs(eng, mk()) == truth
    c = eng.counters
    assert c["rejected"] == 0 and c["accepted"] == c["drafted"] > 0
    assert c["grown_pages"] > 0          # chains extended mid-verify
    assert c["decode_steps"] < plain_steps.counters["decode_steps"]


def test_spec_preemption_mid_verify_identical(folded_cfg):
    """Tight pool + oracle draft growing several rows per tick: growth
    preempts victims between proposal and verify; restored slots replay
    and stay token-identical."""
    cfg, folded = folded_cfg
    kw = dict(BASE, n_pages=8)
    mk = lambda: _cycle_requests(cfg, LENS, MAX_NEWS)
    truth, seqs = _truth(cfg, folded, mk, **kw)
    eng = Engine(cfg, folded, EngineConfig(
        spec_k=3, draft=SequenceDraft(seqs), **kw))
    assert _outs(eng, mk()) == truth
    assert eng.counters["preemptions"] > 0
    assert eng.counters["restores"] > 0
    assert eng.alloc.live == 0           # allocator invariants intact


def test_spec_sampling_slots_ride_along(folded_cfg):
    """temperature > 0 slots are never drafted for (greedy acceptance
    only) but share verify batches with greedy slots; the greedy outputs
    stay bit-identical and the sampler emits its full budget."""
    cfg, folded = folded_cfg

    def mk():
        reqs = _cycle_requests(cfg, LENS, MAX_NEWS)
        reqs[2] = Request(prompt=reqs[2].prompt, max_new_tokens=MAX_NEWS[2],
                          temperature=0.8)
        return reqs

    truth, seqs = _truth(cfg, folded, mk, **BASE)
    eng = Engine(cfg, folded, EngineConfig(
        spec_k=3, draft=SequenceDraft(seqs), **BASE))
    got = _outs(eng, mk())
    for i, (g, t) in enumerate(zip(got, truth)):
        if i == 2:
            assert len(g) == MAX_NEWS[2]   # sampled: length-deterministic
        else:
            assert g == t


def test_spec_k_budget_clamps_at_max_new(folded_cfg):
    """Proposals never extend past max_new_tokens - 1 (the bonus token
    fills the budget): a huge spec_k is safe and still identical."""
    cfg, folded = folded_cfg
    kw = dict(BASE, max_len=96)
    mk = lambda: _cycle_requests(cfg, [3, 5], [2, 24], seed=11)
    truth, seqs = _truth(cfg, folded, mk, **kw)
    eng = Engine(cfg, folded, EngineConfig(
        spec_k=8, draft=SequenceDraft(seqs), **kw))
    assert _outs(eng, mk()) == truth
    c = eng.counters
    assert c["drafted"] == c["accepted"] + c["rejected"]
    assert all(0 <= k <= 8 for k in c["accept_len_hist"])


def test_spec_config_validation():
    with pytest.raises(EngineConfigError, match="spec_k"):
        EngineConfig(spec_k=-1).validate()
    with pytest.raises(EngineConfigError, match="paged"):
        EngineConfig(spec_k=2, cache_layout="contiguous").validate()
    with pytest.raises(EngineConfigError, match="kv_bits"):
        EngineConfig(spec_k=2, cache_layout="paged", kv_bits=4).validate()


# --- draft-source unit tests ---------------------------------------------


def test_prompt_lookup_draft():
    d = PromptLookupDraft(min_ngram=1, max_ngram=3)
    # cycle: suffix [1,2,3] reoccurs at the start, continuation is [4,5]
    assert d.propose(np.array([1, 2, 3, 4, 5, 1, 2, 3]), 2) == [4, 5]
    # longest n-gram wins over a shorter, more recent match
    assert d.propose(np.array([7, 2, 3, 9, 1, 2, 3]), 1) == [9]
    # no earlier occurrence -> nothing
    assert d.propose(np.array([1, 2, 3, 4]), 3) == []
    assert d.propose(np.array([1, 2, 3, 1]), 0) == []
    with pytest.raises(ValueError):
        PromptLookupDraft(min_ngram=0)
    with pytest.raises(ValueError):
        PromptLookupDraft(min_ngram=3, max_ngram=2)


def test_sequence_draft():
    d = SequenceDraft([[1, 2, 3, 4, 5]])
    assert d.propose(np.array([1, 2]), 2) == [3, 4]
    assert d.propose(np.array([1, 2]), 9) == [3, 4, 5]
    assert d.propose(np.array([2, 1]), 2) == []    # prefix mismatch
    assert d.propose(np.array([1, 2, 3, 4, 5]), 2) == []  # exhausted
    d.add([2, 1, 7])
    assert d.propose(np.array([2, 1]), 2) == [7]


def test_make_draft_source():
    assert isinstance(make_draft_source("prompt_lookup"), PromptLookupDraft)
    d = SequenceDraft()
    assert make_draft_source(d) is d
    with pytest.raises(ValueError, match="prompt_lookup"):
        make_draft_source("no_such_draft")
    with pytest.raises(TypeError):
        make_draft_source(42)
