"""Mixtral 8x22B  [arXiv:2401.04088] — 8 experts top-2, SWA per task spec."""
from repro.configs.base import ModelConfig, register

CFG = register(ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16_384, vocab_size=32_768,
    n_experts=8, top_k=2, moe_d_ff=16_384, moe_period=1,
    sliding_window=4096,
    rope_theta=1_000_000.0, param_dtype="bfloat16",
))
