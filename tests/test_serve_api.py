"""Event-driven serving API: cancellation and deadline sheds must never
perturb surviving requests' tokens or leak pool state, the replica router
must be output-identical to a single engine, and the asyncio frontend must
stream/cancel/time-out over the same core without touching token identity.

Async tests drive real event loops via plain ``asyncio.run`` (no plugin);
determinism holds because the core is ticked, not threaded.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import fold as F
from repro.models import transformer as T
from repro.serve import stats as SS
from repro.serve.engine import (Engine, EngineConfig, Request,
                                RequestCancelled, RequestFailed,
                                RequestStatus)
from repro.serve.router import (ReplicaRouter, RouterBusy, RouterConfig,
                                RouterConfigError)
from repro.serve.server import AsyncServer

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def folded_cfg():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, KEY)
    amax = T.init_amax(cfg)
    calib = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, obs, _ = T.forward(cfg, params, amax, calib)
    return cfg, F.fold_params(cfg, params, obs)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (ln,)).astype(np.int32)
            for ln in lens]


def _truth(cfg, folded, prompts, max_news):
    """Undisturbed single-engine reference for token identity."""
    eng = Engine(cfg, folded, EngineConfig(batch_slots=2, max_len=64))
    reqs = [Request(prompt=p.copy(), max_new_tokens=mn)
            for p, mn in zip(prompts, max_news)]
    return [r.out.tolist() for r in eng.generate(reqs)]


def _paged_cfg(**kw):
    base = dict(batch_slots=2, max_len=64, cache_layout="paged", page_size=4)
    base.update(kw)
    return EngineConfig(**base)


def _sweep(eng):
    """Per-tick invariants: slot accounting, pool conservation, allocator
    refcount sweep (``check=True``)."""
    g = eng.stats(check=True)
    assert g["decode_slots_active"] + g["prefill_slots"] \
        + g["free_slots"] == eng.batch
    if "pages_capacity" in g:
        assert g["pages_in_use"] + g["pages_free"] \
            + g["pages_cached_lru"] == g["pages_capacity"]
    return g


def _drive(eng, max_ticks=500, on_tick=None):
    ticks = 0
    while eng.has_work:
        assert ticks < max_ticks, "engine livelocked"
        ticks += 1
        eng.poll()
        _sweep(eng)
        if on_tick is not None:
            on_tick(ticks)
    return ticks


def test_cancel_mid_prefill_survivors_identical(folded_cfg):
    """Cancel a request while its chunked prefill is still in flight: the
    slot/pages free immediately and the survivors' greedy tokens match an
    engine that never saw the victim."""
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [16, 6, 6])
    truth = _truth(cfg, folded, prompts[1:], [8, 8])

    eng = Engine(cfg, folded, _paged_cfg(
        max_batched_tokens=4, max_prefill_chunk=4))   # 16-prompt: 4 ticks
    victim = Request(prompt=prompts[0].copy(), max_new_tokens=8)
    survivors = [Request(prompt=p.copy(), max_new_tokens=8)
                 for p in prompts[1:]]
    vid = eng.submit(victim)
    for r in survivors:
        eng.submit(r)
    eng.poll()
    _sweep(eng)
    assert victim.status is RequestStatus.PREFILL     # mid-prefill for real
    assert eng.cancel(vid)
    _sweep(eng)
    _drive(eng)
    assert victim.status is RequestStatus.CANCELLED
    assert victim.out.tolist() == []                  # nothing emitted yet
    with pytest.raises(RequestCancelled):
        victim.result()
    assert [r.result().tolist() for r in survivors] == truth
    assert eng.counters["cancelled"] == 1
    assert eng.alloc.live == 0


def test_cancel_mid_decode_partial_prefix_and_survivors(folded_cfg):
    """Cancel after a few decode steps: the victim keeps its emitted prefix
    in ``.out`` (a prefix of its own truth), survivors are untouched."""
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6, 6])
    full = _truth(cfg, folded, prompts, [12, 12])

    eng = Engine(cfg, folded, _paged_cfg())
    victim = Request(prompt=prompts[0].copy(), max_new_tokens=12)
    other = Request(prompt=prompts[1].copy(), max_new_tokens=12)
    vid = eng.submit(victim)
    eng.submit(other)
    emitted = {vid: 0}
    # drive by hand: cancel once the victim has decoded >= 3 tokens
    ticks = 0
    cancelled = False
    while eng.has_work:
        assert ticks < 500
        ticks += 1
        for ev in eng.poll():
            if ev.rid == vid and ev.token is not None:
                emitted[vid] += 1
        _sweep(eng)
        if not cancelled and emitted[vid] >= 3:
            assert victim.status is RequestStatus.DECODE
            assert eng.cancel(vid)
            cancelled = True
            _sweep(eng)
    assert cancelled
    assert victim.status is RequestStatus.CANCELLED
    partial = victim.out.tolist()
    assert 3 <= len(partial) < 12
    assert partial == full[0][:len(partial)]          # truth prefix
    assert other.result().tolist() == full[1]
    assert eng.alloc.live == 0


def test_deadline_shed_does_not_poison_pool(folded_cfg):
    """Queued requests past ``deadline_tick`` are shed WAITING (they never
    held pages); the running survivor finishes bit-identically and the
    pool sweeps clean every tick."""
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6, 6, 6])
    truth = _truth(cfg, folded, prompts[:1], [10])

    eng = Engine(cfg, folded, _paged_cfg(batch_slots=1))
    keeper = Request(prompt=prompts[0].copy(), max_new_tokens=10)
    late = [Request(prompt=p.copy(), max_new_tokens=10, deadline_tick=2)
            for p in prompts[1:]]
    eng.submit(keeper)
    for r in late:
        eng.submit(r)
    _drive(eng)
    assert keeper.result().tolist() == truth[0]
    for r in late:
        assert r.status is RequestStatus.CANCELLED
        assert r.finish_reason == "deadline"
        with pytest.raises(RequestCancelled):
            r.result()
    assert eng.counters["shed_deadline"] == 2
    assert eng.alloc.live == 0
    g = eng.stats(check=True)
    assert g["pages_in_use"] == 0 and g["free_slots"] == eng.batch


def test_router_two_replicas_identical_to_single_engine(folded_cfg):
    """Data-parallel routing over two fresh replicas must not change a
    single token vs the single-engine run, per the identity contract."""
    cfg, folded = folded_cfg
    lens = [6, 10, 4, 8, 6, 12]
    prompts = _prompts(cfg, lens)
    max_news = [8] * len(prompts)
    truth = _truth(cfg, folded, prompts, max_news)

    replicas = [Engine(cfg, folded, _paged_cfg()) for _ in range(2)]
    router = ReplicaRouter(replicas)
    reqs = [Request(prompt=p.copy(), max_new_tokens=mn)
            for p, mn in zip(prompts, max_news)]
    for r in reqs:
        router.submit(r)
    ticks = 0
    while router.has_work:
        assert ticks < 500, "router livelocked"
        ticks += 1
        router.poll()
        SS.validate_router_stats(router.stats())
        for rep in replicas:
            _sweep(rep)
    assert [r.result().tolist() for r in reqs] == truth
    assert sum(rep.counters["completed"] for rep in replicas) == len(reqs)
    c = router.counters
    assert c["submitted"] == c["dispatched"] == c["completed"] == len(reqs)
    # both replicas actually took work (least-loaded, fresh, 2 available)
    assert all(rep.counters["completed"] >= 1 for rep in replicas)


def test_router_bounded_queue_rejects(folded_cfg):
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6] * 5)
    replicas = [Engine(cfg, folded, _paged_cfg(batch_slots=1))]
    router = ReplicaRouter(replicas, RouterConfig(max_queue=2))
    reqs = [Request(prompt=p.copy(), max_new_tokens=4) for p in prompts]
    accepted = []
    rejected = 0
    for r in reqs:
        try:
            router.submit(r)
            accepted.append(r)
        except RouterBusy:
            rejected += 1
    assert rejected == 3 and len(accepted) == 2      # queue bound is real
    assert router.counters["rejected"] == 3
    while router.has_work:
        router.poll()
    for r in accepted:
        assert r.status is RequestStatus.FINISHED


def test_router_failed_dispatch_surfaces_as_failed(folded_cfg):
    """A request the engine rejects at dispatch (doesn't fit max_len) must
    come back FAILED with the engine's reason, not crash the router."""
    cfg, folded = folded_cfg
    replicas = [Engine(cfg, folded, _paged_cfg())]
    router = ReplicaRouter(replicas)
    bad = Request(prompt=_prompts(cfg, [8])[0], max_new_tokens=500)
    router.submit(bad)
    events = router.poll()
    assert bad.status is RequestStatus.FAILED
    assert bad.finish_reason.startswith("error:")
    assert any(e.final and e.finish_reason == bad.finish_reason
               for e in events)
    with pytest.raises(RequestFailed):
        bad.result()
    assert not router.has_work


def test_router_cancel_queued_and_dispatched(folded_cfg):
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6, 6, 6])
    replicas = [Engine(cfg, folded, _paged_cfg(batch_slots=1))]
    router = ReplicaRouter(replicas)
    reqs = [Request(prompt=p.copy(), max_new_tokens=8) for p in prompts]
    rids = [router.submit(r) for r in reqs]
    router.poll()                         # dispatches r0 (slots=1)
    assert router.cancel(rids[2])         # still in the router queue
    assert reqs[2].status is RequestStatus.CANCELLED
    assert router.cancel(rids[0])         # dispatched: flows via replica
    while router.has_work:
        router.poll()
    assert reqs[0].status is RequestStatus.CANCELLED
    assert reqs[1].status is RequestStatus.FINISHED
    assert router.counters["cancelled"] == 2
    assert not router.cancel(999)         # unknown rid
    assert replicas[0].alloc.live == 0


def test_router_config_validates_and_shims_loose_kwargs(folded_cfg):
    """RouterConfig is typed + validated like EngineConfig; the loose
    keyword style still works for one release behind a DeprecationWarning
    and maps onto the same config object."""
    with pytest.raises(RouterConfigError, match="max_queue"):
        RouterConfig(max_queue=0).validate()
    with pytest.raises(RouterConfigError, match="thresholds"):
        RouterConfig(min_free_pages=-1).validate()
    with pytest.raises(RouterConfigError, match="max_affinity_pages"):
        RouterConfig(max_affinity_pages=0).validate()
    with pytest.raises(RouterConfigError, match="shed_policy"):
        RouterConfig(shed_policy="yolo").validate()
    with pytest.raises(TypeError, match="max_queue"):
        RouterConfig.from_kwargs(max_q=3)      # typo names the valid fields

    cfg, folded = folded_cfg
    eng = Engine(cfg, folded, _paged_cfg())
    with pytest.warns(DeprecationWarning, match="RouterConfig"):
        router = ReplicaRouter([eng], max_queue=2, affinity=False)
    assert router.config == RouterConfig(max_queue=2, affinity=False)
    with pytest.raises(TypeError, match="not both"):
        ReplicaRouter([eng], RouterConfig(), max_queue=2)
    with pytest.raises(RouterConfigError, match="max_queue"):
        ReplicaRouter([eng], RouterConfig(max_queue=0))


def test_router_affinity_steers_to_prefix_holder(folded_cfg):
    """A request whose prefix chain lives on replica 1 must be steered
    there by affinity — overriding the least-loaded preference for the
    fresher replica 0 — and to replica 0 with affinity off."""
    cfg, folded = folded_cfg
    prompt = _prompts(cfg, [14])[0]
    truth = _truth(cfg, folded, [prompt], [6])

    def warmed_pair():
        reps = [Engine(cfg, folded, _paged_cfg()) for _ in range(2)]
        warm = Request(prompt=prompt.copy(), max_new_tokens=6)
        reps[1].submit(warm)
        reps[1].run()
        assert warm.result().tolist() == truth[0]
        held = reps[1].prefix_store.match([int(t) for t in prompt])
        assert held.n_pages == (len(prompt) - 1) // 4
        return reps

    for affinity, target in ((True, 1), (False, 0)):
        reps = warmed_pair()
        router = ReplicaRouter(reps, RouterConfig(affinity=affinity))
        req = Request(prompt=prompt.copy(), max_new_tokens=6)
        router.submit(req)
        router.poll()
        assert len(router._rev[target]) == 1   # placement, directly
        while router.has_work:
            router.poll()
        assert req.result().tolist() == truth[0]
        c = router.counters
        assert (c["affinity_hits"], c["affinity_misses"]) == \
            ((1, 0) if affinity else (0, 0))
        assert reps[target].counters["completed"] == 1 + target
        # the steered replica serves the prefix from its registry
        assert reps[1].counters["prefix_hits"] == (1 if affinity else 0)


def test_router_dispatch_is_deterministic_run_to_run(folded_cfg):
    """The same trace through a fresh 2-replica router twice: identical
    tokens AND identical placement (per-replica counters) — the explicit
    index tiebreak leaves nothing to iteration order."""
    cfg, folded = folded_cfg
    base = _prompts(cfg, [8], seed=21)[0]
    tails = _prompts(cfg, [6, 4, 6, 4, 8], seed=22)
    prompts = [np.concatenate([base, t]) for t in tails]

    def run_trace():
        reps = [Engine(cfg, folded, _paged_cfg()) for _ in range(2)]
        router = ReplicaRouter(reps, RouterConfig())
        reqs = [Request(prompt=p.copy(), max_new_tokens=6) for p in prompts]
        for r in reqs:
            router.submit(r)
        ticks = 0
        while router.has_work:
            assert ticks < 500
            ticks += 1
            router.poll()
        placement = [rep.counters["completed"] for rep in reps]
        return ([r.result().tolist() for r in reqs], placement,
                dict(router.counters))

    out1, place1, c1 = run_trace()
    out2, place2, c2 = run_trace()
    assert out1 == out2 and place1 == place2 and c1 == c2
    assert c1["affinity_hits"] + c1["affinity_misses"] == len(prompts)


def test_router_shared_tier_adoption_identical_to_single_engine(folded_cfg):
    """Full tentpole path through the router: replica 0 publishes a prefix
    chain to the shared tier; after its registry is reclaimed (cache
    pressure), the next same-prefix request ADOPTS from the tier instead
    of re-prefilling — tokens stay identical to the single-engine truth."""
    cfg, folded = folded_cfg
    base = _prompts(cfg, [12], seed=31)[0]
    tails = _prompts(cfg, [6, 6], seed=32)
    prompts = [np.concatenate([base, t]) for t in tails]
    truth = _truth(cfg, folded, prompts, [6, 6])

    reps = [Engine(cfg, folded, _paged_cfg()) for _ in range(2)]
    router = ReplicaRouter(reps, RouterConfig(shared_tier=True))
    assert router.prefix_tier is not None
    assert all(rep.prefix_tier is router.prefix_tier for rep in reps)

    first = Request(prompt=prompts[0].copy(), max_new_tokens=6)
    router.submit(first)
    while router.has_work:
        router.poll()
        SS.validate_router_stats(router.stats())
    assert first.result().tolist() == truth[0]
    published = router.prefix_tier.n_pages
    assert published > 0 and reps[0].counters["published_pages"] == published

    # reclaim replica 0's registry through the allocator (cache pressure):
    # the tier's host copies are now the only place the chain survives
    taken = reps[0].alloc.alloc(reps[0].alloc.available())
    reps[0].alloc.free_pages(taken)
    assert reps[0].prefix_store.match([int(t) for t in base]).n_pages == 0
    assert router.prefix_tier.n_pages == published

    second = Request(prompt=prompts[1].copy(), max_new_tokens=6)
    router.submit(second)
    while router.has_work:
        router.poll()
        SS.validate_router_stats(router.stats())
        for rep in reps:
            _sweep(rep)
    assert second.result().tolist() == truth[1]
    adopter = reps[1] if reps[1].counters["adopted_pages"] else reps[0]
    assert adopter.counters["adopted_pages"] > 0
    assert adopter.counters["prefix_hits"] >= 1
    s = router.stats()
    assert s["shared_tier_pages"] >= published
    assert s["counters"]["affinity_hits"] + s["counters"]["affinity_misses"] \
        == 2


def test_router_shared_tier_rejects_ineligible_replicas(folded_cfg):
    cfg, folded = folded_cfg
    contiguous = Engine(cfg, folded, EngineConfig(
        batch_slots=2, max_len=64, cache_layout="contiguous"))
    with pytest.raises(RouterConfigError, match="paged"):
        ReplicaRouter([contiguous], RouterConfig(shared_tier=True))


def test_async_server_streams_and_matches_truth(folded_cfg):
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6, 10, 4])
    truth = _truth(cfg, folded, prompts, [8, 8, 8])

    async def run():
        core = Engine(cfg, folded, _paged_cfg())
        server = AsyncServer(core)
        task = asyncio.ensure_future(server.serve_forever())
        handles = [await server.submit(
            Request(prompt=p.copy(), max_new_tokens=8)) for p in prompts]
        streams = [await h.tokens() for h in handles]
        server.stop()
        await task
        return streams, [h.result().tolist() for h in handles]

    streams, results = asyncio.run(run())
    assert streams == truth               # streamed tokens, in order
    assert results == truth               # and the terminal result() agrees


def test_async_server_cancel_mid_stream(folded_cfg):
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6, 6])
    truth = _truth(cfg, folded, prompts, [12, 12])

    async def run():
        core = Engine(cfg, folded, _paged_cfg())
        server = AsyncServer(core)
        task = asyncio.ensure_future(server.serve_forever())
        victim = Request(prompt=prompts[0].copy(), max_new_tokens=12)
        other = Request(prompt=prompts[1].copy(), max_new_tokens=12)
        hv = await server.submit(victim)
        ho = await server.submit(other)
        got = []
        async for tok in hv:
            got.append(tok)
            if len(got) == 3:
                hv.cancel()
        out = await ho.tokens()
        server.stop()
        await task
        return victim, got, out

    victim, got, out = asyncio.run(run())
    assert victim.status is RequestStatus.CANCELLED
    assert got == truth[0][:len(got)] and len(got) >= 3
    assert out == truth[1]


def test_async_server_timeout_cancels(folded_cfg):
    cfg, folded = folded_cfg
    prompt = _prompts(cfg, [6])[0]

    async def run():
        core = Engine(cfg, folded, _paged_cfg())
        server = AsyncServer(core)
        task = asyncio.ensure_future(server.serve_forever())
        req = Request(prompt=prompt.copy(), max_new_tokens=12)
        h = await server.submit(req, timeout=0.0)    # fires next loop turn
        toks = await h.tokens()
        server.stop()
        await task
        return req, toks

    req, toks = asyncio.run(run())
    assert req.status is RequestStatus.CANCELLED
    assert req.finish_reason == "cancelled"
    assert toks == req.out.tolist()       # stream saw exactly the partial


def test_stats_schema_is_frozen(folded_cfg):
    cfg, folded = folded_cfg
    eng = Engine(cfg, folded, _paged_cfg())
    eng.submit(Request(prompt=_prompts(cfg, [6])[0], max_new_tokens=2))
    eng.poll()
    s = eng.stats()
    assert s["schema_version"] == SS.STATS_SCHEMA_VERSION
    SS.validate_stats(s, paged=True)
    SS.validate_counters(s["counters"])

    missing = {k: v for k, v in s.items() if k != "pages_free"}
    with pytest.raises(SS.StatsSchemaError, match="missing"):
        SS.validate_stats(missing, paged=True)
    unknown = dict(s, surprise=1)
    with pytest.raises(SS.StatsSchemaError, match="unknown"):
        SS.validate_stats(unknown, paged=True)
    stale = dict(s, schema_version=SS.STATS_SCHEMA_VERSION + 1)
    with pytest.raises(SS.StatsSchemaError, match="schema_version"):
        SS.validate_stats(stale, paged=True)
    bad_counters = {k: v for k, v in s["counters"].items() if k != "ticks"}
    with pytest.raises(SS.StatsSchemaError, match="ticks"):
        SS.validate_counters(bad_counters)
    with pytest.raises(SS.StatsSchemaError, match="router"):
        SS.validate_router_counters({"bogus": 1}, what="router counters")
    eng.run()                             # drain so the pool sweeps clean
    assert eng.alloc.live == 0


def test_step_wrapper_and_poll_are_the_same_core(folded_cfg):
    """`step()` is a thin view over `poll()`: two fresh engines driven
    through either entry point emit identical tokens."""
    cfg, folded = folded_cfg
    prompts = _prompts(cfg, [6, 10])

    def via_step():
        eng = Engine(cfg, folded, _paged_cfg())
        reqs = [Request(prompt=p.copy(), max_new_tokens=6) for p in prompts]
        for r in reqs:
            eng.submit(r)
        while eng.has_work:
            eng.step()
        return [r.out.tolist() for r in reqs]

    def via_poll():
        eng = Engine(cfg, folded, _paged_cfg())
        reqs = [Request(prompt=p.copy(), max_new_tokens=6) for p in prompts]
        for r in reqs:
            eng.submit(r)
        toks = {r.rid: [] for r in reqs}
        while eng.has_work:
            for ev in eng.poll():
                if ev.token is not None:
                    toks[ev.rid].append(ev.token)
        assert [toks[r.rid] for r in reqs] == [r.out.tolist() for r in reqs]
        return [r.out.tolist() for r in reqs]

    assert via_step() == via_poll()
