"""Integer LayerNorm Pallas kernel — the paper's 3-stage "LN Core" on the VPU.

Stage 1 (row sum -> mean), stage 2 (centered sum of squares -> variance) and
stage 3 (integer Newton rsqrt, gamma multiply, aligned beta add, fixed-point
requantize) run per row-block, all int32.  Bit-identical to
``repro.core.qlayernorm.quant_layernorm``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixedpoint as fxp


def _ln_kernel(sub_mean: bool, eps_codes: int,
               x_ref, g_ref, b_ref, m_ref, s_ref, o_ref):
    xi = x_ref[...].astype(jnp.int32)
    n = xi.shape[-1]
    if sub_mean:
        ssum = jnp.sum(xi, axis=-1, keepdims=True)
        half = n // 2
        mean = jnp.where(ssum >= 0, (ssum + half) // n, -((-ssum + half) // n))
        c = xi - mean
    else:
        c = xi
    ss = jnp.sum(c * c, axis=-1, keepdims=True)
    half = n // 2
    var = jnp.where(ss >= 0, (ss + half) // n, 0)
    var = jnp.maximum(var, eps_codes)
    y_m, s_e = fxp.rsqrt_mantexp(var)
    n_q = fxp._rshift_round(c * y_m, s_e + 1)
    acc = n_q * g_ref[...].astype(jnp.int32) + b_ref[...].astype(jnp.int32)
    y = fxp.rescale(acc, m_ref[0], s_ref[0])
    o_ref[...] = jnp.clip(y, -127, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("subtract_mean", "eps_codes",
                                              "block_rows", "interpret"))
def quant_layernorm(
    x_i8: jax.Array,        # int8 (R, N)
    gamma_i: jax.Array,     # int8 (N,)
    beta_aligned: jax.Array,  # int32 (N,)
    M_out: jax.Array,
    shift_out: jax.Array,
    *,
    subtract_mean: bool = True,
    eps_codes: int = 1,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    r, n = x_i8.shape
    br = min(block_rows, r)
    assert r % br == 0
    kernel = functools.partial(_ln_kernel, subtract_mean, eps_codes)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.int8),
        interpret=interpret,
    )(x_i8, gamma_i, beta_aligned,
      jnp.asarray(M_out, jnp.int32).reshape(1),
      jnp.asarray(shift_out, jnp.int32).reshape(1))
