"""Frozen, versioned schema for the static-analysis report (ANALYSIS.json).

Mirrors the ``repro.serve.stats`` contract: the key sets below are the
single source of truth, payloads carry the version under
``schema_version``, and consumers (the ``analyze`` CLI, the bench
regression gate, tests) validate exact key sets instead of guessing from
shape.  :data:`ANALYSIS_SCHEMA_VERSION` bumps whenever a key is added,
removed, or changes meaning.  Version 1 is the first frozen schema.

Baseline policy — what is gated vs merely recorded:

* ``violations`` — gated at zero on any FRESH report, no baseline needed
  (the committed baseline is exempt only in the sense that it never has
  any: a baseline with violations should never have been committed).
* per-graph ``float_prims`` — the SET of primitive names that produce a
  float output in each audited graph.  Gated as a one-way ratchet vs the
  committed baseline: a new float primitive appearing in a hot graph is
  exactly the "integer pipeline regresses to float one op at a time"
  failure the subsystem exists to catch.  Sets, not counts: eqn counts
  shift with jax/XLA versions and fusion decisions; the set of float op
  *kinds* on the serve path is the stable contract.
* ``op_histogram`` / ``hbm_bytes_by_dtype`` / ``n_eqns`` — recorded for
  the trajectory, deliberately not gated (version-noisy).
"""
from __future__ import annotations

from typing import Dict, List, Mapping

ANALYSIS_SCHEMA_VERSION = 1

REPORT_KIND = "analysis_report"

# --- top-level report keys ----------------------------------------------
REPORT_KEYS: Dict[str, str] = {
    "kind": f"artifact discriminator: always {REPORT_KIND!r}",
    "schema_version": "analysis schema version (this module)",
    "jax_version": "jax the graphs were traced under (informational)",
    "presets": "preset name -> per-preset audit payload",
    "skipped": "list of {preset, reason} for presets this host cannot run",
    "pallas_lint": "kernel lint payload: {checks, violations}",
    "violations_total": "total violations across presets + pallas lint",
}

PRESET_KEYS: Dict[str, str] = {
    "config": "engine knobs: {kv_bits, tp, spec_k}",
    "graphs": "hot-graph name -> per-graph audit payload",
}

GRAPH_KEYS: Dict[str, str] = {
    "n_eqns": "eqns walked (all nesting levels)",
    "violations": "list of {rule, graph, scope, detail}",
    "op_histogram": "output dtype -> primitive -> eqn count",
    "float_prims": "sorted primitive names with a float output (GATED set)",
    "hbm_bytes_by_dtype": "HLO-estimated HBM bytes per dtype ({} if no HLO)",
}

VIOLATION_KEYS: Dict[str, str] = {
    "rule": "stable rule id (INT-DOT-FLOAT, DONATION, IDXMAP-RANGE, ...)",
    "graph": "hot graph / kernel the violation was found in",
    "scope": "nested eqn path inside the graph ('' for graph-level)",
    "detail": "human-readable location + why",
}

PALLAS_KEYS: Dict[str, str] = {
    "checks": "list of {check, kernel, ok, detail} per lint group",
    "violations": "list of {rule, graph, scope, detail}",
}

_REPORT = frozenset(REPORT_KEYS)
_PRESET = frozenset(PRESET_KEYS)
_GRAPH = frozenset(GRAPH_KEYS)
_VIOLATION = frozenset(VIOLATION_KEYS)
_PALLAS = frozenset(PALLAS_KEYS)


class AnalysisSchemaError(ValueError):
    """An ANALYSIS.json payload does not match the frozen schema."""


def _check_keys(got, expected, what: str):
    missing = sorted(expected - got)
    unknown = sorted(got - expected)
    if missing or unknown:
        raise AnalysisSchemaError(
            f"{what} does not match analysis schema "
            f"v{ANALYSIS_SCHEMA_VERSION}: missing={missing} "
            f"unknown={unknown}")


def _check_violations(viols, what: str):
    for i, v in enumerate(viols):
        _check_keys(set(v), _VIOLATION, f"{what}[{i}]")


def validate_report(doc: Mapping, *, what: str = "ANALYSIS.json") -> Mapping:
    """Exact-match a report against the frozen schema, all levels deep."""
    if doc.get("kind") != REPORT_KIND:
        raise AnalysisSchemaError(
            f"{what} carries kind={doc.get('kind')!r}, expected "
            f"{REPORT_KIND!r}")
    if doc.get("schema_version") != ANALYSIS_SCHEMA_VERSION:
        raise AnalysisSchemaError(
            f"{what} carries schema_version={doc.get('schema_version')!r}, "
            f"this build understands {ANALYSIS_SCHEMA_VERSION}")
    _check_keys(set(doc), _REPORT, what)
    for pname, preset in doc["presets"].items():
        pwhat = f"{what}['presets'][{pname!r}]"
        _check_keys(set(preset), _PRESET, pwhat)
        for gname, graph in preset["graphs"].items():
            gwhat = f"{pwhat}['graphs'][{gname!r}]"
            _check_keys(set(graph), _GRAPH, gwhat)
            _check_violations(graph["violations"], f"{gwhat}['violations']")
    _check_keys(set(doc["pallas_lint"]), _PALLAS, f"{what}['pallas_lint']")
    _check_violations(doc["pallas_lint"]["violations"],
                      f"{what}['pallas_lint']['violations']")
    for i, sk in enumerate(doc["skipped"]):
        _check_keys(set(sk), {"preset", "reason"}, f"{what}['skipped'][{i}]")
    return doc


def count_violations(doc: Mapping) -> int:
    n = sum(len(g["violations"]) for p in doc["presets"].values()
            for g in p["graphs"].values())
    return n + len(doc["pallas_lint"]["violations"])


def build_report(*, presets: Mapping, skipped: List[Dict],
                 pallas: Mapping, jax_version: str) -> Dict:
    """Assemble + validate a report from ``audit_engine`` results.

    ``presets`` maps preset name -> (config dict, {graph: AuditResult},
    {graph: hbm_bytes_by_dtype dict})."""
    doc: Dict = {
        "kind": REPORT_KIND,
        "schema_version": ANALYSIS_SCHEMA_VERSION,
        "jax_version": jax_version,
        "presets": {},
        "skipped": list(skipped),
        "pallas_lint": {"checks": list(pallas["checks"]),
                        "violations": list(pallas["violations"])},
        "violations_total": 0,
    }
    for name, (config, results, hbm) in presets.items():
        graphs = {}
        for gname, res in results.items():
            graphs[gname] = {
                "n_eqns": res.n_eqns,
                "violations": [v.to_dict() for v in res.violations],
                "op_histogram": res.op_histogram,
                "float_prims": sorted(res.float_prims),
                "hbm_bytes_by_dtype": dict(hbm.get(gname, {})),
            }
        doc["presets"][name] = {"config": dict(config), "graphs": graphs}
    doc["violations_total"] = count_violations(doc)
    return validate_report(doc)


def compare_to_baseline(cur: Mapping, base: Mapping) -> List[str]:
    """One-way float-primitive ratchet vs the committed baseline.

    Returns failure strings for (a) any float primitive newly producing
    output in a graph both reports audited, and (b) any baseline
    preset/graph that vanished from the current report without being
    recorded as skipped.  Presets only the current report has (new
    hardware, new knobs) are fine — they become gated once committed."""
    failures: List[str] = []
    validate_report(cur, what="current report")
    validate_report(base, what="baseline report")
    skipped_now = {s["preset"] for s in cur["skipped"]}
    for pname, bpreset in base["presets"].items():
        if pname not in cur["presets"]:
            if pname not in skipped_now:
                failures.append(
                    f"preset {pname!r} is in the baseline but the current "
                    "report neither audited nor skipped it")
            continue
        cgraphs = cur["presets"][pname]["graphs"]
        for gname, bgraph in bpreset["graphs"].items():
            if gname not in cgraphs:
                failures.append(
                    f"graph {pname}/{gname} is in the baseline but missing "
                    "from the current report")
                continue
            new = sorted(set(cgraphs[gname]["float_prims"])
                         - set(bgraph["float_prims"]))
            if new:
                failures.append(
                    f"graph {pname}/{gname} grew new float primitives "
                    f"{new} — the integer datapath regressed toward float "
                    "(update the baseline ONLY if this is intentional)")
    return failures
