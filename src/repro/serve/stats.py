"""Frozen, versioned schema for the serving-stack observability payloads.

This module is the single source of truth for the key sets of

* ``Engine.stats()``        — instantaneous gauges + cumulative counters,
* ``Engine.counters``       — the cumulative counter dict itself,
* ``ReplicaRouter.stats()`` — router gauges wrapping per-replica payloads.

Before this schema existed the key names were asserted ad-hoc in three
places (engine tests, ``serve_bench.py``'s per-tick trace, and
``check_regression.py``'s artifact walk); adding a counter meant silently
desynchronizing whichever one you forgot.  Now the engine *builds* its
counter dict from :data:`COUNTERS`, validates every ``stats()`` payload
against the gauge sets on the way out, and the bench + regression gate
import the same sets — a key can no longer exist in one consumer's world
and not another's.

Versioning contract: :data:`STATS_SCHEMA_VERSION` bumps whenever a key is
added, removed, or its meaning changes.  Payloads carry the version under
``schema_version``; consumers that persist or compare payloads (the bench
artifacts, the regression gate) must check it rather than guessing from
key shape.  Version 1 is the first frozen schema (the PR-5 payload plus
the request-lifecycle counters ``cancelled`` / ``shed_deadline``).
Version 2 adds the speculative-decoding keys: the ``spec_k`` gauge and
the ``drafted`` / ``accepted`` / ``rejected`` / ``accept_len_hist``
counters (the histogram is the one non-scalar counter — a dict mapping
per-tick accepted-proposal length to tick count).
Version 3 adds the cross-replica prefix-sharing keys: the engine
counters ``published_pages`` / ``adopted_pages`` (sealed prefix pages
exported to / installed from the shared tier), the router counters
``affinity_hits`` / ``affinity_misses`` (dispatches steered by the
prefix-affinity probe vs fallen back to least-loaded), and the router
gauge ``shared_tier_pages``.
"""
from __future__ import annotations

from typing import Dict, Mapping

STATS_SCHEMA_VERSION = 3

# --- Engine.stats() gauges (every layout) --------------------------------
GAUGES: Dict[str, str] = {
    "schema_version": "stats schema version (this module)",
    "waiting": "requests queued, not yet seated in a slot",
    "decode_slots_active": "slots whose whole prompt is cached (decoding)",
    "prefill_slots": "slots mid-prefill (chunk cursor short of the prompt)",
    "free_slots": "unoccupied slots",
    "prefill_tokens_pending": "prompt rows still to prefill across slots",
    "prefill_chunks_pending": "prefill chunk forwards still to run",
    "spec_k": "configured max draft proposals per slot per tick (0 = off)",
}

# --- extra gauges present iff cache_layout == "paged" --------------------
PAGED_GAUGES: Dict[str, str] = {
    "pages_in_use": "pool pages with refcount > 0",
    "pages_free": "pages on the free list proper",
    "pages_cached_lru": "refcount-0 registered pages (reclaimable prefix cache)",
    "pages_capacity": "allocatable pages (pool minus the trash page)",
    "tp": "tensor-parallel degree the pool is sharded over",
}

# --- Engine.counters (cumulative; Engine builds its dict from this) ------
COUNTERS: Dict[str, str] = {
    "ticks": "scheduler ticks (poll() calls)",
    "prefill_tokens": "prompt rows run through chunk forwards",
    "prefill_chunks": "prefill chunk forwards run",
    "oneshot_prefills": "prompts prefilled in a single chunk",
    "chunked_prefills": "prompts that took more than one chunk",
    "loop_prefill_steps": "batch-1 decode-loop prefill steps (SSM/SWA path)",
    "decode_steps": "batched decode forwards",
    "decode_tokens": "tokens produced by decode forwards",
    "completed": "requests finished (length or EOS)",
    "prefix_hits": "prompts that mapped registered prefix pages",
    "shared_rows": "prompt rows served from the prefix registry",
    "suffix_prefills": "prefix hits whose remainder ran in one chunk",
    "cache_pages_peak": "high-water mark of live pool pages",
    "grown_pages": "decode pages granted on demand",
    "preemptions": "victims spilled because the pool ran dry",
    "preempted_prefill": "victims spilled mid-prefill",
    "preempted_decode": "victims spilled mid-decode",
    "restores": "preempted requests re-seated",
    "spilled_rows": "cache rows held by victims at spill time",
    "recomputed_tokens": "replayed rows the prefix registry had lost",
    "pool_wait_ticks": "ticks a request waited on pages with a slot free",
    "cancelled": "requests cancelled via Engine.cancel()",
    "shed_deadline": "waiting requests shed at their deadline_tick",
    "drafted": "draft tokens proposed across all verify forwards",
    "accepted": "draft tokens accepted (argmax-matched) and committed",
    "rejected": "draft tokens rejected (cursor rolled back over them)",
    "accept_len_hist": "dict: accepted-prefix length -> slot-tick count",
    "published_pages": "sealed prefix pages exported to the shared tier",
    "adopted_pages": "prefix pages installed from the shared tier",
}

# --- ReplicaRouter.stats() gauges + counters -----------------------------
ROUTER_GAUGES: Dict[str, str] = {
    "schema_version": "stats schema version (this module)",
    "queued": "requests held in the router queue (not yet dispatched)",
    "inflight": "requests dispatched to a replica and not yet terminal",
    "n_replicas": "engine replicas behind the router",
    "replicas": "list of per-replica Engine.stats() payloads",
    "shared_tier_pages": "page payloads held by the shared prefix tier "
                         "(0 when the tier is off)",
}

ROUTER_COUNTERS: Dict[str, str] = {
    "ticks": "router polls (each ticks every replica once)",
    "submitted": "requests accepted into the router",
    "dispatched": "requests handed to a replica engine",
    "completed": "requests finished (length or EOS)",
    "rejected": "submissions refused because the queue was full",
    "shed_deadline": "queued requests shed at their deadline_tick",
    "cancelled": "requests cancelled through the router",
    "affinity_hits": "dispatches steered to a replica whose registry "
                     "already held the request's prefix chain",
    "affinity_misses": "affinity-enabled dispatches that fell back to "
                       "least-loaded (no replica held the chain)",
}

_GAUGE_KEYS = frozenset(GAUGES)
_PAGED_KEYS = frozenset(PAGED_GAUGES)
_COUNTER_KEYS = frozenset(COUNTERS)
_ROUTER_GAUGE_KEYS = frozenset(ROUTER_GAUGES)
_ROUTER_COUNTER_KEYS = frozenset(ROUTER_COUNTERS)


class StatsSchemaError(ValueError):
    """A stats/counters payload does not match the frozen schema."""


def _check_keys(got, expected, what: str):
    missing = sorted(expected - got)
    unknown = sorted(got - expected)
    if missing or unknown:
        raise StatsSchemaError(
            f"{what} does not match stats schema v{STATS_SCHEMA_VERSION}: "
            f"missing={missing} unknown={unknown}")


def _check_version(payload: Mapping, what: str):
    v = payload.get("schema_version")
    if v != STATS_SCHEMA_VERSION:
        raise StatsSchemaError(
            f"{what} carries schema_version={v!r}, this build understands "
            f"{STATS_SCHEMA_VERSION}")


def validate_counters(counters: Mapping, what: str = "Engine.counters"):
    """Exact-match the counter dict against :data:`COUNTERS`."""
    _check_keys(set(counters), _COUNTER_KEYS, what)
    return counters


def validate_router_counters(counters: Mapping,
                             what: str = "ReplicaRouter.counters"):
    """Exact-match the router counter dict against :data:`ROUTER_COUNTERS`."""
    _check_keys(set(counters), _ROUTER_COUNTER_KEYS, what)
    return counters


def validate_stats(stats: Mapping, *, paged: bool,
                   what: str = "Engine.stats()"):
    """Exact-match an ``Engine.stats()`` payload (gauges + counters)."""
    expected = _GAUGE_KEYS | {"counters"}
    if paged:
        expected = expected | _PAGED_KEYS
    _check_keys(set(stats), expected, what)
    _check_version(stats, what)
    validate_counters(stats["counters"], what=f"{what}['counters']")
    return stats


def validate_router_stats(stats: Mapping,
                          what: str = "ReplicaRouter.stats()"):
    """Exact-match a ``ReplicaRouter.stats()`` payload, including every
    embedded per-replica engine payload."""
    _check_keys(set(stats), _ROUTER_GAUGE_KEYS | {"counters"}, what)
    _check_version(stats, what)
    _check_keys(set(stats["counters"]), _ROUTER_COUNTER_KEYS,
                f"{what}['counters']")
    for i, rep in enumerate(stats["replicas"]):
        validate_stats(rep, paged="pages_capacity" in rep,
                       what=f"{what}['replicas'][{i}]")
    return stats
