"""Production mesh construction.

Single pod: 16 x 16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — 'pod' composes
with 'data' for gradient reduction / batch sharding; XLA emits hierarchical
collectives (reduce-scatter on ICI inside the pod, all-reduce across DCN).

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever-fits mesh for CPU tests: (1, n_devices//model, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_tp_mesh(model: int = 1):
    """1-D ('model',) mesh over the first ``model`` devices — the serving
    engine's tensor-parallel axis for the sharded paged KV pool.  Unlike
    ``make_host_mesh`` it does not require the total device count to divide:
    a TP=4 engine on an 8-device host takes devices [0, 4).  On CPU,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` simulates the
    devices — the recipe the test-tp CI lane runs under."""
    devs = jax.devices()
    assert 1 <= model <= len(devs), \
        f"TP={model} needs {model} devices, found {len(devs)} " \
        "(on CPU set XLA_FLAGS=--xla_force_host_platform_device_count)"
    return Mesh(np.asarray(devs[:model]), ("model",))
