"""Single source of truth for the v4-lite roofline ceilings.

``kernels/autotune.py`` (tile selection), ``benchmarks/roofline.py``
(artifact pricing) and ``repro/analysis/pallas_lint.py`` (VMEM budget
lint) all reason about the same machine; before this module each kept a
hand-mirrored copy of the constants, which is exactly the drift class the
analysis lane exists to catch.  Import from here — never re-declare.

Values are TPU v5e-class per-chip ceilings; the drift test
(``tests/test_autotune.py``) pins every consumer to these objects.
"""
from __future__ import annotations

PEAK_INT8_FLOPS = 197e12     # int8 MXU ops/s per chip
PEAK_FLOPS = PEAK_INT8_FLOPS  # bf16/int8 alias used by roofline pricing
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per ICI link
ICI_LINKS = 4                # links per chip

VMEM_BUDGET = 16 * 2**20     # bytes/core
VMEM_FILL = 0.5              # headroom for double-buffering + scratch
STEP_OVERHEAD_S = 2e-6       # DMA issue + grid step bookkeeping
